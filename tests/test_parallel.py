"""Parallel fleet runtime: per-shard threads, concurrency-safe core.

The load-bearing guarantees:

* ``ParallelShardedEngine`` (one ``ShardRunner`` thread per shard) is
  *transcript-identical* to the sequential ``ShardedEngine`` on
  randomized fleets — routed outcomes, violation counts, and per-shard
  summary rows all match, including under the barrier-synced virtual
  clock (the deterministic test mode the acceptance criteria pin);
* the sequential path (``parallel=False``) is untouched — the parallel
  class only ever *adds* threads on top of the same routing/merge;
* the shared state the shard threads touch concurrently stays exact:
  ``CostMeter`` billing aggregates to the cent, the striped-lock
  ``FrameStore`` drains to empty under concurrent release, and
  ``OnlineLatencyTable`` folds keep their invariants under concurrent
  observers;
* a shard thread that dies mid-run re-raises at ``finish()`` instead of
  hanging the fleet.
"""
import sys
import threading

import numpy as np
import pytest

from repro.core.clock import BarrierVirtualClock, WallClock
from repro.core.config import ServeConfig
from repro.core.cost import CostMeter, alibaba_cost
from repro.core.engine import ServingEngine, SimExecutor
from repro.core.fleet import FleetPlan, ShardedEngine, fleet_uniform_pool
from repro.core.framestore import FrameStore
from repro.core.latency import LatencyTable, OnlineLatencyTable
from repro.core.parallel import ParallelShardedEngine, ShardRunner
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig
from repro.sources import FleetCameraSource, make_source

TABLE = LatencyTable({1: (0.05, 0.0), 2: (0.08, 0.0), 4: (0.12, 0.0),
                      8: (0.2, 0.0)})
GROUP = 4


def classify(p):
    return (p.slo, p.camera_id // GROUP)


def det_platform(instances=64, seed=0):
    return Platform(TABLE, PlatformConfig(
        max_instances=instances, pre_warm=instances, cold_start_s=0.0,
        keep_alive_s=1e9, seed=seed))


def fleet_arrivals(n_cameras=40, duration_s=3.0, seed=7, **kw):
    return FleetCameraSource(n_cameras=n_cameras, duration_s=duration_s,
                             seed=seed, **kw).arrivals()


def outcome_key(o):
    return (o.patch.camera_id, o.patch.frame_id, o.patch.x0, o.patch.y0,
            round(o.t_arrive, 9), round(o.t_submit, 9),
            round(o.t_finish, 9))


def build_fleet(n_shards, cls=ShardedEngine, n_cameras=40,
                camera_block=GROUP, clocks=None, queue_depth=64):
    """Identically-constructed fleet for either engine class: camera
    groups aligned to the batching classes, per-shard deterministic
    platforms seeded by shard index."""
    groups = [[] for _ in range(n_shards)]
    for blk in range((n_cameras + camera_block - 1) // camera_block):
        cams = range(blk * camera_block,
                     min((blk + 1) * camera_block, n_cameras))
        groups[blk % n_shards].extend(cams)
    plan = FleetPlan(n_shards=n_shards,
                     camera_groups=tuple(tuple(g) for g in groups))
    engines = [ServingEngine(
        fleet_uniform_pool(256, 256, TABLE, classify=classify),
        SimExecutor(det_platform(seed=s)),
        clock=clocks[s] if clocks else None)
        for s in range(n_shards)]
    if cls is ParallelShardedEngine:
        return cls(engines, plan.shard_of, plan=plan,
                   queue_depth=queue_depth)
    return cls(engines, plan.shard_of, plan=plan)


def stats_rows(engine):
    """shard_stats minus the wall-measured utilization column."""
    return [{k: v for k, v in row.items() if k != "utilization"}
            for row in engine.shard_stats()]


# ------------------------------------------- transcript equivalence ----


@pytest.mark.parametrize("seed,n_shards", [(7, 4), (11, 4), (3, 8)])
def test_parallel_transcript_identical_to_sequential(seed, n_shards):
    arrivals = fleet_arrivals(seed=seed, burst_prob=0.3, burst_factor=4.0)
    seq = build_fleet(n_shards)
    seq.run(arrivals)
    par = build_fleet(n_shards, cls=ParallelShardedEngine)
    par.run(arrivals)
    assert list(map(outcome_key, par.outcomes)) \
        == list(map(outcome_key, seq.outcomes))
    assert sum(o.violated for o in par.outcomes) \
        == sum(o.violated for o in seq.outcomes)
    assert stats_rows(par) == stats_rows(seq)
    assert {inv.shard for inv in par.invocations} \
        == {inv.shard for inv in seq.invocations}


def test_parallel_transcript_identical_under_barrier_clock():
    # the acceptance-criteria configuration: both arms drive
    # barrier-synced virtual members; the threaded arm rendezvouses in
    # the runners' sync(), the sequential arm through finish()'s align()
    n_shards = 4
    arrivals = fleet_arrivals(seed=13)
    seq = build_fleet(n_shards,
                      clocks=BarrierVirtualClock(n_shards).members)
    seq.run(arrivals)
    par_bar = BarrierVirtualClock(n_shards, timeout_s=30.0)
    par = build_fleet(n_shards, cls=ParallelShardedEngine,
                      clocks=par_bar.members)
    par.run(arrivals)
    assert list(map(outcome_key, par.outcomes)) \
        == list(map(outcome_key, seq.outcomes))
    assert stats_rows(par) == stats_rows(seq)
    # the post-barrier drain is deterministic: each shard ends at the
    # same engine time on both arms (the final drain past the barrier
    # advances each member independently)
    seq_times = [eng.clock.now() for eng in seq.shards]
    par_times = [eng.clock.now() for eng in par.shards]
    assert seq_times == par_times


def test_parallel_small_queue_depth_backpressures_not_deadlocks():
    arrivals = fleet_arrivals(n_cameras=16, duration_s=2.0)
    seq = build_fleet(2, n_cameras=16)
    seq.run(arrivals)
    par = build_fleet(2, cls=ParallelShardedEngine, n_cameras=16,
                      queue_depth=1)
    par.run(arrivals)
    assert list(map(outcome_key, par.outcomes)) \
        == list(map(outcome_key, seq.outcomes))


def test_parallel_offer_path_and_empty_finish():
    arrivals = fleet_arrivals(n_cameras=8, duration_s=1.0)
    seq = build_fleet(2, n_cameras=8)
    for a in arrivals:
        seq.offer(a)
    seq.finish()
    par = build_fleet(2, cls=ParallelShardedEngine, n_cameras=8)
    for a in arrivals:
        par.offer(a)
    par.finish()
    assert list(map(outcome_key, par.outcomes)) \
        == list(map(outcome_key, seq.outcomes))
    # finish with no offers (runners never started) must not hang
    empty = build_fleet(2, cls=ParallelShardedEngine, n_cameras=8)
    empty.finish()
    assert empty.outcomes == []


def test_parallel_shard_error_propagates_at_finish():
    class Boom(Exception):
        pass

    class BoomExecutor:
        def submit(self, inv):
            raise Boom("shard executor died")

        def resolve(self, handle):           # pragma: no cover
            raise AssertionError

    plan = FleetPlan(n_shards=2, camera_groups=((0,), (1,)))
    engines = [ServingEngine(
        fleet_uniform_pool(256, 256, TABLE, classify=classify),
        BoomExecutor()) for _ in range(2)]
    par = ParallelShardedEngine(engines, plan.shard_of, plan=plan)
    arrivals = fleet_arrivals(n_cameras=2, duration_s=1.0)
    with pytest.raises(Boom):
        par.run(arrivals)


# -------------------------------------------------------- clocks ----


def test_barrier_clock_align_lifts_all_members():
    bar = BarrierVirtualClock(3, t0=1.0)
    bar.members[0].advance_to(2.0)
    bar.members[2].advance_to(7.0)
    bar.align()
    assert [m.now() for m in bar.members] == [7.0, 7.0, 7.0]
    # monotone: align never rewinds a member
    bar.members[1].advance_to(9.0)
    bar.align()
    assert [m.now() for m in bar.members] == [9.0, 9.0, 9.0]


def test_barrier_clock_threaded_sync_rendezvous():
    bar = BarrierVirtualClock(4, timeout_s=30.0)
    times = [1.0, 4.0, 2.5, 3.0]
    seen = []

    def worker(i):
        m = bar.members[i]
        m.advance_to(times[i])
        m.sync()
        seen.append(m.now())

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert seen == [4.0] * 4


def test_barrier_clock_sync_times_out_loudly():
    bar = BarrierVirtualClock(2, timeout_s=0.05)
    with pytest.raises(RuntimeError, match="timed out"):
        bar.members[0].sync()                 # peer never arrives


def test_wall_clock_shard_view_shares_timeline():
    base = WallClock(speed=50.0)
    a, b = base.shard_view(), base.shard_view()
    assert a.speed == base.speed and a._epoch == base._epoch
    t0 = a.now()
    a.advance_to(t0 + 0.5)
    # b reads the same timeline (its own floor, no cross-thread write)
    assert b.now() >= t0
    assert b._floor != a._floor


# ----------------------------------------------- shared-state safety ----


def test_cost_meter_concurrent_billing_exact_to_the_cent():
    meter = CostMeter()
    n_threads, n_charges, t_f = 8, 400, 0.125
    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)        # force aggressive interleaving
    try:
        threads = [threading.Thread(
            target=lambda: [meter.charge(t_f) for _ in range(n_charges)])
            for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sys.setswitchinterval(old)
    total_charges = n_threads * n_charges
    assert meter.invocations == total_charges
    assert meter.busy_seconds == pytest.approx(total_charges * t_f)
    sequential = CostMeter()
    for _ in range(total_charges):
        sequential.charge(t_f)
    assert round(meter.total, 2) == round(sequential.total, 2)
    assert meter.total == pytest.approx(
        total_charges * alibaba_cost(t_f), rel=1e-12)


def test_frame_store_concurrent_release_drains_exactly():
    store = FrameStore()
    n_frames, refs_per_frame, n_threads = 64, 8, 8
    for f in range(n_frames):
        store.add(f, np.zeros(4), refs_per_frame)
    assert len(store) == n_frames

    def release_all(offset):
        for f in range(n_frames):
            store.release((f + offset) % n_frames)

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)
    try:
        threads = [threading.Thread(target=release_all, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sys.setswitchinterval(old)
    # 8 threads x 1 release each per frame == exactly the 8 refs
    assert len(store) == 0 and store.refs_snapshot() == {}
    assert store.get(0) is None and 0 not in store


def test_online_latency_table_concurrent_observe_keeps_invariants():
    online = OnlineLatencyTable(TABLE, alpha=0.25)
    rng = np.random.default_rng(0)
    samples = [(int(b), float(e)) for b, e in
               zip(rng.integers(1, 9, 400), rng.uniform(0.01, 0.4, 400))]

    def observer(worker):
        for b, e in samples:
            online.observe(b, e, worker=worker)
            online.mu_sigma(b)

    threads = [threading.Thread(target=observer, args=(w,))
               for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert online.n_observations == 4 * len(samples)
    for b in (1, 2, 4, 8):
        mu, sigma = online.mu_sigma(b)
        assert np.isfinite(mu) and mu > 0 and sigma >= 0


# --------------------------------------------------- scheduler wiring ----


def test_scheduler_parallel_matches_sequential_results():
    def serve(parallel):
        cfg = ServeConfig(classify="slo", shards=2, planner="cost",
                          n_workers=4, source="fleet", parallel=parallel)
        sched = TangramScheduler(256, 256, TABLE,
                                 Platform(TABLE, PlatformConfig(
                                     max_instances=24, pre_warm=12)),
                                 config=cfg)
        src = make_source("fleet", n_cameras=16, duration_s=2.0, seed=2)
        return sched.serve_source(src, name="fleet-par")

    seq, par = serve(False), serve(True)
    assert par.n_patches == seq.n_patches > 0
    assert sorted(map(outcome_key, par.outcomes)) \
        == sorted(map(outcome_key, seq.outcomes))
    assert par.total_cost == pytest.approx(seq.total_cost)
    rows_s = [{k: v for k, v in r.items() if k != "utilization"}
              for r in seq.summary()["per_shard"]]
    rows_p = [{k: v for k, v in r.items() if k != "utilization"}
              for r in par.summary()["per_shard"]]
    assert rows_p == rows_s


def test_serve_config_parallel_validation_and_roundtrip():
    import json
    with pytest.raises(ValueError, match="parallel"):
        ServeConfig(parallel=True)
    cfg = ServeConfig(shards=4, parallel=True)
    assert ServeConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg


def test_shard_runner_counts_and_stop():
    eng = ServingEngine(
        fleet_uniform_pool(256, 256, TABLE, classify=classify),
        SimExecutor(det_platform()))
    runner = ShardRunner(0, eng, queue_depth=8)
    runner.start()
    arrivals = fleet_arrivals(n_cameras=4, duration_s=1.0)
    runner.submit(arrivals)
    runner.stop()
    runner.join(timeout=30.0)
    assert runner.error is None
    assert runner.submitted == runner.consumed == len(arrivals)
    assert runner.pending() == 0
    assert len(eng.outcomes) == len(arrivals)
