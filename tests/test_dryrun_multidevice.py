"""Multi-device dry-run coverage via subprocess (own XLA_FLAGS world).

Runs launch/dryrun.py on the small test meshes (8 fake host devices) for a
representative arch of each family, both single- and multi-pod.  The full
production meshes are exercised by the real dry-run (EXPERIMENTS.md).
"""
import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mesh", "test",
         "--quick", *args],
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.parametrize("arch,shape", [
    ("vit-s16", "serve_b128"),
    ("dit-s2", "gen_fast"),
])
def test_single_pod_test_mesh(arch, shape):
    r = run_dryrun("--arch", arch, "--shape", shape)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failed" in r.stdout


def test_multi_pod_test_mesh_with_json():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "r.json")
        r = run_dryrun("--arch", "vit-s16", "--shape", "serve_b128",
                       "--multi-pod", "--json", out)
        assert r.returncode == 0, r.stdout + r.stderr
        data = json.load(open(out))
        assert len(data["results"]) == 1
        assert data["failures"] == []
        row = data["results"][0]
        assert row["mesh"].startswith("2x")
        assert row["flops_per_device"] > 0


def test_lm_decode_on_test_mesh():
    r = run_dryrun("--arch", "minitron-4b", "--shape", "decode_32k")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failed" in r.stdout


def test_serve_pipeline_data_parallel_end_to_end():
    """The full serving pipeline on 8 fake devices: stitch -> detector
    under the data-parallel NamedSharding layout -> unstitch -> route.
    The routed-detection count must match the 1-device run of the same
    scene (sharding must not change results)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # slo far beyond the wall time: batching is then driven only by the
    # memory bound + the final flush, never by wall-clock timers, so both
    # runs see identical invocations even on a loaded CI runner
    argv = [sys.executable, "-m", "repro.launch.serve",
            "--frames", "16", "--canvas", "128", "--slo", "120"]

    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r8 = subprocess.run(argv, capture_output=True, text=True, env=env,
                        timeout=900)
    assert r8.returncode == 0, r8.stdout + r8.stderr
    assert "serve mesh: 1 worker(s) x data=8" in r8.stdout
    served8 = [l for l in r8.stdout.splitlines() if l.startswith("served")]
    assert served8 and "data-parallel over data=8" in served8[0]
    # at least one invocation actually split its batch over the 8 devices
    assert "(0 data-parallel" not in served8[0]

    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    r1 = subprocess.run(argv, capture_output=True, text=True, env=env,
                        timeout=900)
    assert r1.returncode == 0, r1.stdout + r1.stderr
    served1 = [l for l in r1.stdout.splitlines() if l.startswith("served")]

    def stats(line):   # "served N patches in ... routed D detections"
        toks = line.split()
        return int(toks[1]), int(toks[toks.index("routed") + 1])
    patches8, dets8 = stats(served8[0])
    assert patches8 > 0
    assert (patches8, dets8) == stats(served1[0])


def test_serve_worker_pool_slices_mesh_end_to_end():
    """--workers 2 on 8 fake devices: make_worker_meshes must cut the
    device set into two data=4 slices, and the pooled pipeline (shared
    frame store, out-of-order harvest) must still serve every patch
    data-parallel within each slice."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--frames", "16", "--canvas", "128", "--slo", "120",
         "--workers", "2", "--placement", "least", "--online-latency"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "serve mesh: 2 worker(s) x data=4" in r.stdout
    served = [l for l in r.stdout.splitlines() if l.startswith("served")]
    assert served and "data-parallel over data=4" in served[0]
    assert "(0 data-parallel" not in served[0]
    assert "0 frames still held" in served[0]
    workers = [l for l in r.stdout.splitlines()
               if l.strip().startswith("worker ")]
    assert len(workers) == 2 and all("drift" in l for l in workers)
