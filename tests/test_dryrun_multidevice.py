"""Multi-device dry-run coverage via subprocess (own XLA_FLAGS world).

Runs launch/dryrun.py on the small test meshes (8 fake host devices) for a
representative arch of each family, both single- and multi-pod.  The full
production meshes are exercised by the real dry-run (EXPERIMENTS.md).
"""
import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mesh", "test",
         "--quick", *args],
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.parametrize("arch,shape", [
    ("vit-s16", "serve_b128"),
    ("dit-s2", "gen_fast"),
])
def test_single_pod_test_mesh(arch, shape):
    r = run_dryrun("--arch", arch, "--shape", shape)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failed" in r.stdout


def test_multi_pod_test_mesh_with_json():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "r.json")
        r = run_dryrun("--arch", "vit-s16", "--shape", "serve_b128",
                       "--multi-pod", "--json", out)
        assert r.returncode == 0, r.stdout + r.stderr
        data = json.load(open(out))
        assert len(data["results"]) == 1
        assert data["failures"] == []
        row = data["results"][0]
        assert row["mesh"].startswith("2x")
        assert row["flops_per_device"] > 0


def test_lm_decode_on_test_mesh():
    r = run_dryrun("--arch", "minitron-4b", "--shape", "decode_32k")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failed" in r.stdout
