"""Unified event-driven serving engine (core/engine.py).

Covers: timers firing at their scheduled virtual time (the old serve-loop
polled only on arrivals), the per-SLO-class InvokerPool (outcome
exactly-once + class purity + head-of-line-blocking relief), executor
equivalence (SimExecutor and DeviceExecutor produce identical
patch->invocation groupings for the same trace), the DeviceExecutor's
refcounted frame store, deterministic event ordering at timestamp ties,
the seq-keyed arrival bookkeeping (leak regression), and the pluggable
clock (wall-clock run ≡ virtual-clock run).
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clock import VirtualClock, WallClock
from repro.core.engine import (DeviceExecutor, ServingEngine, SimExecutor,
                               slo_class, uniform_pool)
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.data.video import Arrival
from repro.serverless.platform import Platform, PlatformConfig


def table(mu=0.1, sigma=0.01, n=32):
    return LatencyTable({b: (mu * b, sigma) for b in range(1, n + 1)},
                        slack_sigmas=3.0)


def patch(t_gen, slo=1.0, w=64, h=64, frame_id=0, camera_id=0):
    return Patch(0, 0, w, h, frame_id=frame_id, camera_id=camera_id,
                 t_gen=t_gen, slo=slo)


def arrivals_of(patches):
    """Arrival == generation (no uplink shaping): isolates the engine."""
    return [Arrival(p.t_gen, p, 0.0) for p in patches]


def sim_engine(latency=None, classify=None, platform_cfg=None):
    latency = latency or table()
    plat = Platform(latency, platform_cfg or PlatformConfig())
    pool = uniform_pool(256, 256, latency, classify=classify)
    return ServingEngine(pool, SimExecutor(plat), check_invariants=True)


def fake_serve_fn(params, x):
    """Detector stand-in: zero objectness (no detections), right shapes."""
    import jax.numpy as jnp
    return (jnp.zeros((x.shape[0], 2, 2)),
            jnp.zeros((x.shape[0], 2, 2, 4)))


# ------------------------------------------------------------ timer bug ----

def test_timer_fires_at_scheduled_virtual_time_not_next_arrival():
    """Regression for the serve-loop timer bug: the old launch/serve loop
    polled the invoker only when a new patch arrived, so a timer falling
    in a gap between frames fired late, inflating t_submit and the SLO
    accounting.  The engine fires it at its scheduled virtual time even
    when the next arrival is far away."""
    eng = sim_engine()
    out = eng.run(arrivals_of([patch(0.0), patch(5.0)]))
    # t_remain = 1.0 - (0.1 + 3 * 0.01) = 0.87, inside the (0, 5) gap
    first = eng.invocations[0]
    assert first.reason == "timer"
    assert first.t_submit == pytest.approx(0.87)
    assert out[0].wait == pytest.approx(0.87)
    # the straddled patch was NOT dragged to the second arrival's time
    assert out[0].t_submit < 5.0


def test_streaming_offer_matches_batch_run():
    ps = [patch(0.0), patch(0.4), patch(2.0), patch(6.0)]
    batch = sim_engine()
    batch.run(arrivals_of(ps))
    stream = sim_engine()
    for a in arrivals_of(ps):
        stream.offer(a)
    stream.finish()
    key = lambda e: [(i.t_submit, i.reason, len(i.patches))
                     for i in e.invocations]
    assert key(stream) == key(batch)


# ----------------------------------------------------- pool property test ----

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 5), st.sampled_from([0.4, 2.0]),
                          st.integers(16, 256), st.integers(16, 256)),
                min_size=1, max_size=40))
def test_pool_every_outcome_once_and_class_pure(arrivals):
    """For any interleaving of arrivals across two SLO classes: every
    patch yields exactly one PatchOutcome, t_submit >= t_arrive, and no
    invoker ever receives another class's patch."""
    patches = [patch(t, slo=s, w=w, h=h)
               for t, s, w, h in sorted(arrivals)]
    eng = sim_engine(classify=slo_class)
    out = eng.run(arrivals_of(patches))

    assert sorted(id(o.patch) for o in out) == sorted(id(p) for p in patches)
    for o in out:
        assert o.t_submit >= o.t_arrive - 1e-9
    for inv in eng.invocations:
        assert inv.patches
        assert all(slo_class(p) == inv.key for p in inv.patches)
    # completions delivered exactly once per invocation, in finish order
    assert len(eng.completions) == len(eng.invocations)
    finishes = [c.t_finish for c in eng.completions]
    assert finishes == sorted(finishes)


# ------------------------------------------- mixed-SLO head-of-line relief ----

def mixed_trace():
    """Three episodes: a burst of 6 canvas-filling loose patches, then one
    tight patch arriving while the loose queue is deep."""
    ps = []
    for k in range(3):
        t0 = 3.0 * k
        for i in range(6):
            ps.append(patch(t0 + 0.05 * i, slo=10.0, w=256, h=256))
        ps.append(patch(t0 + 1.0, slo=0.55, w=64, h=64))
    return ps


def run_mixed(classify):
    lat = table(mu=0.1, sigma=0.005)
    eng = sim_engine(latency=lat, classify=classify,
                     platform_cfg=PlatformConfig(max_instances=1))
    out = eng.run(arrivals_of(mixed_trace()))
    tight = [o for o in out if o.patch.slo < 1.0]
    assert len(tight) == 3
    return sum(o.violated for o in tight) / len(tight)


def test_invoker_pool_lowers_tight_class_violations():
    """The single shared queue head-of-line blocks the tight class: each
    tight arrival forces the deep loose queue to dispatch first (SLO
    pressure), and the tight batch then queues behind that execution on
    the concurrency-1 platform.  Per-class invokers leave the loose queue
    on its own (distant) timer, so tight batches run on an idle platform."""
    shared = run_mixed(None)
    pooled = run_mixed(slo_class)
    assert pooled < shared, (pooled, shared)


# ------------------------------------------------- executor equivalence ----

def trace_for_device(n=18, seed=3):
    rng = np.random.default_rng(seed)
    ps = []
    for i in range(n):
        t = round(float(rng.uniform(0, 4.0)), 3)
        w = int(rng.integers(8, 64))
        h = int(rng.integers(8, 64))
        ps.append(Patch(0, 0, w, h, frame_id=i // 3, t_gen=t,
                        slo=float(rng.choice([0.6, 2.0]))))
    return sorted(ps, key=lambda p: p.t_gen)


def test_sim_and_device_executors_share_invocation_boundaries():
    """Invocation boundaries depend only on arrivals and the batcher —
    the same trace groups patches identically whether invocations run on
    the platform model or on the real stitch->detect->unstitch pipeline."""
    trace = trace_for_device()
    lat = table()

    sim = ServingEngine(uniform_pool(64, 64, lat, classify=slo_class),
                        SimExecutor(Platform(lat, PlatformConfig())))
    sim.run(arrivals_of(trace))

    dev_exec = DeviceExecutor(fake_serve_fn, None, 64, 64)
    dev = ServingEngine(uniform_pool(64, 64, lat, classify=slo_class),
                        dev_exec)
    dev.run(arrivals_of(trace))

    idx = {id(p): i for i, p in enumerate(trace)}
    group = lambda e: [[idx[id(p)] for p in inv.patches]
                       for inv in e.invocations]
    assert group(sim) == group(dev)
    assert dev_exec.n_invocations == len(dev.invocations)


def test_two_model_scheduler_sim_and_device_share_boundaries():
    """Same identity property under a two-model ServeConfig: with each
    SLO class mapped to its own registry model, the sim run (per-model
    warm pools + load costs on the platform) and the device run (a
    DeviceExecutor with per-model runtimes) group patches into the same
    invocations, and every outcome carries the model its class maps
    to."""
    from repro.core.config import ServeConfig
    from repro.core.engine import ModelRuntime
    from repro.core.models import ModelSpec, register_model
    from repro.core.scheduler import TangramScheduler

    register_model(ModelSpec(name="eng-fast", canvas_m=64, canvas_n=64,
                             weight_bytes=1e9, table=table(mu=0.05, sigma=0)))
    register_model(ModelSpec(name="eng-heavy", canvas_m=64, canvas_n=64,
                             weight_bytes=4e9, table=table(mu=0.3, sigma=0)))
    cfg = ServeConfig(classify="slo",
                      model_map={"0.6": "eng-fast", "2.0": "eng-heavy"})
    trace = trace_for_device()
    lat = table()
    calls = {"eng-fast": 0, "eng-heavy": 0}

    def counting(name):
        def fn(params, x):
            calls[name] += 1
            return fake_serve_fn(params, x)
        return fn

    def run(executor=None):
        sched = TangramScheduler(64, 64, lat,
                                 Platform(lat, PlatformConfig()),
                                 config=cfg, executor=executor)
        res = sched.run([trace], bandwidth_bps=1e12)
        groups = {}
        for o in res.outcomes:
            groups.setdefault((o.model, round(o.t_submit, 9)),
                              set()).add(idx[id(o.patch)])
        return res, groups

    idx = {id(p): i for i, p in enumerate(trace)}
    sim_res, sim_groups = run()
    dev = DeviceExecutor(
        fake_serve_fn, None, 64, 64,
        models={"eng-fast": ModelRuntime(counting("eng-fast"), None, 64, 64),
                "eng-heavy": ModelRuntime(counting("eng-heavy"),
                                          None, 64, 64)})
    dev_res, dev_groups = run(executor=dev)

    assert sim_groups == dev_groups
    for res in (sim_res, dev_res):
        for o in res.outcomes:
            assert o.model == ("eng-fast" if o.patch.slo == 0.6
                               else "eng-heavy")
        assert set(res.summary()["models"]) >= {"eng-fast", "eng-heavy"}
    # the device run routed every invocation through its model's runtime
    assert calls["eng-fast"] > 0 and calls["eng-heavy"] > 0
    assert sum(calls.values()) == dev.n_invocations


# ---------------------------------------------- event ordering at ties ----

class RecordingPool:
    """Transparent pool wrapper logging poll-fires and completion
    feedback, to observe the engine's event order at timestamp ties."""

    def __init__(self, inner):
        self.inner = inner
        self.log = []

    def on_patch(self, t, p):
        return self.inner.on_patch(t, p)

    def next_timer(self):
        return self.inner.next_timer()

    def poll(self, t):
        fired = self.inner.poll(t)
        if fired is not None:
            self.log.append(("timer", t))
        return fired

    def flush(self, t):
        return self.inner.flush(t)

    def on_result(self, inv, t_finish):
        self.log.append(("completion", t_finish))


def test_completion_delivered_before_timer_at_same_instant():
    """Pinned tie rule: a completion and a timer scheduled at the same
    instant resolve completion-first, so batcher feedback from finished
    work lands before the next batch is cut."""
    lat = table(mu=1.0, sigma=0.0)
    pool = RecordingPool(uniform_pool(256, 256, lat))
    eng = ServingEngine(pool, SimExecutor(Platform(lat, PlatformConfig())))
    # patch A cannot meet its SLO -> fires "late" at t=0, exec 1.0 on the
    # pre-warmed instance -> completion at exactly t=1.0
    eng.offer(Arrival(0.0, patch(0.0, slo=0.5), 0.0))
    # patch B's timer: t_remain = (0.2 + 1.8) - 1.0 = 1.0, a dead tie
    eng.offer(Arrival(0.2, patch(0.2, slo=1.8), 0.0))
    eng.finish()
    assert pool.log[0] == ("completion", pytest.approx(1.0))
    assert pool.log[1] == ("timer", pytest.approx(1.0))
    assert [i.reason for i in eng.invocations] == ["late", "timer"]


def test_pool_timer_tie_first_registered_class_fires_first():
    """Pinned tie rule: when two class invokers share a timer instant,
    the first-registered class (insertion order = order of each class's
    first arrival) fires first."""
    eng = sim_engine(classify=lambda p: p.camera_id)
    # same SLO and size -> identical t_remain = 0.87 for both classes;
    # camera 7 registered first
    eng.run(arrivals_of([patch(0.0, camera_id=7), patch(0.0, camera_id=3)]))
    assert [inv.key for inv in eng.invocations] == [7, 3]
    assert all(inv.t_submit == pytest.approx(0.87)
               for inv in eng.invocations)
    assert all(inv.reason == "timer" for inv in eng.invocations)


# ------------------------------------------- arrival bookkeeping (leak) ----

def test_arrival_bookkeeping_slot_reused_and_evicted_on_outcome():
    """Regression for the `_arrive_at` leak: arrival entries live in
    reused slots that hold the patch alive (no id() aliasing) and are
    cleared the moment the patch's outcome is recorded — a long-lived
    engine's slot table stays sized to the peak backlog, not the trace
    length."""
    eng = sim_engine()
    eng.offer(Arrival(0.0, patch(0.0), 0.0))
    assert len(eng._slot_of) == 1 and len(eng._slot_patch) == 1
    # the next offer advances past the first patch's completion (~0.97):
    # its bookkeeping must already be gone when the new entry is added,
    # and the freed slot must be *reused* (table does not grow)
    eng.offer(Arrival(5.0, patch(5.0), 0.0))
    assert len(eng._slot_of) == 1
    assert len(eng._slot_patch) == 1, "retired slot was not reused"
    eng.finish()
    assert eng._slot_of == {}
    assert all(p is None for p in eng._slot_patch)
    assert [o.t_arrive for o in eng.outcomes] == [0.0, 5.0]


def test_outcomes_complete_over_long_streaming_run():
    eng = sim_engine()
    ps = [patch(0.3 * i) for i in range(40)]
    for a in arrivals_of(ps):
        eng.offer(a)
    eng.finish()
    assert len(eng.outcomes) == 40
    assert eng._slot_of == {}
    assert all(p is None for p in eng._slot_patch)
    arrived = {id(o.patch): o.t_arrive for o in eng.outcomes}
    assert all(arrived[id(p)] == p.t_gen for p in ps)


# -------------------------------------------------------- pluggable clock ----

def test_wall_clock_run_matches_virtual_clock_boundaries():
    """The clock only decides how the engine *waits* between events, not
    which events happen: a compressed wall-clock replay produces the
    exact invocation stream of the virtual-clock run."""
    ps = [patch(0.0), patch(0.4, slo=2.0), patch(0.9), patch(1.3, slo=2.0)]
    lat = table()

    def run(clock):
        plat = Platform(lat, PlatformConfig())
        eng = ServingEngine(uniform_pool(256, 256, lat, classify=slo_class),
                            SimExecutor(plat), clock=clock)
        eng.run(arrivals_of(ps))
        return [(i.t_submit, i.reason, [id(p) for p in i.patches])
                for i in eng.invocations]

    virtual = run(VirtualClock())
    wall = run(WallClock(speed=500.0))
    assert wall == virtual


def test_wall_clock_advance_to_sleeps_scaled():
    sleeps = []
    t = [100.0]
    clk = WallClock(speed=10.0, time_fn=lambda: t[0],
                    sleep_fn=lambda s: (sleeps.append(s),
                                        t.__setitem__(0, t[0] + s)))
    assert clk.now() == 0.0
    clk.advance_to(5.0)          # 5 engine-seconds = 0.5 wall-seconds
    assert sleeps == [pytest.approx(0.5)]
    assert clk.now() == pytest.approx(5.0)
    clk.advance_to(1.0)          # already past: no sleep
    assert len(sleeps) == 1


def test_virtual_clock_monotone_jump():
    clk = VirtualClock()
    clk.advance_to(3.0)
    clk.advance_to(1.0)
    assert clk.now() == 3.0


# --------------------------------------------------- frame store eviction ----

def test_device_frame_store_refcount_eviction():
    """Regression for the frames_store leak: a frame is evicted the moment
    every patch cut from it has been routed; the store is empty after the
    final flush.  Frames that produced no patches are never stored."""
    dev = DeviceExecutor(fake_serve_fn, None, 64, 64)
    trace = []
    for fid in range(4):
        n = [2, 3, 0, 1][fid]
        dev.add_frame(fid, np.full((64, 128, 3), fid, np.float32), n)
        for j in range(n):
            trace.append(Patch(8 * j, 0, 8 * j + 8, 16, frame_id=fid,
                               t_gen=0.2 * fid + 0.01 * j, slo=0.5))
    assert set(dev.frames) == {0, 1, 3}      # fid 2 produced no patches

    eng = ServingEngine(uniform_pool(64, 64, table()), dev)
    out = eng.run(arrivals_of(trace))
    assert len(out) == len(trace)
    assert dev.frames == {}
    assert dev._refs == {}


def test_device_frame_evicted_midway_once_fully_routed():
    """Eviction is per-frame as completions land, not one big final
    sweep: a frame whose patches all completed before a later arrival is
    already gone when that arrival is processed."""
    dev = DeviceExecutor(fake_serve_fn, None, 64, 64)
    dev.add_frame(0, np.zeros((64, 128, 3), np.float32), 1)
    dev.add_frame(1, np.zeros((64, 128, 3), np.float32), 1)
    early = Patch(0, 0, 16, 16, frame_id=0, t_gen=0.0, slo=0.3)
    late = Patch(0, 0, 16, 16, frame_id=1, t_gen=5.0, slo=0.3)

    eng = ServingEngine(uniform_pool(64, 64, table()), dev)
    eng.offer(Arrival(0.0, early, 0.0))
    eng.offer(Arrival(5.0, late, 0.0))       # advances past frame 0's life
    assert 0 not in dev.frames
    assert 1 in dev.frames
    eng.finish()
    assert dev.frames == {}
