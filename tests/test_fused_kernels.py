"""Fused device hot path: stitch->patch-embed and decode->gather kernels
vs their pure-jnp oracles, plus the end-to-end property the fusion must
preserve — routed detections identical to the unfused pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partitioning import Patch
from repro.core.stitching import build_batch_plan, stitch
from repro.kernels.stitch import ops as stitch_ops
from repro.kernels.stitch.fused_embed import (stitch_embed_pallas,
                                              unstitch_decode_pallas)
from repro.kernels.stitch.ref import (stitch_embed_reference,
                                      unstitch_decode_reference)
from repro.kernels.stitch.stitch import stitch_pallas, unstitch_pallas
from repro.models import detector as detector_lib


def _packed_plan(m, n, n_patches=9, seed=7, dtype=np.float32):
    """A packer-built plan with random patch geometry + random pixels."""
    rng = np.random.default_rng(seed)
    patches = [Patch(0, 0, int(rng.integers(8, n // 2 + 1)),
                     int(rng.integers(8, m // 2 + 1)),
                     frame_id=i % 3) for i in range(n_patches)]
    canvases = stitch(patches, m, n)
    plan = build_batch_plan(patches, canvases, m, n)
    crops = [np.asarray(rng.normal(size=(p.h, p.w, 3)), np.float32)
             for p in patches]
    slots = stitch_ops.pack_plan_host(crops, plan).astype(dtype)
    return plan, patches, jnp.asarray(slots), jnp.asarray(plan.records)


# ------------------------------------------------ stitch -> patch-embed ----

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 2e-2)])
def test_stitch_embed_matches_reference(dtype, tol):
    m = n = 128
    patch, d = 32, 48
    plan, _, slots, records = _packed_plan(m, n)
    rng = np.random.default_rng(1)
    kernel = jnp.asarray(rng.normal(size=(patch * patch * 3, d)) * 0.05,
                         dtype)
    bias = jnp.asarray(rng.normal(size=(d,)), dtype)

    ref = stitch_embed_reference(slots.astype(dtype), records, kernel, bias,
                                 m, n, patch)
    for block_rows in (1, 2, 4):
        out = stitch_embed_pallas(slots.astype(dtype), records, kernel,
                                  bias, m, n, patch, block_rows=block_rows,
                                  interpret=True)
        assert out.shape == (plan.num_canvases, (m // patch) * (n // patch),
                             d)
        assert out.dtype == dtype
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)


def test_stitch_embed_empty_plan_is_bias():
    kernel = jnp.ones((32 * 32 * 3, 16), jnp.float32)
    bias = jnp.full((16,), 2.5, jnp.float32)
    out = stitch_embed_pallas(jnp.zeros((0, 8, 8, 3), jnp.float32),
                              jnp.zeros((0, 4, 6), jnp.int32),
                              kernel, bias, 64, 64, 32, interpret=True)
    assert out.shape == (0, 4, 16)
    out = stitch_ops.stitch_embed(jnp.zeros((0, 8, 8, 3), jnp.float32),
                                  jnp.zeros((2, 0, 6), jnp.int32),
                                  kernel, bias, 64, 64, 32,
                                  impl="pallas_interpret")
    assert out.shape == (2, 4, 16)
    np.testing.assert_allclose(np.asarray(out), 2.5)


# ------------------------------------------------- decode -> slot gather ----

def test_unstitch_decode_matches_reference():
    m = n = 128
    patch = 32
    plan, _, _, records = _packed_plan(m, n)
    side = m // patch
    rng = np.random.default_rng(2)
    raw = jnp.asarray(rng.normal(size=(plan.num_canvases, side, side, 5)),
                      jnp.float32)

    ref = unstitch_decode_reference(raw, records, patch, plan.num_patches)
    out = unstitch_decode_pallas(raw, records, patch, plan.slot_capacity,
                                 interpret=True)
    # slots past num_patches are undefined in the kernel output (dummy
    # parking, as in unstitch_pallas) — compare the live slots only
    np.testing.assert_allclose(np.asarray(out[:plan.num_patches]),
                               np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_unstitch_decode_empty():
    out = unstitch_decode_pallas(jnp.zeros((1, 4, 4, 5), jnp.float32),
                                 jnp.zeros((1, 0, 6), jnp.int32), 32, 0,
                                 interpret=True)
    assert out.shape == (0, 4, 4, 5)


# ------------------------------------------------ int payload round-trip ----

@pytest.mark.parametrize("dtype,lo,hi", [(jnp.int8, -128, 128),
                                         (jnp.uint8, 0, 256)])
def test_stitch_unstitch_roundtrip_int_payloads(dtype, lo, hi):
    """Quantized pixel payloads survive stitch->unstitch bit-exactly:
    the data movement kernels are copy/gather and must not touch values."""
    m = n = 64
    rng = np.random.default_rng(4)
    patches = [Patch(0, 0, int(rng.integers(8, 33)),
                     int(rng.integers(8, 33))) for _ in range(6)]
    canvases = stitch(patches, m, n)
    plan = build_batch_plan(patches, canvases, m, n)
    crops = [np.asarray(rng.integers(lo, hi, size=(p.h, p.w, 3)),
                        np.float32) for p in patches]
    slots = jnp.asarray(stitch_ops.pack_plan_host(crops, plan), dtype)
    records = jnp.asarray(plan.records)

    batch = stitch_pallas(slots, records, m, n, interpret=True)
    assert batch.dtype == dtype
    back = unstitch_pallas(batch, records, plan.slot_capacity, plan.hmax,
                           plan.wmax, interpret=True)
    np.testing.assert_array_equal(np.asarray(back[:plan.num_patches]),
                                  np.asarray(slots[:plan.num_patches]))


# --------------------------------------------- fused == unfused, end-to-end ----

def _tiny_detector(canvas=128):
    from repro.launch.serve import build_detector
    return build_detector(canvas=canvas)


def _margin_filter(per_frame, threshold=0.5, margin=1e-3):
    """Drop detections whose score sits within ``margin`` of the
    threshold — fp reduction-order differences between the fused and
    unfused matmuls may flip those, and they carry no signal."""
    out = {}
    for fid, dets in per_frame.items():
        kept = [(s, b) for s, b in dets if abs(s - threshold) >= margin]
        if kept:
            out[fid] = kept
    return out


def test_fused_pipeline_matches_unfused_routed_detections():
    """The acceptance property: per-frame routed detections from
    stitch_embed -> forward_tokens -> unstitch_decode -> route_fused
    match stitch -> serve -> route_detections on the same plan/weights."""
    m = n = 128
    cfg, params, serve_fn, rules = _tiny_detector(m)
    plan, patches, slots, records = _packed_plan(m, n, seed=9)

    canvases = stitch_ops.stitch_canvases(slots, records, m, n)
    obj, boxes = serve_fn(params, canvases)
    unfused = stitch_ops.route_detections(plan, patches, np.asarray(obj),
                                          np.asarray(boxes))

    ek, eb = detector_lib.embed_params(cfg, params)
    tokens = stitch_ops.stitch_embed(slots, records, ek, eb, m, n,
                                     cfg.patch, impl="pallas_interpret")
    raw = detector_lib.forward_tokens(cfg, params, tokens, rules)
    fused_grids = stitch_ops.unstitch_decode(raw, records, cfg.patch,
                                             plan.slot_capacity,
                                             impl="pallas_interpret")
    fused = stitch_ops.route_fused(plan, patches, np.asarray(fused_grids))

    unfused = _margin_filter(unfused)
    fused = _margin_filter(fused)
    assert set(fused) == set(unfused)
    for fid in unfused:
        assert len(fused[fid]) == len(unfused[fid]), fid
        for (fs, fb), (us, ub) in zip(fused[fid], unfused[fid]):
            assert fs == pytest.approx(us, abs=1e-4)
            assert fb == pytest.approx(ub, abs=1e-3)


def test_device_executor_fused_matches_unfused():
    """DeviceExecutor(fuse=True) completes with the same routed
    detections and evidence pixels as the unfused executor."""
    from repro.core.engine import DeviceExecutor
    from repro.core.invoker import Invocation

    m = n = 128
    cfg, params, serve_fn, rules = _tiny_detector(m)
    ek, eb = detector_lib.embed_params(cfg, params)
    tok = jax.jit(lambda p, t: detector_lib.forward_tokens(cfg, p, t, rules))

    rng = np.random.default_rng(5)
    frames = {fid: np.asarray(rng.normal(size=(m, 2 * n, 3)), np.float32)
              for fid in (0, 1)}
    patches = [Patch(10, 10, 74, 74, frame_id=0),
               Patch(80, 20, 120, 60, frame_id=0),
               Patch(0, 0, 48, 48, frame_id=1),
               Patch(128, 64, 192, 128, frame_id=1)]
    canvases = stitch(patches, m, n)

    def run(**kw):
        ex = DeviceExecutor(serve_fn, params, m, n, clock=lambda: 0.0, **kw)
        for fid, px in frames.items():
            ex.add_frame(fid, px,
                         sum(1 for p in patches if p.frame_id == fid))
        inv = Invocation(0.0, list(canvases), list(patches), 0.0, "timer")
        comp = ex.resolve(ex.submit(inv))
        return ex, comp

    ex_u, comp_u = run()
    ex_f, comp_f = run(fuse=True, tokens_fn=tok, embed_kernel=ek,
                       embed_bias=eb, patch=cfg.patch)
    assert ex_u.n_fused == 0 and ex_f.n_fused == 1

    dets_u, pix_u = comp_u.outputs
    dets_f, pix_f = comp_f.outputs
    dets_u, dets_f = _margin_filter(dets_u), _margin_filter(dets_f)
    assert set(dets_f) == set(dets_u)
    for fid in dets_u:
        assert len(dets_f[fid]) == len(dets_u[fid])
        for (fs, fb), (us, ub) in zip(dets_f[fid], dets_u[fid]):
            assert fs == pytest.approx(us, abs=1e-4)
            assert fb == pytest.approx(ub, abs=1e-3)
    # fused evidence is served from the packed slots; it must equal the
    # unfused gather output (the input crops) exactly
    assert set(pix_f) == set(pix_u)
    for fid in pix_u:
        for a, b in zip(pix_f[fid], pix_u[fid]):
            np.testing.assert_allclose(a, b, atol=1e-6)


def test_route_fused_matches_route_detections_on_random_heads():
    """route_fused over decode+gather reference grids reproduces
    route_detections over the full-canvas decode of the same raw head."""
    m = n = 128
    patch = 32
    plan, patches, _, records = _packed_plan(m, n, seed=13)
    side = m // patch
    rng = np.random.default_rng(6)
    raw = jnp.asarray(rng.normal(size=(plan.num_canvases, side, side, 5)),
                      jnp.float32)

    from repro.config import DetectorConfig
    cfg = DetectorConfig(name="route-ref", canvas=m, patch=patch,
                         n_layers=1, d_model=16, n_heads=2, d_ff=32)
    obj, boxes = detector_lib.decode_boxes(cfg, raw)
    ref = stitch_ops.route_detections(plan, patches, np.asarray(obj),
                                      np.asarray(boxes))
    grids = unstitch_decode_reference(raw, records, patch, plan.num_patches)
    got = stitch_ops.route_fused(plan, patches, np.asarray(grids))

    ref, got = _margin_filter(ref), _margin_filter(got)
    assert set(got) == set(ref)
    for fid in ref:
        assert len(got[fid]) == len(ref[fid])
        for (gs, gb), (rs, rb) in zip(got[fid], ref[fid]):
            assert gs == pytest.approx(rs, abs=1e-5)
            assert gb == pytest.approx(rb, abs=1e-4)
