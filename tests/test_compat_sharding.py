"""Version-gated sharding compat layer (repro.compat.shardingx).

These tests exercise both sides of the gate regardless of the installed
jax: the native side runs as-is, the fallback sides are forced by
monkeypatching the feature flags.
"""
import os

import jax
import jax.numpy as jnp

from repro.compat import shardingx
from repro.launch.mesh import (make_serve_mesh, make_test_mesh,
                               make_unit_mesh, mesh_chips)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestFeatureDetection:
    def test_flags_are_consistent(self):
        # axis_types on make_mesh implies make_mesh itself exists
        assert not (shardingx.MAKE_MESH_HAS_AXIS_TYPES
                    and not shardingx.HAS_MAKE_MESH)
        # AxisType implies make_mesh grew the axis_types kwarg (they
        # shipped together)
        if shardingx.HAS_AXIS_TYPE and shardingx.HAS_MAKE_MESH:
            assert shardingx.MAKE_MESH_HAS_AXIS_TYPES

    def test_auto_axis_types_matches_gate(self):
        types = shardingx.auto_axis_types(3)
        if shardingx.HAS_AXIS_TYPE:
            assert len(types) == 3
        else:
            assert types is None


class TestMakeMesh:
    def test_unit_mesh(self):
        mesh = make_unit_mesh()
        assert tuple(mesh.axis_names) == ("data", "model")
        assert mesh.devices.shape == (1, 1)
        assert mesh_chips(mesh) == 1

    def test_mesh_utils_fallback_builds_identical_mesh(self, monkeypatch):
        native = shardingx.make_mesh((1, 1), ("data", "model"))
        monkeypatch.setattr(shardingx, "MAKE_MESH_HAS_AXIS_TYPES", False)
        monkeypatch.setattr(shardingx, "HAS_MAKE_MESH", False)
        fallback = shardingx.make_mesh((1, 1), ("data", "model"))
        assert tuple(fallback.axis_names) == tuple(native.axis_names)
        assert fallback.devices.shape == native.devices.shape
        assert (fallback.devices == native.devices).all()

    def test_no_axis_types_midversion_fallback(self, monkeypatch):
        if not shardingx.HAS_MAKE_MESH:
            import pytest
            pytest.skip("this jax has no jax.make_mesh to gate off")
        monkeypatch.setattr(shardingx, "MAKE_MESH_HAS_AXIS_TYPES", False)
        mesh = shardingx.make_mesh((1, 1), ("data", "model"))
        assert tuple(mesh.axis_names) == ("data", "model")

    def test_serve_mesh_covers_local_devices(self):
        mesh = make_serve_mesh()
        assert tuple(mesh.axis_names) == ("data", "model")
        assert mesh_chips(mesh) == len(jax.devices())

    def test_serve_mesh_device_subset(self):
        mesh = make_serve_mesh(1)           # explicit count < world size OK
        assert mesh_chips(mesh) == 1

    def test_mesh_from_devices_roundtrip(self):
        mesh = make_unit_mesh()
        rebuilt = shardingx.mesh_from_devices(mesh.devices, mesh.axis_names)
        assert tuple(rebuilt.axis_names) == tuple(mesh.axis_names)
        assert rebuilt.devices.shape == mesh.devices.shape


class TestUseMesh:
    def test_jit_lowers_inside_ctx(self):
        mesh = make_unit_mesh()
        with shardingx.use_mesh(mesh):
            out = jax.jit(lambda x: x * 2)(jnp.arange(4.0))
        assert float(out.sum()) == 12.0

    def test_get_abstract_mesh_never_raises(self):
        assert shardingx.get_abstract_mesh() is None  # outside any ctx

    def test_ambient_mesh_visible_inside_ctx(self):
        """Both gate sides must report the ambient mesh inside use_mesh —
        otherwise logical sharding constraints silently no-op on one side
        and the two sides compile different programs."""
        mesh = make_unit_mesh()
        with shardingx.use_mesh(mesh):
            ambient = shardingx.get_abstract_mesh()
            assert ambient is not None
            assert shardingx.mesh_axis_sizes(ambient) == \
                {"data": 1, "model": 1}
        assert shardingx.get_abstract_mesh() is None

    def test_logical_constraint_applies_inside_ctx(self):
        from repro.sharding import DEFAULT_RULES, with_logical_constraint
        mesh = make_unit_mesh()
        with shardingx.use_mesh(mesh):
            out = jax.jit(lambda x: with_logical_constraint(
                x, ("batch", "embed"), DEFAULT_RULES))(jnp.ones((4, 8)))
        assert out.shape == (4, 8)


class TestCostAnalysisDict:
    class _Compiled:
        def __init__(self, ca):
            self._ca = ca

        def cost_analysis(self):
            return self._ca

    def test_old_jax_list_form(self):
        assert shardingx.cost_analysis_dict(
            self._Compiled([{"flops": 5.0}])) == {"flops": 5.0}
        assert shardingx.cost_analysis_dict(self._Compiled([])) == {}

    def test_new_jax_dict_form(self):
        assert shardingx.cost_analysis_dict(
            self._Compiled({"flops": 5.0})) == {"flops": 5.0}
        assert shardingx.cost_analysis_dict(self._Compiled(None)) == {}

    def test_real_compiled_artifact(self):
        ca = shardingx.cost_analysis_dict(
            jax.jit(lambda x: x @ x).lower(
                jnp.ones((8, 8), jnp.float32)).compile())
        assert isinstance(ca, dict)
        assert float(ca.get("flops", 0.0)) > 0


def test_no_axis_type_references_outside_compat():
    """The whole point of the layer: ``jax.sharding.AxisType`` must only
    ever be touched inside repro/compat/ — everything else routes through
    the factory and survives both sides of the version gate."""
    offenders = []
    for root, _, files in os.walk(SRC):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            if os.sep + "compat" + os.sep in path:
                continue
            with open(path) as f:
                if "AxisType" in f.read():
                    offenders.append(os.path.relpath(path, SRC))
    assert offenders == [], f"AxisType referenced outside compat: {offenders}"
