"""Patch-stitching solver (Alg. 2 lines 24-39): unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partitioning import Patch
from repro.core.stitching import (Canvas, FreeRect, PackState, _choose,
                                  _split, stitch,
                                  total_efficiency, validate)


def P_(w, h, **kw):
    return Patch(0, 0, w, h, **kw)


class TestChoose:
    def test_best_short_side_fit(self):
        free = [FreeRect(0, 0, 100, 100), FreeRect(0, 0, 60, 80)]
        # patch 50x50: rect2 leaves min(10, 30) = 10 < rect1 min(50,50)=50
        assert _choose(free, 50, 50) == 1

    def test_no_fit(self):
        assert _choose([FreeRect(0, 0, 10, 10)], 20, 5) is None

    def test_exact_fit_preferred(self):
        free = [FreeRect(0, 0, 100, 100), FreeRect(0, 0, 50, 50)]
        assert _choose(free, 50, 50) == 1


class TestSplit:
    def test_split_covers_residual_area(self):
        c = FreeRect(10, 20, 50, 80)
        parts = _split(c, 30, 40)
        residual = c.w * c.h - 30 * 40
        assert sum(p.w * p.h for p in parts) == residual

    def test_no_empty_rects(self):
        parts = _split(FreeRect(0, 0, 50, 50), 50, 50)
        assert parts == []

    def test_shorter_axis_rule(self):
        # wide rect (w > h): vertical cut -> right part is full height
        parts = _split(FreeRect(0, 0, 100, 50), 30, 20)
        right = [p for p in parts if p.x == 30]
        assert right and right[0].h == 50
        # tall rect: horizontal cut -> top part is full width
        parts = _split(FreeRect(0, 0, 50, 100), 30, 20)
        top = [p for p in parts if p.y == 20]
        assert top and top[0].w == 50


class TestStitch:
    def test_single_patch_bottom_left(self):
        cs = stitch([P_(100, 50)], 256, 256)
        assert len(cs) == 1
        p = cs[0].placements[0]
        assert (p.x, p.y) == (0, 0)

    def test_opens_new_canvas_when_full(self):
        cs = stitch([P_(256, 256), P_(256, 256)], 256, 256)
        assert len(cs) == 2

    def test_packs_four_quadrants(self):
        cs = stitch([P_(128, 128)] * 4, 256, 256)
        assert len(cs) == 1
        assert cs[0].efficiency == 1.0

    def test_oversized_patch_raises(self):
        with pytest.raises(ValueError):
            stitch([P_(300, 10)], 256, 256)

    def test_no_resize_no_padding(self):
        """Placements keep exact patch dims (the paper's core property)."""
        patches = [P_(37, 91), P_(200, 13), P_(64, 64)]
        cs = stitch(patches, 256, 256)
        placed = {pl.patch_idx: pl for c in cs for pl in c.placements}
        for i, p in enumerate(patches):
            assert (placed[i].w, placed[i].h) == (p.w, p.h)

    def test_deterministic(self):
        patches = [P_(60, 60), P_(100, 40), P_(40, 100), P_(120, 120)]
        a = stitch(patches, 256, 256)
        b = stitch(patches, 256, 256)
        assert [(p.x, p.y) for c in a for p in c.placements] == \
            [(p.x, p.y) for c in b for p in c.placements]


@st.composite
def patch_lists(draw):
    n = draw(st.integers(1, 40))
    return [P_(draw(st.integers(1, 256)), draw(st.integers(1, 256)))
            for _ in range(n)]


class TestStitchProperties:
    @settings(max_examples=60, deadline=None)
    @given(patch_lists())
    def test_invariants(self, patches):
        cs = stitch(patches, 256, 256)
        validate(cs)  # in-bounds + pairwise non-overlap
        # every patch placed exactly once
        placed = sorted(pl.patch_idx for c in cs for pl in c.placements)
        assert placed == list(range(len(patches)))

    @settings(max_examples=60, deadline=None)
    @given(patch_lists())
    def test_area_conservation(self, patches):
        cs = stitch(patches, 256, 256)
        assert sum(c.used_area for c in cs) == sum(p.area for p in patches)

    @settings(max_examples=30, deadline=None)
    @given(patch_lists())
    def test_canvas_count_lower_bound(self, patches):
        cs = stitch(patches, 256, 256)
        min_canvases = -(-sum(p.area for p in patches) // (256 * 256))
        assert len(cs) >= min_canvases

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 16))
    def test_identical_quarters_fill_fully(self, n):
        cs = stitch([P_(128, 128)] * (4 * n), 256, 256)
        assert len(cs) == n
        assert total_efficiency(cs) == 1.0

    @settings(max_examples=60, deadline=None)
    @given(patch_lists())
    def test_incremental_append_equals_from_scratch(self, patches):
        """PackState appends one patch per arrival into the live free-rect
        state; the result must be the same packing (same canvas count,
        same placements, valid) as restitching the whole queue from
        scratch — the invariant the invoker's O(1) restitch rests on."""
        state = PackState(256, 256)
        for i, p in enumerate(patches):
            fits = state.fits(p.w, p.h)
            before = len(state.canvases)
            state.append(p)
            # the read-only probe predicts the canvas-count change
            assert len(state.canvases) == before + (0 if fits else 1)
            scratch = stitch(patches[: i + 1], 256, 256)
            assert len(state.canvases) == len(scratch)
            assert [(pl.patch_idx, pl.canvas_idx, pl.x, pl.y, pl.w, pl.h)
                    for c in state.canvases for pl in c.placements] == \
                [(pl.patch_idx, pl.canvas_idx, pl.x, pl.y, pl.w, pl.h)
                 for c in scratch for pl in c.placements]
        validate(state.canvases)
