"""End-to-end behaviour tests for the Tangram system.

The full loop: synthetic scene -> GMM -> RoIs -> Algorithm 1 -> bandwidth-
shaped arrivals -> Algorithm 2 (stitch + SLO-aware invoker) -> serverless
platform -> per-patch SLO accounting — plus the real-model serving driver
(stitch kernel in interpret mode + jit'd detector).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmm, partitioning, rois
from repro.core.latency import detector_latency_model
from repro.core.scheduler import ServeConfig, TangramScheduler
from repro.data.synthetic import Scene, preset
from repro.serverless.platform import Platform, PlatformConfig


def build_patch_streams(n_frames=25, slo=1.0):
    scene = Scene(preset(0, width=320, height=160))
    state = gmm.init_state(160, 320)
    stream = []
    for t, frame, gt in scene.frames(n_frames):
        state, fg = gmm.update_jit(state, jnp.asarray(frame))
        if t < 1.0:
            continue
        boxes, valid = rois.extract_rois_jit(jnp.asarray(fg))
        b = np.asarray(boxes)[np.asarray(valid)]
        stream.extend(partitioning.partition_host(
            b, 320, 160, 4, 4, frame_id=scene.t, t_gen=t, slo=slo))
    return [stream]


def test_full_pipeline_meets_slo_budget():
    streams = build_patch_streams()
    assert sum(len(s) for s in streams) > 10
    model = detector_latency_model(256, 256)
    table = model.build_table(16)
    plat = Platform(table, PlatformConfig())
    sched = TangramScheduler(256, 256, table, plat,
                             config=ServeConfig(check_invariants=True))
    res = sched.run(streams, bandwidth_bps=20e6)
    assert res.n_patches == sum(len(s) for s in streams)
    assert res.violation_rate <= 0.05          # the paper's headline claim
    assert res.invocations >= 1
    assert res.total_cost > 0


def test_serve_driver_with_real_model_and_pallas_stitch():
    """launch/serve.py: real jit'd detector + Pallas stitch (interpret)."""
    from repro.launch import serve
    serve.main(["--frames", "16", "--canvas", "128", "--slo", "5.0",
                "--use-pallas-stitch"])


def test_serve_driver_async_wall_clock_smoke():
    """launch/serve.py --async-device on a compressed wall clock: the
    full driver path through AsyncDeviceExecutor + WallClock."""
    from repro.launch import serve
    serve.main(["--frames", "10", "--canvas", "128", "--slo", "5.0",
                "--async-device", "--max-inflight", "2",
                "--clock", "wall", "--wall-speed", "50"])


def test_serve_driver_worker_pool_online_latency_smoke():
    """launch/serve.py --workers/--placement/--online-latency: the full
    driver path through make_worker_meshes -> WorkerPoolExecutor (shared
    frame store) with the online estimator fed back into the invoker."""
    from repro.launch import serve
    serve.main(["--frames", "10", "--canvas", "128", "--slo", "5.0",
                "--workers", "2", "--placement", "least",
                "--online-latency"])


def test_serve_driver_live_synthetic_virtual_clock():
    """launch/serve.py --source synthetic: live edge ingestion (GMM ->
    RoIs -> Alg. 1 during serving) against the real jit'd detector, with
    the ingestion window + degrade policy active, on the virtual clock."""
    from repro.launch import serve
    serve.main(["--frames", "12", "--canvas", "128", "--slo", "5.0",
                "--source", "synthetic", "--ingestion-window", "64",
                "--overload", "degrade"])


def test_serve_driver_live_synthetic_wall_clock():
    """The same live path on a compressed wall clock with the async
    executor: arrivals are produced in real (scaled) time while device
    work overlaps — the end-to-end live serving configuration."""
    from repro.launch import serve
    serve.main(["--frames", "10", "--canvas", "128", "--slo", "5.0",
                "--source", "synthetic", "--async-device",
                "--max-inflight", "2", "--clock", "wall",
                "--wall-speed", "50", "--ingestion-window", "64"])


def test_serve_driver_live_file_source(tmp_path):
    """launch/serve.py --source file: a recorded frame stack through the
    live edge pipeline."""
    from repro.data.synthetic import Scene, preset
    from repro.launch import serve
    sc = Scene(preset(0, width=256, height=128))
    frames = []
    for _ in range(10):
        sc.step()
        frames.append(sc.render())
    np.save(tmp_path / "clip.npy", np.stack(frames))
    serve.main(["--frames", "10", "--canvas", "128", "--slo", "5.0",
                "--source", "file", "--frames-path",
                str(tmp_path / "clip.npy")])


def test_train_driver_reduced_detector():
    from repro.launch import train
    train.main(["--arch", "tangram-detector", "--steps", "3", "--batch", "2"])
