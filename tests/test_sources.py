"""The source layer: trace replay identity, live ingestion, backpressure.

The load-bearing guarantees:

* ``TraceSource`` replay is *event-for-event identical* to the
  historical ``shape_arrivals`` + ``merge_arrivals`` path — this is what
  keeps every benchmark number unchanged under the source API;
* a ``SyntheticCameraSource`` under sustained overload keeps the
  engine's backlog bounded by dropping frames (or degrading RoI
  quality), with the accounting surfaced in ``Results.summary()``;
* sources are built by registry name (``make_source``), and multi-camera
  merges preserve arrival order.
"""
import numpy as np
import pytest

from repro.core.config import ServeConfig
from repro.core.engine import ServingEngine, SimExecutor, uniform_pool
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.data.video import Arrival, merge_arrivals, shape_arrivals
from repro.serverless.platform import Platform, PlatformConfig
from repro.sources import (MergedSource, RateProfile, SourceStats,
                           SyntheticCameraSource, TraceSource, make_source)

TABLE = LatencyTable({1: (0.05, 0.0), 2: (0.08, 0.0), 4: (0.12, 0.0)})
# slow platform for overload runs: service times far above the frame
# interval, so a fast camera overloads it structurally
SLOW = LatencyTable({1: (0.5, 0.0), 2: (0.8, 0.0), 4: (1.2, 0.0)})


def patch_streams(n_cams=2, n=25):
    rng = np.random.default_rng(0)
    return [[Patch(0, 0, int(rng.integers(16, 96)), int(rng.integers(16, 96)),
                   frame_id=i, camera_id=cam, t_gen=i * 0.1, slo=1.0)
             for i in range(n)] for cam in range(n_cams)]


def outcome_key(outcomes):
    return [(o.patch.camera_id, o.patch.frame_id, o.t_arrive, o.t_submit,
             o.t_finish) for o in outcomes]


# ------------------------------------------------------------ trace source ----

def test_trace_source_arrivals_identical_to_batch_path():
    streams = patch_streams()
    batch = merge_arrivals([shape_arrivals(s, 20e6) for s in streams])
    src = TraceSource(streams=streams, bandwidth_bps=20e6)
    assert [(a.t_arrive, id(a.patch), a.n_bytes) for a in src.arrivals] \
        == [(a.t_arrive, id(a.patch), a.n_bytes) for a in batch]


def test_engine_serve_trace_identical_to_run():
    """engine.serve(TraceSource) == engine.run(arrivals): same outcomes,
    same invocation boundaries — the boundary-identity pin."""
    streams = patch_streams()
    arrivals = merge_arrivals([shape_arrivals(s, 20e6) for s in streams])

    e1 = ServingEngine(uniform_pool(128, 128, TABLE, max_canvases=4),
                       SimExecutor(Platform(TABLE)))
    e1.run(arrivals)
    e2 = ServingEngine(uniform_pool(128, 128, TABLE, max_canvases=4),
                       SimExecutor(Platform(TABLE)))
    e2.serve(TraceSource(streams=streams, bandwidth_bps=20e6))

    assert outcome_key(e1.outcomes) == outcome_key(e2.outcomes)
    assert [len(i.patches) for i in e1.invocations] \
        == [len(i.patches) for i in e2.invocations]


def test_trace_source_stats_match_uplink_accounting():
    streams = patch_streams()
    src = TraceSource(streams=streams, bandwidth_bps=20e6)
    stats = src.stats()
    assert stats.kind == "trace"
    assert stats.arrivals == sum(len(s) for s in streams)
    assert stats.bytes_sent == pytest.approx(
        sum(a.n_bytes for a in src.arrivals))
    assert stats.transmission_seconds > 0
    assert stats.frames_dropped == stats.frames_degraded == 0


def test_trace_source_argument_validation():
    with pytest.raises(ValueError):
        TraceSource()
    with pytest.raises(ValueError):
        TraceSource(streams=[[]], bandwidth_bps=1e6,
                    arrivals=[])
    with pytest.raises(ValueError):
        TraceSource(streams=[[]])   # bandwidth required


# ---------------------------------------------------------------- registry ----

def test_make_source_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown source"):
        make_source("nope")


def test_make_source_builds_each_kind(tmp_path):
    assert isinstance(make_source("trace", arrivals=[]), TraceSource)
    assert isinstance(make_source("synthetic", n_frames=2, canvas=64),
                      SyntheticCameraSource)
    assert isinstance(make_source("synthetic", n_cameras=2, n_frames=2,
                                  canvas=64), MergedSource)
    np.save(tmp_path / "f.npy", np.zeros((2, 64, 128), np.float32))
    from repro.sources import FileStreamSource
    assert isinstance(make_source("file", path=tmp_path / "f.npy",
                                  canvas=64), FileStreamSource)


# ------------------------------------------------------------ rate profile ----

def test_rate_profile_deterministic_and_modulated():
    r = RateProfile(fps=10.0, burst_prob=0.3, burst_factor=2.0,
                    diurnal_amplitude=0.5, diurnal_period_s=5.0, seed=7)
    it1 = r.intervals()
    it2 = RateProfile(fps=10.0, burst_prob=0.3, burst_factor=2.0,
                      diurnal_amplitude=0.5, diurnal_period_s=5.0,
                      seed=7).intervals()
    a = [next(it1) for _ in range(50)]
    b = [next(it2) for _ in range(50)]
    assert a == b                          # seeded: reproducible
    assert len(set(np.round(a, 9))) > 1    # actually modulated
    flat = RateProfile(fps=10.0).intervals()
    assert [next(flat) for _ in range(5)] == pytest.approx([0.1] * 5)


def test_rate_profile_validation():
    with pytest.raises(ValueError):
        RateProfile(fps=0.0)
    with pytest.raises(ValueError):
        RateProfile(diurnal_amplitude=1.0)


# ------------------------------------------------------------- live source ----

def serve_synthetic(overload, window, latency=SLOW, n_frames=30):
    src = make_source("synthetic", n_frames=n_frames, canvas=128,
                      rate=RateProfile(fps=30.0, seed=1),
                      bandwidth_bps=400e6, overload=overload, warmup_s=0.2)
    sched = TangramScheduler(
        128, 128, latency, Platform(latency, PlatformConfig()),
        config=ServeConfig(max_canvases=4, ingestion_window=window))
    res = sched.serve_source(src, name=f"overload-{overload}")
    return res, res.summary()["source"]


def test_synthetic_overload_drop_bounds_backlog():
    """10x+ sustained overload (0.5s service vs 33ms frame interval):
    the drop policy keeps the backlog at the window while a camera that
    ignores the signal lets it grow without bound."""
    window = 16
    res_none, none = serve_synthetic("none", window)
    res_drop, drop = serve_synthetic("drop", window)

    assert drop["frames_dropped"] > 0
    assert none["frames_dropped"] == none["frames_degraded"] == 0
    # bounded: a frame is only processed when backlog < window, so the
    # high water is window-1 plus one frame's patches at most — far
    # below the unthrottled backlog
    assert drop["backlog_high_water"] < none["backlog_high_water"]
    assert drop["patches_emitted"] < none["patches_emitted"]
    # every emitted patch is still served to an outcome
    assert len(res_drop.outcomes) == drop["patches_emitted"]
    assert res_drop.summary()["source"]["ingestion_window"] == window


def test_synthetic_overload_degrade_reduces_quality_then_drops():
    window = 16
    _, degrade = serve_synthetic("degrade", window)
    assert degrade["frames_degraded"] > 0
    # degrade escalates to drop at 2x the window, so the backlog stays
    # bounded even though degraded frames keep transmitting
    assert degrade["backlog_high_water"] <= 2 * window + 64


def test_synthetic_no_window_never_throttles():
    res, src = serve_synthetic("drop", window=None, latency=TABLE)
    assert src["frames_dropped"] == src["frames_degraded"] == 0
    assert src["patches_emitted"] == len(res.outcomes) > 0


def test_live_source_stats_consistent():
    _, src = serve_synthetic("drop", window=16)
    assert src["frames_total"] == 30
    assert src["arrivals"] == src["patches_emitted"]
    assert src["bytes_sent"] > 0
    assert src["transmission_seconds"] > 0


def test_live_source_rejects_bad_policy():
    with pytest.raises(ValueError, match="overload"):
        SyntheticCameraSource(n_frames=2, overload="panic")


# ------------------------------------------------------------ merged source ----

def test_merged_cameras_yield_sorted_arrivals():
    src = make_source("synthetic", n_cameras=3, n_frames=12, canvas=128,
                      rate=RateProfile(fps=20.0), bandwidth_bps=40e6,
                      warmup_s=0.2)
    arrivals = list(src.events(None))
    assert arrivals, "merged stream produced no arrivals"
    times = [a.t_arrive for a in arrivals]
    assert times == sorted(times)
    cams = {a.patch.camera_id for a in arrivals}
    assert len(cams) > 1
    # frame ids embed the camera id: no collisions across cameras
    fids = [a.patch.frame_id for a in arrivals]
    assert all((f >> 20) == a.patch.camera_id
               for f, a in zip(fids, arrivals))
    stats = src.stats()
    assert stats.kind == "merged[3]"
    assert stats.patches_emitted == len(arrivals)


def test_merged_source_requires_members():
    with pytest.raises(ValueError):
        MergedSource([])


def test_merge_order_stable_under_timestamp_ties():
    # two cameras emitting at the *same instants*: the merge key is
    # (t_arrive, camera_id, seq), so delivery order at a tie is pinned
    # to camera id — independent of member listing order (regression:
    # heapq.merge on t_arrive alone broke ties by member position)
    def stream(cam):
        out = []
        for i, t in enumerate((0.0, 0.0, 0.5, 1.0)):
            patch = Patch(0, 0, 32 + i, 32, frame_id=(cam << 20) | i,
                          camera_id=cam, t_gen=t, slo=1.0)
            out.append(Arrival(t, patch, 0.0))
        return out

    def build(order):
        members = [TraceSource(arrivals=stream(cam)) for cam in order]
        return [(a.t_arrive, a.patch.camera_id, a.patch.frame_id)
                for a in MergedSource(members).events(None)]

    forward = build([0, 1])
    backward = build([1, 0])
    assert forward == backward
    # at each shared timestamp, camera 0 precedes camera 1, and each
    # camera's own patches stay in seq order
    ties = [k for k in forward if k[0] == forward[0][0]]
    assert [c for _, c, _ in ties] == sorted(c for _, c, _ in ties)
    for cam in (0, 1):
        fids = [f for _, c, f in forward if c == cam]
        assert fids == sorted(fids)


# -------------------------------------------------------------- file source ----

def test_file_stream_source_serves_recorded_frames(tmp_path):
    from repro.data.synthetic import Scene, preset
    sc = Scene(preset(0, width=256, height=128))
    frames = []
    for _ in range(12):
        sc.step()
        frames.append(sc.render())
    np.save(tmp_path / "clip.npy", np.stack(frames))

    src = make_source("file", path=tmp_path / "clip.npy", canvas=128,
                      n_frames=24,   # longer than the clip: loops
                      rate=RateProfile(fps=20.0), bandwidth_bps=40e6,
                      warmup_s=0.2)
    sched = TangramScheduler(128, 128, TABLE, Platform(TABLE),
                             config=ServeConfig(max_canvases=4))
    res = sched.serve_source(src, name="file")
    stats = res.summary()["source"]
    assert stats["kind"] == "file"
    assert stats["frames_total"] == 24
    assert stats["patches_emitted"] == len(res.outcomes) > 0


def test_load_frames_formats(tmp_path):
    from repro.data.video import load_frames
    stack = (np.random.default_rng(0).random((3, 8, 10)) * 255) \
        .astype(np.uint8)
    np.save(tmp_path / "a.npy", stack)
    out = load_frames(tmp_path / "a.npy")
    assert out.shape == (3, 8, 10) and out.dtype == np.float32
    assert out.max() <= 1.0                     # 8-bit rescaled

    np.savez(tmp_path / "b.npz", frames=stack.astype(np.float32) / 255.0)
    assert load_frames(tmp_path / "b.npz").shape == (3, 8, 10)

    rgb = np.random.default_rng(1).random((2, 8, 10, 3)).astype(np.float32)
    np.save(tmp_path / "c.npy", rgb)
    assert load_frames(tmp_path / "c.npy").shape == (2, 8, 10)

    d = tmp_path / "frames"
    d.mkdir()
    for i in range(2):
        np.save(d / f"{i:03d}.npy", stack[0])
    assert load_frames(d).shape == (2, 8, 10)
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(ValueError):
        load_frames(empty)


# ------------------------------------------------------------- source stats ----

def test_source_stats_add_aggregates():
    a = SourceStats(kind="a", arrivals=2, bytes_sent=10.0, frames_total=3,
                    frames_dropped=1, patches_emitted=2)
    b = SourceStats(kind="b", arrivals=3, bytes_sent=5.0, frames_total=4,
                    frames_degraded=2, patches_emitted=3)
    a.add(b)
    assert (a.arrivals, a.bytes_sent, a.frames_total, a.frames_dropped,
            a.frames_degraded, a.patches_emitted) == (5, 15.0, 7, 1, 2, 5)
    assert set(a.to_dict()) == {
        "kind", "arrivals", "bytes_sent", "transmission_seconds",
        "frames_total", "frames_dropped", "frames_degraded",
        "patches_emitted"}
