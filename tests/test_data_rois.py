"""Synthetic scenes, byte models, RoI extraction (JAX vs numpy reference)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmm, rois
from repro.core.partitioning import Patch
from repro.data import video
from repro.data.synthetic import SCENE_PRESETS, Scene, preset


class TestScene:
    def test_deterministic(self):
        a, b = Scene(preset(0)), Scene(preset(0))
        for _ in range(3):
            a.step(), b.step()
        np.testing.assert_array_equal(a.render(), b.render())

    def test_roi_proportion_in_calibrated_band(self):
        """Table I: RoIs are a few percent to ~15% of the frame."""
        props = []
        for i in range(len(SCENE_PRESETS)):
            s = Scene(preset(i))
            s.step()
            props.append(s.roi_proportion())
        assert 0.01 < np.mean(props) < 0.30
        assert max(props) < 0.5

    def test_boxes_within_frame(self):
        s = Scene(preset(2))
        for _ in range(5):
            s.step()
            b = s.boxes()
            if len(b):
                assert (b[:, 0] >= 0).all() and (b[:, 2] <= s.cfg.width).all()
                assert (b[:, 1] >= 0).all() and (b[:, 3] <= s.cfg.height).all()

    def test_fluctuating_counts(self):
        """Fig. 3: object counts fluctuate irregularly."""
        s = Scene(preset(5))
        counts = []
        for _ in range(60):
            s.step()
            counts.append(len(s.boxes()))
        assert len(set(counts)) > 1


class TestBytesModel:
    def test_patch_bytes_linear_in_area(self):
        small = video.patch_bytes(Patch(0, 0, 10, 10))
        big = video.patch_bytes(Patch(0, 0, 100, 100))
        # headers aside, bytes scale with area at BPP_FG per pixel
        assert big - small == pytest.approx((10_000 - 100) * video.BPP_FG)

    def test_4k_frame_about_1mb(self):
        b = video.frame_bytes(3840, 2160)
        assert 0.7e6 < b < 1.5e6

    def test_masked_cheaper_than_full(self):
        full = video.frame_bytes(960, 540)
        masked = video.masked_frame_bytes(960, 540, fg_area=20000)
        assert masked < 0.5 * full

    def test_arrival_shaping_fifo(self):
        patches = [Patch(0, 0, 100, 100, t_gen=0.0),
                   Patch(0, 0, 100, 100, t_gen=0.0)]
        arr = video.shape_arrivals(patches, bandwidth_bps=8e5)  # 100 KB/s
        assert arr[1].t_arrive > arr[0].t_arrive
        assert arr[0].t_arrive == pytest.approx(
            video.patch_bytes(patches[0]) / 1e5)


class TestRoIExtraction:
    def test_jax_matches_numpy_reference(self):
        rng = np.random.default_rng(0)
        for trial in range(5):
            mask = np.zeros((96, 128), bool)
            for _ in range(rng.integers(1, 5)):
                y, x = rng.integers(0, 64), rng.integers(0, 96)
                mask[y:y + rng.integers(8, 30), x:x + rng.integers(8, 30)] = 1
            jb, jv = rois.extract_rois_jit(jnp.asarray(mask))
            jboxes = {tuple(b) for b in np.asarray(jb)[np.asarray(jv)]}
            nb, nv = rois.numpy_rois(mask)
            nboxes = {tuple(b) for b in nb}
            assert jboxes == nboxes, f"trial {trial}"

    def test_empty_mask(self):
        boxes, valid = rois.extract_rois_jit(jnp.zeros((64, 64), bool))
        assert not bool(valid.any())

    def test_detects_small_distant_object(self):
        """Small objects must survive the downsample (paper motivation)."""
        mask = np.zeros((128, 128), bool)
        mask[60:68, 60:68] = True                # ~8px object
        boxes, valid = rois.extract_rois_jit(jnp.asarray(mask))
        b = np.asarray(boxes)[np.asarray(valid)]
        assert len(b) == 1
        x0, y0, x1, y1 = b[0]
        assert x0 <= 60 and y0 <= 60 and x1 >= 68 and y1 >= 68


class TestGMMPipeline:
    def test_end_to_end_scene_to_patches(self):
        scene = Scene(preset(0, width=256, height=128))
        state = gmm.init_state(128, 256)
        got_patches = False
        from repro.core.partitioning import partition_host
        for t, frame, gt in scene.frames(25):
            state, fg = gmm.update_jit(state, jnp.asarray(frame))
            if t < 1.5:
                continue
            boxes, valid = rois.extract_rois_jit(jnp.asarray(fg))
            b = np.asarray(boxes)[np.asarray(valid)]
            patches = partition_host(b, 256, 128, 2, 2, t_gen=t)
            if patches:
                got_patches = True
                for p in patches:
                    assert 0 <= p.x0 < p.x1 <= 256
                    assert 0 <= p.y0 < p.y1 <= 128
        assert got_patches
