"""Property tests for the online latency estimator.

Pinned properties (PR acceptance):

* with **zero observations** an ``OnlineLatencyTable`` is *exactly* its
  seed ``LatencyTable`` — same ``mu_sigma`` and ``t_slack`` at every
  batch size, including the clamp below the smallest profiled point;
* under **adversarial observation streams** (NaN, infinities, negatives,
  zeros, denormals, astronomically large values) every served estimate
  stays finite with ``mu > 0`` and ``sigma >= 0``, and invalid
  observations are rejected without perturbing the state.

Runs under real hypothesis (CI) or the vendored shim (tests/_vendor).
"""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.latency import LatencyTable, OnlineLatencyTable


@st.composite
def seed_tables(draw):
    """Profiled tables with mu non-decreasing in batch size (a real
    device profile: bigger batches are never faster) — the regime the
    seed's linear extrapolation is meant for."""
    n_entries = draw(st.integers(min_value=1, max_value=6))
    batches = sorted(set(draw(st.lists(
        st.integers(min_value=1, max_value=16),
        min_size=n_entries, max_size=n_entries))))
    table = {}
    mu = 0.0
    for b in batches:
        mu += draw(st.floats(min_value=1e-6, max_value=5.0))
        sigma = draw(st.floats(min_value=0.0, max_value=1.0))
        table[b] = (mu, sigma)
    return LatencyTable(table, slack_sigmas=3.0)


_adversarial = st.one_of(
    st.floats(min_value=-1e9, max_value=1e9),
    st.sampled_from([float("nan"), float("inf"), float("-inf"),
                     0.0, -0.0, 1e308, 5e-324, -1.0, 1e-9]))


@given(seed_tables(), st.integers(min_value=1, max_value=32))
@settings(max_examples=60)
def test_zero_observations_is_exactly_the_seed(seed, batch):
    online = OnlineLatencyTable(seed)
    assert online.mu_sigma(batch) == seed.mu_sigma(batch)
    assert online.t_slack(batch) == seed.t_slack(batch)
    assert online.t_slack(0) == seed.t_slack(0) == 0.0
    assert online.slack_sigmas == seed.slack_sigmas
    assert online.drift() == 1.0


@given(seed_tables(),
       st.lists(st.tuples(st.integers(min_value=0, max_value=20),
                          _adversarial),
                min_size=1, max_size=40),
       st.integers(min_value=1, max_value=32))
@settings(max_examples=60)
def test_adversarial_streams_keep_estimates_finite_positive(
        seed, stream, probe_batch):
    online = OnlineLatencyTable(seed)
    for batch, elapsed in stream:
        online.observe(batch, elapsed, worker=batch % 3)
        mu, sigma = online.mu_sigma(probe_batch)
        assert math.isfinite(mu) and mu > 0.0
        assert math.isfinite(sigma) and sigma >= 0.0
        t = online.t_slack(probe_batch)
        assert math.isfinite(t) and t > 0.0
        assert math.isfinite(online.drift())
        lo, hi = online.ratio_bounds
        assert lo <= online.drift() <= hi


@given(seed_tables(),
       st.sampled_from([float("nan"), float("inf"), float("-inf"),
                        -1.0, 0.0, -0.0]))
@settings(max_examples=30)
def test_invalid_observations_are_rejected_without_state_change(
        seed, bad):
    online = OnlineLatencyTable(seed)
    online.observe(2, 0.5)
    before = (online.mu_sigma(2), online.n_observations, online.drift())
    assert online.observe(2, bad) is False
    assert online.observe(0, 0.5) is False      # empty batch
    assert (online.mu_sigma(2), online.n_observations,
            online.drift()) == before
    assert online.n_rejected >= 2


def test_ewma_converges_to_sustained_observation():
    seed = LatencyTable({1: (0.01, 0.001)})
    online = OnlineLatencyTable(seed, alpha=0.5)
    for _ in range(20):
        online.observe(1, 0.08)
    mu, sigma = online.mu_sigma(1)
    assert mu == pytest.approx(0.08, rel=1e-3)
    assert sigma >= 0.0
    # unobserved batch sizes scale by the (clamped) drift ratio
    mu4, _ = online.mu_sigma(4)
    assert mu4 == pytest.approx(seed.mu_sigma(4)[0] * online.drift(),
                                rel=1e-6)


def test_drift_ratio_is_clamped():
    seed = LatencyTable({1: (0.01, 0.0)})
    online = OnlineLatencyTable(seed, alpha=1.0, ratio_bounds=(0.5, 4.0))
    online.observe(1, 10.0)             # 1000x the profile
    assert online.drift() == 4.0
    mu4, _ = online.mu_sigma(4)
    assert mu4 == pytest.approx(seed.mu_sigma(4)[0] * 4.0)
    online.observe(1, 1e-9)             # collapse toward zero
    assert online.drift() == 0.5


def test_constructor_validation():
    seed = LatencyTable({1: (0.01, 0.0)})
    with pytest.raises(ValueError):
        OnlineLatencyTable(seed, alpha=0.0)
    with pytest.raises(ValueError):
        OnlineLatencyTable(seed, alpha=1.5)
    with pytest.raises(ValueError):
        OnlineLatencyTable(seed, ratio_bounds=(0.0, 1.0))
    with pytest.raises(ValueError):
        OnlineLatencyTable(seed, ratio_bounds=(2.0, 1.0))


def test_seed_clamp_below_smallest_profiled_point_is_preserved():
    """PR 2's fix (no extrapolation through the origin) survives the
    online wrapper: below the smallest profiled batch the seed's clamped
    value is served, scaled only by observed drift."""
    seed = LatencyTable({4: (0.4, 0.04), 8: (0.8, 0.08)})
    online = OnlineLatencyTable(seed)
    assert online.mu_sigma(1) == seed.mu_sigma(1) == (0.4, 0.04)
    online.observe(4, 0.8)              # 2x drift at batch 4
    mu1, _ = online.mu_sigma(1)
    assert mu1 == pytest.approx(0.4 * online.drift())
