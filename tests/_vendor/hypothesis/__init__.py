"""Minimal drop-in subset of `hypothesis` for environments without it.

Loaded only as a fallback: ``tests/conftest.py`` appends this directory to
``sys.path`` when the real package is not installed (see pyproject.toml's
test extra — CI installs the real thing).  Implements exactly the surface
this repo's property tests use: ``given``, ``settings``, and the
strategies in :mod:`hypothesis.strategies`.

Semantics: ``@given`` draws ``settings.max_examples`` pseudo-random
examples from a PRNG seeded by the test's qualified name, so runs are
deterministic per test.  No shrinking, no example database, no health
checks — failures report the drawn arguments and re-raise.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

from . import strategies

__all__ = ["given", "settings", "strategies", "HealthCheck", "assume"]

_DEFAULT_MAX_EXAMPLES = 50


class HealthCheck:
    """Accepted and ignored (API compatibility)."""
    all = classmethod(lambda cls: [])
    too_slow = filter_too_much = data_too_large = None


class _Unsatisfied(Exception):
    pass


def assume(condition):
    """Abort the current example when the assumption fails."""
    if not condition:
        raise _Unsatisfied()
    return True


class settings:
    """Decorator recording run options; only ``max_examples`` is honored."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def given(*given_strategies, **given_kwargs):
    """Run the test once per drawn example (deterministic per test name)."""
    if given_kwargs:
        raise NotImplementedError("shim supports positional strategies only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            opts = getattr(wrapper, "_shim_settings", None) or settings()
            seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
            rnd = random.Random(seed)
            ran = 0
            attempts = 0
            while ran < opts.max_examples:
                attempts += 1
                if attempts > opts.max_examples * 50:
                    raise RuntimeError(
                        f"{fn.__qualname__}: could not satisfy assumptions")
                drawn = [s.example_from(rnd) for s in given_strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as exc:
                    # BaseExceptions (KeyboardInterrupt, pytest.skip's
                    # Skipped, SystemExit) propagate untouched
                    raise AssertionError(
                        f"{fn.__qualname__} failed on example #{ran}: "
                        f"{drawn!r}") from exc
                ran += 1

        # hide the strategy-drawn trailing parameters from pytest's
        # fixture resolution (they are filled by the shim, not fixtures)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(given_strategies)])
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.hypothesis_shim = True
        return wrapper

    return decorate
