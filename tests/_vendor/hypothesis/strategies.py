"""Strategy subset for the vendored hypothesis shim (see __init__.py).

Each strategy is a thin wrapper over a draw function taking a
``random.Random``; ``composite`` hands the user function a ``draw``
callable bound to the current PRNG, matching real hypothesis usage.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[Any], Any]):
        self._draw_fn = draw_fn

    def example_from(self, rnd) -> Any:
        return self._draw_fn(rnd)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rnd: f(self._draw_fn(rnd)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rnd):
            for _ in range(1000):
                v = self._draw_fn(rnd)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate never satisfied")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: bool(rnd.getrandbits(1)))


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rnd: rnd.choice(elements))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rnd):
        size = rnd.randint(min_size, max_size)
        return [elements.example_from(rnd) for _ in range(size)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rnd: tuple(s.example_from(rnd) for s in strategies))


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rnd: rnd.choice(strategies).example_from(rnd))


def composite(fn: Callable[..., Any]) -> Callable[..., SearchStrategy]:
    """``@st.composite`` — fn's first argument is the ``draw`` callable."""
    def build(*args, **kwargs) -> SearchStrategy:
        def draw_example(rnd):
            def draw(strategy: SearchStrategy):
                return strategy.example_from(rnd)
            return fn(draw, *args, **kwargs)
        return SearchStrategy(draw_example)
    return build
