"""Completion-driven AIMD adaptation (core/adaptive.py).

The headline test reproduces the failure mode the controller exists for:
a sustained load step backlogs the (concurrency-1) platform, the offline
latency table can't see the queueing, and the static configuration keeps
firing tight-SLO batches too late.  The AIMD pool observes the excess on
delivered completions and fires earlier (margin) with smaller budgets
(multiplicative decrease), cutting the tight class's violation rate.
"""
import pytest

from repro.core.adaptive import (AIMDConfig, AdaptiveInvokerPool, ClassSpec,
                                 adaptive_uniform_pool, pool_from_specs)
from repro.core.engine import ServingEngine, SimExecutor, slo_class
from repro.core.invoker import Invocation
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.data.video import Arrival
from repro.serverless.platform import Platform, PlatformConfig

TIGHT, LOOSE = 0.6, 8.0
MU = 0.05


def table():
    return LatencyTable({b: (MU * b, 0.0) for b in range(1, 33)},
                        slack_sigmas=3.0)


def patch(t, slo=1.0, w=64, h=64, camera_id=0):
    return Patch(0, 0, w, h, t_gen=t, slo=slo, camera_id=camera_id)


# ------------------------------------------------------- load-step study ----

def load_step_trace():
    """Warmup trickle, then a sustained step of canvas-filling loose
    patches just under platform capacity (standing backlog, bounded),
    with tight patches riding through it."""
    ps = []
    for k in range(4):
        ps.append(patch(0.4 * k, slo=TIGHT, camera_id=1))
    for j in range(6):                       # step onset: instant backlog
        ps.append(patch(2.0 + 0.001 * j, slo=LOOSE, w=256, h=256))
    t = 2.1
    while t < 5.0:                           # sustained near-capacity load
        ps.append(patch(round(t, 3), slo=LOOSE, w=256, h=256))
        t += 0.055
    t = 2.15
    while t < 5.6:
        ps.append(patch(round(t, 3), slo=TIGHT, camera_id=1))
        t += 0.3
    return [sorted(ps, key=lambda p: p.t_gen)]


def run_load_step(adaptive):
    lat = table()
    plat = Platform(lat, PlatformConfig(max_instances=1, pre_warm=1,
                                        cold_start_s=0.0))
    sched = TangramScheduler(256, 256, lat, plat, max_canvases=8,
                             classify=slo_class, adaptive=adaptive)
    return sched.run(load_step_trace(), bandwidth_bps=400e6), sched


def test_aimd_reduces_tight_violations_under_load_step():
    """Acceptance: the completion-feedback controller beats the static
    `max_canvases` configuration on the tight class when a load step
    introduces queueing the latency table cannot see."""
    static_res, _ = run_load_step(None)
    aimd_res, aimd_sched = run_load_step(AIMDConfig())

    static_tight = static_res.class_violation_rate(slo_class, TIGHT)
    aimd_tight = aimd_res.class_violation_rate(slo_class, TIGHT)
    assert aimd_tight < static_tight, (aimd_tight, static_tight)
    # deterministic trace (sigma=0): pin the gap is substantial, not a
    # one-violation fluke
    assert static_tight >= 0.4
    assert aimd_tight <= static_tight - 0.15
    # the controller actually moved the knobs it owns
    st = aimd_sched.pool.state[TIGHT]
    assert st.violations > 0
    assert st.margin > 0.0
    # and the loose class was not sacrificed
    assert aimd_res.class_violation_rate(slo_class, LOOSE) \
        <= static_res.class_violation_rate(slo_class, LOOSE)


# ------------------------------------------------------- controller unit ----

def fake_inv(t_submit, patches, t_slack, key=None):
    return Invocation(t_submit, [], patches, t_slack, "timer", key=key)


def test_aimd_decrease_on_violation_and_margin_jump():
    pool = adaptive_uniform_pool(256, 256, table(), max_canvases=8,
                                 cfg=AIMDConfig(margin_headroom=1.0))
    p = patch(0.0, slo=1.0)
    pool.on_patch(0.0, p)                    # registers the class invoker
    invoker = pool.invokers[None]
    assert invoker.max_canvases == 8 and invoker.margin == 0.0

    # finished 0.5s past the deadline, 1.3s over the 0.2s estimate
    pool.on_result(fake_inv(0.0, [p], t_slack=0.2, key=None), t_finish=1.5)
    assert invoker.max_canvases == 4                    # 8 * 0.5
    assert invoker.margin == pytest.approx(1.3)         # observed excess
    assert pool.state[None].violations == 1


def test_aimd_additive_recovery_and_margin_decay():
    cfg = AIMDConfig(patience=2, margin_decay=0.5, margin_headroom=1.0,
                     max_canvases=6)
    pool = adaptive_uniform_pool(256, 256, table(), max_canvases=4, cfg=cfg)
    p = patch(0.0, slo=1.0)
    pool.on_patch(0.0, p)
    invoker = pool.invokers[None]
    pool.on_result(fake_inv(0.0, [p], 0.2), t_finish=1.5)   # violation
    assert invoker.max_canvases == 2
    m0 = invoker.margin
    for k in range(4):                       # 4 clean = 2 increase steps
        pool.on_result(fake_inv(2.0 + k, [patch(2.0 + k, slo=9.0)], 0.2),
                       t_finish=2.1 + k)
    assert invoker.max_canvases == 4
    assert invoker.margin == pytest.approx(m0 * 0.25)
    # ceiling respected
    for k in range(20):
        pool.on_result(fake_inv(9.0 + k, [patch(9.0 + k, slo=9.0)], 0.2),
                       t_finish=9.1 + k)
    assert invoker.max_canvases == cfg.max_canvases


def test_aimd_floor_respected():
    pool = adaptive_uniform_pool(256, 256, table(), max_canvases=2,
                                 cfg=AIMDConfig(min_canvases=1))
    p = patch(0.0, slo=0.1)
    pool.on_patch(0.0, p)
    for _ in range(5):
        pool.on_result(fake_inv(0.0, [p], 0.2), t_finish=5.0)
    assert pool.invokers[None].max_canvases == 1


# --------------------------------------------------- per-class geometry ----

def test_pool_from_specs_per_class_geometry():
    specs = {TIGHT: ClassSpec(128, 128, table(), max_canvases=2),
             LOOSE: ClassSpec(256, 512, table(), max_canvases=8)}
    pool = pool_from_specs(specs, classify=slo_class)
    pool.on_patch(0.0, patch(0.0, slo=TIGHT))
    pool.on_patch(0.0, patch(0.0, slo=LOOSE))
    assert (pool.invokers[TIGHT].m, pool.invokers[TIGHT].n) == (128, 128)
    assert pool.invokers[TIGHT].max_canvases == 2
    assert (pool.invokers[LOOSE].m, pool.invokers[LOOSE].n) == (256, 512)
    assert pool.invokers[LOOSE].max_canvases == 8


def test_pool_from_specs_default_and_missing():
    specs = {TIGHT: ClassSpec(128, 128, table())}
    pool = pool_from_specs(specs, classify=slo_class)
    with pytest.raises(ValueError, match="unknown SLO class"):
        pool.on_patch(0.0, patch(0.0, slo=LOOSE))
    pool = pool_from_specs(specs, default=ClassSpec(64, 64, table()),
                           classify=slo_class)
    pool.on_patch(0.0, patch(0.0, slo=LOOSE, w=32, h=32))
    assert (pool.invokers[LOOSE].m, pool.invokers[LOOSE].n) == (64, 64)


def test_pool_from_specs_adaptive_flag():
    specs = {TIGHT: ClassSpec(128, 128, table(), max_canvases=4)}
    pool = pool_from_specs(specs, classify=slo_class, adaptive=AIMDConfig())
    assert isinstance(pool, AdaptiveInvokerPool)
    pool.on_patch(0.0, patch(0.0, slo=TIGHT))
    assert pool.state[TIGHT].max_canvases == 4


def test_adaptive_pool_runs_on_engine_end_to_end():
    """The adaptive pool is a drop-in engine batcher: every patch still
    yields exactly one outcome."""
    lat = table()
    pool = adaptive_uniform_pool(256, 256, lat, classify=slo_class)
    eng = ServingEngine(pool, SimExecutor(Platform(lat, PlatformConfig())),
                        check_invariants=True)
    ps = [patch(0.1 * i, slo=(TIGHT if i % 3 else LOOSE)) for i in range(30)]
    out = eng.run([Arrival(p.t_gen, p, 0.0) for p in ps])
    assert len(out) == 30
