"""Overlapped device execution (AsyncDeviceExecutor) and the
submit/complete executor protocol.

Covers the PR invariant (identical patch->invocation groupings across
SimExecutor, sync DeviceExecutor, and async DeviceExecutor), detection-
routing equivalence between sync and async device modes (virtual and
compressed wall clock), bounded in-flight depth under a burst (device
stub with a real service time), and frame-store eviction when
completions are delivered asynchronously.
"""
import numpy as np
import pytest

from repro.core.clock import WallClock
from repro.core.devicestub import StubAccelerator
from repro.core.engine import (AsyncDeviceExecutor, DeviceExecutor,
                               ServingEngine, SimExecutor, slo_class,
                               uniform_pool)
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.data.video import Arrival
from repro.serverless.platform import Platform, PlatformConfig


def table(mu=0.1, sigma=0.01, n=32):
    return LatencyTable({b: (mu * b, sigma) for b in range(1, n + 1)},
                        slack_sigmas=3.0)


def arrivals_of(patches):
    return [Arrival(p.t_gen, p, 0.0) for p in patches]


def fake_serve_fn(params, x):
    """Detector stand-in: zero objectness (no detections), right shapes."""
    import jax.numpy as jnp
    return (jnp.zeros((x.shape[0], 2, 2)),
            jnp.zeros((x.shape[0], 2, 2, 4)))


def detecting_serve_fn(params, x):
    """Content-dependent stand-in: objectness = mean cell intensity over a
    4x4 grid, boxes = the cell rectangles — so routed detections depend
    on which frame's pixels landed in each placement."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def go(x):
        b, m, n, _ = x.shape
        s = 4
        obj = x.reshape(b, s, m // s, s, n // s, 3).mean(axis=(2, 4, 5))
        ys, xs = jnp.meshgrid(jnp.arange(s), jnp.arange(s), indexing="ij")
        cw, ch = n // s, m // s
        boxes = jnp.stack([xs * cw, ys * ch, (xs + 1) * cw, (ys + 1) * ch],
                          axis=-1).astype(jnp.float32)
        return obj, jnp.broadcast_to(boxes, (b, s, s, 4))

    return go(x)


def trace_for_device(n=24, seed=3):
    rng = np.random.default_rng(seed)
    ps = []
    for i in range(n):
        t = round(float(rng.uniform(0, 4.0)), 3)
        w = int(rng.integers(8, 64))
        h = int(rng.integers(8, 64))
        ps.append(Patch(0, 0, w, h, frame_id=i // 3, t_gen=t,
                        slo=float(rng.choice([0.6, 2.0]))))
    return sorted(ps, key=lambda p: p.t_gen)


# ------------------------------------------------ boundary equivalence ----

def test_identical_boundaries_across_sim_sync_and_async_executors():
    """Acceptance: the same trace yields identical invocation boundaries
    under {SimExecutor, sync DeviceExecutor, async DeviceExecutor} — the
    execution substrate and its overlap mode never leak into batching."""
    trace = trace_for_device()
    lat = table()

    def run(executor):
        eng = ServingEngine(uniform_pool(64, 64, lat, classify=slo_class),
                            executor)
        eng.run(arrivals_of(trace))
        return eng

    idx = {id(p): i for i, p in enumerate(trace)}
    group = lambda e: [[idx[id(p)] for p in inv.patches]
                       for inv in e.invocations]

    sim = run(SimExecutor(Platform(lat, PlatformConfig())))
    sync_dev = run(DeviceExecutor(fake_serve_fn, None, 64, 64))
    async_dev = run(AsyncDeviceExecutor(fake_serve_fn, None, 64, 64,
                                        max_inflight=2))
    assert group(sync_dev) == group(sim)
    assert group(async_dev) == group(sim)


# -------------------------------------------------- detection routing ----

class _Capture:
    """Mixin: stash routed detections before the engine drops outputs."""

    def on_complete(self, comp):
        per_frame, _ = comp.outputs
        for fid, dets in per_frame.items():
            self.captured.setdefault(fid, []).extend(dets)
        super().on_complete(comp)


class CaptureSync(_Capture, DeviceExecutor):
    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.captured = {}


class CaptureAsync(_Capture, AsyncDeviceExecutor):
    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.captured = {}


def frames_and_trace(n_frames=4, per_frame=3, seed=7):
    """Bright/dark patterned frames so detections are content-dependent."""
    rng = np.random.default_rng(seed)
    frames, ps = {}, []
    for fid in range(n_frames):
        px = rng.uniform(0.0, 1.0, size=(64, 128, 3)).astype(np.float32)
        px[:, : 32 * (fid % 3)] = 0.9          # varying bright band
        frames[fid] = px
        for j in range(per_frame):
            x0 = int(rng.integers(0, 64))
            y0 = int(rng.integers(0, 32))
            ps.append(Patch(x0, y0, x0 + int(rng.integers(16, 64)),
                            y0 + int(rng.integers(16, 32)), frame_id=fid,
                            t_gen=round(0.3 * fid + 0.07 * j, 3), slo=0.5))
    return frames, sorted(ps, key=lambda p: p.t_gen)


def _run_device(cls, frames, trace, clock=None, **kw):
    dev = cls(detecting_serve_fn, None, 64, 64, **kw)
    counts = {}
    for p in trace:
        counts[p.frame_id] = counts.get(p.frame_id, 0) + 1
    for fid, px in frames.items():
        dev.add_frame(fid, px, counts.get(fid, 0))
    eng = ServingEngine(uniform_pool(64, 64, table()), dev, clock=clock)
    eng.run(arrivals_of(trace))
    return dev, eng


def _sorted_dets(captured):
    return {fid: sorted((round(s, 5), tuple(round(v, 3) for v in box))
                        for s, box in dets)
            for fid, dets in captured.items()}


def test_async_routes_identical_detections_to_sync():
    frames, trace = frames_and_trace()
    sync_dev, sync_eng = _run_device(CaptureSync, frames, trace)
    async_dev, async_eng = _run_device(CaptureAsync, frames, trace,
                                       max_inflight=2)
    assert sync_dev.captured, "trace produced no detections to compare"
    assert _sorted_dets(async_dev.captured) == _sorted_dets(sync_dev.captured)
    assert async_dev.n_detections == sync_dev.n_detections
    # frame store fully drained even with deferred completion delivery
    assert async_dev.frames == {} and async_dev._refs == {}
    assert len(async_eng.outcomes) == len(trace)


def test_wall_clock_async_smoke_matches_sync_detections():
    """Wall-clock smoke (CI-safe: ~2s of engine time at 400x compression):
    the async executor under a real-time clock still routes the sync
    run's exact detections."""
    frames, trace = frames_and_trace(n_frames=3, per_frame=2)
    sync_dev, _ = _run_device(CaptureSync, frames, trace)
    async_dev, async_eng = _run_device(CaptureAsync, frames, trace,
                                       clock=WallClock(speed=400.0),
                                       max_inflight=3)
    assert _sorted_dets(async_dev.captured) == _sorted_dets(sync_dev.captured)
    assert len(async_eng.outcomes) == len(trace)
    assert async_eng.completions
    finishes = [c.t_finish for c in async_eng.completions]
    assert finishes == sorted(finishes)      # monotone delivery, pinned


# ------------------------------------------------- bounded in-flight ----

def test_bounded_inflight_depth_respected_under_burst():
    """A burst of immediately-firing patches against a slow stub device:
    the engine must block at max_inflight unresolved handles, never
    beyond, and still deliver every completion."""
    with StubAccelerator(service_s=0.015) as stub:
        dev = AsyncDeviceExecutor(stub.serve_fn, None, 64, 64,
                                  max_inflight=3, sync=stub.sync)
        # every patch arrives past its deadline -> one "late" fire each
        ps = [Patch(0, 0, 32, 32, frame_id=i, t_gen=0.01 * i, slo=1e-6)
              for i in range(10)]
        eng = ServingEngine(uniform_pool(64, 64, table()), dev)
        eng.run(arrivals_of(ps))
    assert eng.inflight_high_water <= 3
    assert eng.inflight_high_water >= 2, \
        "burst never overlapped — the async path ran synchronously"
    assert len(eng.completions) == len(eng.invocations) == stub.n_calls
    assert len(eng.outcomes) == len(ps)
    assert eng._slot_of == {}
    assert all(p is None for p in eng._slot_patch)


def test_async_max_inflight_validation():
    with pytest.raises(ValueError):
        AsyncDeviceExecutor(fake_serve_fn, None, 64, 64, max_inflight=0)
