"""MoE dispatch invariants (GShard grouped top-k routing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import MoEConfig
from repro.models import moe


def _gates(rng, g, s, e):
    logits = jnp.asarray(rng.normal(size=(g, s, e)), jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


class TestTopKDispatch:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 3), st.sampled_from([4, 8]))
    def test_capacity_never_exceeded(self, seed, top_k, n_experts):
        rng = np.random.default_rng(seed)
        cfg = MoEConfig(n_experts=n_experts, top_k=top_k,
                        capacity_factor=1.0, group_size=16)
        cap = moe.capacity(16, cfg)
        gates = _gates(rng, 2, 16, n_experts)
        dispatch, combine, aux = moe._top_k_dispatch(gates, cfg, cap)
        # per (group, expert, slot): at most one token
        per_slot = jnp.sum(dispatch, axis=1)          # (G, E, C)
        assert float(per_slot.max()) <= 1.0 + 1e-6
        # per token: at most top_k assignments
        per_token = jnp.sum(dispatch, axis=(2, 3))    # (G, S)
        assert float(per_token.max()) <= top_k + 1e-6

    def test_combine_weights_normalized(self):
        rng = np.random.default_rng(0)
        cfg = MoEConfig(n_experts=8, top_k=2, capacity_factor=2.0,
                        group_size=16)
        cap = moe.capacity(16, cfg)
        gates = _gates(rng, 2, 16, 8)
        dispatch, combine, _ = moe._top_k_dispatch(gates, cfg, cap)
        # with generous capacity every token keeps its k experts and the
        # combine weights per token sum to 1
        sums = jnp.sum(combine, axis=(2, 3))
        np.testing.assert_allclose(np.asarray(sums), 1.0, atol=1e-5)

    def test_dropping_under_tight_capacity(self):
        rng = np.random.default_rng(1)
        cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=0.25,
                        group_size=32)
        cap = moe.capacity(32, cfg)
        gates = _gates(rng, 1, 32, 4)
        dispatch, _, _ = moe._top_k_dispatch(gates, cfg, cap)
        assigned = float(jnp.sum(dispatch))
        assert assigned < 32 * 2          # some tokens dropped
        assert assigned > 0

    def test_aux_loss_penalizes_imbalance(self):
        cfg = MoEConfig(n_experts=4, top_k=1, capacity_factor=4.0,
                        group_size=16)
        cap = moe.capacity(16, cfg)
        uniform = jnp.full((1, 16, 4), 0.25)
        skewed = jnp.asarray(np.tile([0.97, 0.01, 0.01, 0.01], (1, 16, 1)),
                             jnp.float32)
        _, _, aux_u = moe._top_k_dispatch(uniform, cfg, cap)
        _, _, aux_s = moe._top_k_dispatch(skewed, cfg, cap)
        assert float(aux_s) > float(aux_u)

    def test_block_output_shape_and_grads(self):
        rng = np.random.default_rng(2)
        cfg = MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff_expert=32,
                        group_size=32)
        from repro import param as P
        specs = moe.moe_specs(64, cfg, jnp.float32)
        params = P.init_params(jax.random.PRNGKey(0), specs)
        x = jnp.asarray(rng.normal(size=(2, 32, 64)) * 0.1, jnp.float32)
        from repro.sharding import DEFAULT_RULES
        out, aux = moe.moe_block(params, x, cfg, compute_dtype=jnp.float32,
                                 rules=DEFAULT_RULES)
        assert out.shape == x.shape
        g = jax.grad(lambda p: jnp.sum(moe.moe_block(
            p, x, cfg, compute_dtype=jnp.float32,
            rules=DEFAULT_RULES)[0] ** 2))(params)
        total = sum(float(jnp.abs(l).sum())
                    for l in jax.tree_util.tree_leaves(g))
        assert np.isfinite(total) and total > 0
