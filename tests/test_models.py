"""Model identity end-to-end: registry, weight caches, placement,
per-model platform economics, and the latency bank.

Covers the multi-model PR acceptance criteria:

* ``ModelSpec`` / ``make_model`` — arch-derived defaults (canvas
  geometry, weight bytes, load seconds), explicit-table precedence,
  the unified unknown-name error;
* ``WeightCache`` — deterministic LRU eviction and load-cost
  accounting, including through a ``WorkerPoolExecutor`` (sync and
  async workers);
* ``ModelAffinityPlacement`` — same-model batches co-locate (cache
  residency when caches exist, sticky homes otherwise);
* platform per-model warm pools — an instance warm for model A is cold
  for model B; cold starts decompose into container + weight load; the
  ``model=None`` path is byte-identical to the legacy single-model
  platform;
* ``LatencyBank`` — per-model observation routing so ``t_slack`` and
  AIMD adapt per model;
* a two-class two-model ``TangramScheduler`` run: per-model latency
  feeds ``t_slack``, model-affinity placement loads each model's
  weights once while model-oblivious placement keeps swapping.
"""
import math

import pytest

from repro.core.config import ServeConfig
from repro.core.engine import Completion, ExecHandle
from repro.core.invoker import Invocation
from repro.core.latency import LatencyBank, LatencyTable, OnlineLatencyTable
from repro.core.models import ModelSpec, make_model, model_names, \
    register_model
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.core.workers import (ModelAffinityPlacement, WeightCache,
                                WorkerPoolExecutor, make_placement,
                                weight_caches)
from repro.serverless.platform import Platform, PlatformConfig


def table(mu=0.1, sigma=0.0, n=16):
    return LatencyTable({b: (mu * b, sigma) for b in range(1, n + 1)},
                        slack_sigmas=3.0)


def _inv(model=None, key=None, t=0.0, n_patches=1):
    ps = [Patch(0, 0, 16, 16, t_gen=t, slo=1.0) for _ in range(n_patches)]
    return Invocation(t, [], ps, 0.0, "timer", key=key, model=model)


# ------------------------------------------------------------ registry ----

class TestRegistry:
    def test_zoo_is_seeded(self):
        assert {"tangram", "vit_s16", "efficientnet_b7"} <= set(model_names())

    def test_unknown_model_unified_error(self):
        with pytest.raises(ValueError, match="unknown model 'nope'"):
            make_model("nope")

    def test_register_last_wins(self):
        register_model(ModelSpec(name="dup", canvas_m=32, canvas_n=32,
                                 weight_bytes=1.0, table=table()))
        register_model(ModelSpec(name="dup", canvas_m=64, canvas_n=64,
                                 weight_bytes=2.0, table=table()))
        assert make_model("dup").canvas_m == 64

    def test_arch_derived_defaults(self):
        spec = make_model("tangram")
        a = spec.arch
        assert (spec.canvas_m, spec.canvas_n) == (a.canvas, a.canvas)
        per_param = 2 if a.param_dtype in ("bfloat16", "float16") else 4
        assert spec.weight_bytes == pytest.approx(a.n_params * per_param)
        assert spec.load_s == pytest.approx(spec.weight_bytes / spec.load_bw)

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="canvas geometry"):
            ModelSpec(name="bad", weight_bytes=1.0, table=table())
        with pytest.raises(ValueError, match="weight_bytes"):
            ModelSpec(name="bad", canvas_m=32, canvas_n=32, table=table())
        with pytest.raises(ValueError, match="latency source"):
            ModelSpec(name="bad", canvas_m=32, canvas_n=32, weight_bytes=1.0)

    def test_explicit_table_wins_and_arch_builds_one(self):
        t = table(mu=0.5)
        spec = ModelSpec(name="tabled", canvas_m=32, canvas_n=32,
                         weight_bytes=1.0, table=t)
        assert spec.latency_table() is t
        derived = make_model("tangram").latency_table(max_batch=4)
        assert derived.t_slack(1) > 0

    def test_reduced_archs_differ_per_trunk(self):
        r1 = make_model("tangram").reduced_arch(256)
        r2 = make_model("vit_s16").reduced_arch(256)
        assert r1.name != r2.name
        assert (r1.d_model, r1.n_layers) != (r2.d_model, r2.n_layers) or \
            r1.patch != r2.patch


# -------------------------------------------------------- weight cache ----

class TestWeightCache:
    MODELS = {"a": (60.0, 1.0), "b": (60.0, 2.0), "c": (50.0, 0.5)}

    def test_lru_eviction_is_deterministic(self):
        c = WeightCache(120.0, self.MODELS)
        assert c.ensure("a") == 1.0          # miss: load
        assert c.ensure("b") == 2.0          # fits alongside a
        assert c.resident() == ["a", "b"]
        assert c.ensure("a") == 0.0          # hit touches a MRU
        assert c.resident() == ["b", "a"]
        assert c.ensure("c") == 0.5          # evicts b (LRU), not a
        assert c.resident() == ["a", "c"]
        assert c.evictions == 1
        # replay is bit-identical: no clock, no randomness
        c2 = WeightCache(120.0, self.MODELS)
        for m in ("a", "b", "a", "c"):
            c2.ensure(m)
        assert c2.resident() == c.resident()
        assert c2.used_bytes == c.used_bytes

    def test_load_cost_accounting(self):
        c = WeightCache(60.0, self.MODELS)
        total = sum(c.ensure(m) for m in ("a", "b", "a", "b"))
        # capacity for one model: every switch reloads
        assert total == pytest.approx(1.0 + 2.0 + 1.0 + 2.0)
        assert c.load_seconds == pytest.approx(total)
        assert c.n_hits == 0 and c.n_misses == 4
        assert c.hit_rate == 0.0

    def test_untagged_and_unknown_are_free(self):
        c = WeightCache(100.0, self.MODELS)
        assert c.ensure(None) == 0.0
        assert c.ensure("unknown") == 0.0
        assert c.resident() == []

    def test_oversized_model_still_loads_alone(self):
        c = WeightCache(10.0, {"big": (100.0, 3.0)})
        assert c.ensure("big") == 3.0
        assert c.resident() == ["big"]
        assert c.ensure("big") == 0.0        # resident despite oversize

    def test_weight_caches_are_independent(self):
        cs = weight_caches(2, 100.0, self.MODELS)
        cs[0].ensure("a")
        assert cs[0].holds("a") and not cs[1].holds("a")


# ----------------------------------------------- model-affinity placement ----

class _InstantWorker:
    """Sync worker: completion known at submit (SimExecutor-shaped)."""

    def __init__(self, service_s=0.1):
        self.service_s = service_s

    def submit(self, inv):
        comp = Completion(inv, inv.t_submit + self.service_s)
        return ExecHandle(inv, t_finish=comp.t_finish, completion=comp)

    def resolve(self, handle):
        return handle.completion


class _DeferredWorker:
    """Async worker: finish time unknown until resolve."""

    def submit(self, inv):
        return ExecHandle(inv, t_finish=None)

    def resolve(self, handle):
        return Completion(handle.invocation, handle.invocation.t_submit + 0.1)


class TestModelAffinityPlacement:
    def test_registered_in_factory(self):
        assert isinstance(make_placement("model"), ModelAffinityPlacement)

    def test_cache_residency_wins(self):
        caches = weight_caches(2, 100.0, {"m": (50.0, 1.0)})
        pool = WorkerPoolExecutor([_InstantWorker(), _InstantWorker()],
                                  placement=ModelAffinityPlacement(),
                                  weight_caches=caches)
        caches[1].ensure("m")                # worker 1 already holds m
        assert pool.placement.choose(_inv(model="m"), pool) == 1

    def test_sticky_homes_spread_round_robin(self):
        pool = WorkerPoolExecutor([_InstantWorker(), _InstantWorker()],
                                  placement=ModelAffinityPlacement())
        p = pool.placement
        assert p.choose(_inv(model="x"), pool) == 0
        assert p.choose(_inv(model="y"), pool) == 1
        # homes are sticky across repeats
        assert p.choose(_inv(model="x"), pool) == 0
        assert p.choose(_inv(model="y"), pool) == 1

    def test_untagged_falls_back_to_least_outstanding(self):
        pool = WorkerPoolExecutor([_InstantWorker(), _InstantWorker()],
                                  placement=ModelAffinityPlacement())
        pool.outstanding[0] = 3
        assert pool.placement.choose(_inv(), pool) == 1

    def test_pool_charges_load_cost_once_per_worker(self):
        caches = weight_caches(1, 100.0, {"m": (50.0, 1.0)})
        pool = WorkerPoolExecutor([_InstantWorker(service_s=0.1)],
                                  placement=ModelAffinityPlacement(),
                                  weight_caches=caches)
        h1 = pool.submit(_inv(model="m", t=0.0))
        h2 = pool.submit(_inv(model="m", t=5.0))
        # first touch pays the load on the known finish time; second hits
        assert pool.resolve(h1).t_finish == pytest.approx(0.0 + 0.1 + 1.0)
        assert pool.resolve(h2).t_finish == pytest.approx(5.0 + 0.1)
        assert caches[0].stats()["load_s"] == pytest.approx(1.0)

    def test_async_worker_load_cost_applies_at_resolve(self):
        caches = weight_caches(1, 100.0, {"m": (50.0, 1.0)})
        pool = WorkerPoolExecutor([_DeferredWorker()],
                                  weight_caches=caches)
        h = pool.submit(_inv(model="m", t=0.0))
        assert h.load_s == pytest.approx(1.0)
        comp = pool.resolve(h)
        assert comp.t_finish == pytest.approx(0.0 + 0.1 + 1.0)
        assert h.load_s == 0.0               # debit applied exactly once

    def test_worker_and_model_cache_stats(self):
        caches = weight_caches(2, 100.0, {"m": (50.0, 1.0)})
        pool = WorkerPoolExecutor([_InstantWorker(), _InstantWorker()],
                                  placement=ModelAffinityPlacement(),
                                  weight_caches=caches)
        for t in (0.0, 1.0, 2.0):
            pool.resolve(pool.submit(_inv(model="m", t=t)))
        ws = pool.worker_stats()
        assert any("weights" in w for w in ws)
        ms = pool.model_cache_stats()["m"]
        assert ms["weight_misses"] == 1 and ms["weight_hits"] == 2
        assert ms["weight_hit_rate"] == pytest.approx(2 / 3, abs=1e-4)


# -------------------------------------- platform per-model warm pools ----

class TestPlatformModelEconomics:
    def test_warm_for_a_is_cold_for_b(self):
        p = Platform(table(), PlatformConfig(pre_warm=1, max_instances=1))
        r1 = p.submit(0.0, 1, model="a", model_load_s=0.3)
        r2 = p.submit(1.0, 1, model="a", model_load_s=0.3)
        r3 = p.submit(2.0, 1, model="b", model_load_s=0.4)
        assert r1.weight_loaded and r1.load_s == pytest.approx(0.3)
        assert not r2.weight_loaded and r2.load_s == 0.0
        assert r2.t_start == pytest.approx(1.0)      # warm same-model
        assert r3.weight_loaded
        assert r3.t_start == pytest.approx(2.0 + 0.4)  # swap, no container
        assert not r3.cold

    def test_cold_start_decomposes_into_container_plus_load(self):
        cfg = PlatformConfig(pre_warm=0, max_instances=1,
                             cold_start_s=0.25, container_cold_s=0.1)
        p = Platform(table(), cfg)
        r = p.submit(0.0, 1, model="a", model_load_s=0.5)
        assert r.cold and r.weight_loaded
        assert r.t_start == pytest.approx(0.1 + 0.5)

    def test_container_cold_defaults_to_cold_start(self):
        p = Platform(table(), PlatformConfig(pre_warm=0, max_instances=1,
                                             cold_start_s=0.25))
        r = p.submit(0.0, 1, model="a", model_load_s=0.5)
        assert r.t_start == pytest.approx(0.25 + 0.5)

    def test_untagged_path_identical_to_legacy(self):
        cfg = PlatformConfig(straggler_prob=0.1, seed=3, pre_warm=1,
                             max_instances=2)
        a, b = Platform(table(sigma=0.01), cfg), Platform(table(sigma=0.01),
                                                          cfg)
        for i in range(8):
            ra = a.submit(i * 0.05, 1 + i % 3)
            rb = b.submit(i * 0.05, 1 + i % 3, model=None, model_load_s=0.0)
            assert ra == rb
        assert a.total_cost == pytest.approx(b.total_cost)

    def test_per_model_latency_override_and_stats(self):
        p = Platform(table(mu=0.1), PlatformConfig(pre_warm=2,
                                                   max_instances=2))
        heavy = table(mu=1.0)
        r = p.submit(0.0, 1, model="h", model_load_s=0.2, latency=heavy)
        assert r.exec_s == pytest.approx(1.0)        # sigma 0: exact
        stats = p.model_stats()["h"]
        assert stats["invocations"] == 1
        assert stats["weight_loads"] == 1
        assert stats["load_seconds"] == pytest.approx(0.2)
        assert stats["weight_hit_rate"] == 0.0


# --------------------------------------------------------- latency bank ----

class TestLatencyBank:
    def test_routes_observations_per_model(self):
        fast = OnlineLatencyTable(table(mu=0.1))
        slow = OnlineLatencyTable(table(mu=1.0))
        bank = LatencyBank({"fast": fast, "slow": slow})
        for _ in range(64):
            bank.observe(1, 0.4, model="fast")       # 4x slower than table
        assert fast.drift() > 2.0
        assert slow.drift() == pytest.approx(1.0)    # untouched
        assert bank.table("fast") is fast

    def test_unknown_model_unified_error(self):
        bank = LatencyBank({"a": OnlineLatencyTable(table())})
        with pytest.raises(ValueError, match="unknown model"):
            bank.table("nope")

    def test_sole_table_is_default(self):
        only = OnlineLatencyTable(table(mu=0.2))
        bank = LatencyBank({"only": only})
        bank.observe(1, 0.8)                         # no model: routes there
        assert only.drift() > 1.0

    def test_round_trip(self):
        bank = LatencyBank({"a": OnlineLatencyTable(table(mu=0.1)),
                            "b": OnlineLatencyTable(table(mu=0.2))})
        rebuilt = LatencyBank.from_dict(bank.to_dict())
        assert rebuilt.table("a").t_slack(2) == \
            pytest.approx(bank.table("a").t_slack(2))


# ------------------------------------------- two-model scheduler run ----

def _register_pair():
    register_model(ModelSpec(name="sched-fast", canvas_m=128, canvas_n=128,
                             weight_bytes=2e9, table=table(mu=0.04)))
    register_model(ModelSpec(name="sched-heavy", canvas_m=128, canvas_n=128,
                             weight_bytes=8e9, table=table(mu=0.25)))


def _streams(n_frames=30):
    streams = []
    for cam, slo in enumerate((0.5, 2.0)):
        streams.append([Patch(0, 0, 48, 48, frame_id=f, camera_id=cam,
                              t_gen=f / 10.0, slo=slo)
                        for f in range(n_frames)])
    return streams


def _run(placement, online=False):
    _register_pair()
    cfg = ServeConfig(classify="slo", n_workers=2, placement=placement,
                      online_latency=online,
                      model_map={"0.5": "sched-fast", "2.0": "sched-heavy"})
    lat = table()
    sched = TangramScheduler(256, 256, lat,
                             Platform(lat, PlatformConfig(max_instances=2,
                                                          pre_warm=2)),
                             config=cfg)
    return sched, sched.run(_streams(), bandwidth_bps=1e9)


class TestTwoModelScheduler:
    def test_per_model_t_slack(self):
        sched, res = _run("model")
        fast = sched.pool.invokers[0.5]
        heavy = sched.pool.invokers[2.0]
        assert fast.latency.t_slack(1) < heavy.latency.t_slack(1)
        # and the per-model estimates came from the registry tables
        assert fast.latency.t_slack(1) == pytest.approx(0.04)
        assert heavy.latency.t_slack(1) == pytest.approx(0.25)

    def test_outcomes_and_summary_carry_model_identity(self):
        _, res = _run("model")
        assert all(o.model is not None for o in res.outcomes)
        for o in res.outcomes:
            want = "sched-fast" if o.patch.slo == 0.5 else "sched-heavy"
            assert o.model == want
        rows = res.summary()["models"]
        assert set(rows) == {"sched-fast", "sched-heavy"}
        for row in rows.values():
            assert {"patches", "violations", "invocations",
                    "weight_loads", "weight_hit_rate"} <= set(row)

    def test_affinity_loads_each_model_once(self):
        _, res = _run("model")
        loads = {m: r["weight_loads"]
                 for m, r in res.summary()["models"].items()}
        assert loads == {"sched-fast": 1, "sched-heavy": 1}

    def test_oblivious_placement_swaps_more(self):
        _, affinity = _run("model")
        _, oblivious = _run("least")
        n_loads = lambda r: sum(row["weight_loads"]
                                for row in r.summary()["models"].values())
        assert n_loads(affinity) < n_loads(oblivious)
        assert affinity.violation_rate <= oblivious.violation_rate

    def test_online_latency_uses_a_bank(self):
        sched, res = _run("model", online=True)
        assert isinstance(sched.estimator, LatencyBank)
        assert res.n_patches == sum(len(s) for s in _streams())
