"""End-to-end scheduler simulation + baseline comparisons (paper claims)."""
import numpy as np
import pytest

from repro.core import baselines
from repro.core.latency import detector_latency_model
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig

CANVAS = 256
SLO = 1.0


def make_streams(n_cams=2, n_frames=20, per_frame=6, seed=0):
    rng = np.random.default_rng(seed)
    streams = []
    for cam in range(n_cams):
        patches = []
        for f in range(n_frames):
            t = f / 10.0
            for _ in range(rng.integers(1, per_frame + 1)):
                w = int(rng.integers(16, 160))
                h = int(rng.integers(16, 160))
                patches.append(Patch(0, 0, w, h, frame_id=f, camera_id=cam,
                                     t_gen=t, slo=SLO))
        streams.append(patches)
    return streams


def table():
    return detector_latency_model(CANVAS, CANVAS).build_table(16)


def run_tangram(streams, bw=20e6):
    plat = Platform(table(), PlatformConfig())
    sched = TangramScheduler(CANVAS, CANVAS, table(), plat,
                             check_invariants=True)
    return sched.run(streams, bw)


class TestTangramEndToEnd:
    def test_all_patches_served_once(self):
        streams = make_streams()
        res = run_tangram(streams)
        assert res.n_patches == sum(len(s) for s in streams)

    def test_slo_violations_within_5pct(self):
        """The paper's headline claim at the default setting."""
        res = run_tangram(make_streams(n_cams=3, n_frames=30))
        assert res.violation_rate <= 0.05

    def test_batching_amortizes_invocations(self):
        res = run_tangram(make_streams())
        assert res.invocations < res.n_patches / 3

    def test_canvas_efficiency_reported(self):
        res = run_tangram(make_streams())
        assert res.canvas_efficiencies
        assert all(0 < e <= 1.0 for e in res.canvas_efficiencies)

    def test_higher_bandwidth_improves_canvas_efficiency(self):
        """Fig. 13(d): higher bw -> faster arrivals -> fuller canvases."""
        lo = run_tangram(make_streams(seed=4), bw=10e6)
        hi = run_tangram(make_streams(seed=4), bw=80e6)
        assert np.mean(hi.canvas_efficiencies) >= \
            np.mean(lo.canvas_efficiencies) - 0.05


class TestBaselineComparisons:
    def test_tangram_cheaper_than_elf(self):
        """Fig. 8/12: per-patch invocation (ELF) costs more."""
        streams = make_streams(n_cams=3, n_frames=30)
        tangram = run_tangram(streams)
        elf = baselines.run_elf(streams, 20e6,
                                Platform(table(), PlatformConfig()),
                                CANVAS * CANVAS)
        assert tangram.total_cost < elf.total_cost

    def test_tangram_cheaper_than_clipper_and_mark(self):
        streams = make_streams(n_cams=3, n_frames=30)
        tangram = run_tangram(streams)
        clip = baselines.run_clipper(streams, 20e6,
                                     Platform(table(), PlatformConfig()),
                                     CANVAS * CANVAS, tile_side=128, slo=SLO)
        mark = baselines.run_mark(streams, 20e6,
                                  Platform(table(), PlatformConfig()),
                                  CANVAS * CANVAS, tile_side=128)
        assert tangram.total_cost < clip.total_cost
        assert tangram.total_cost < mark.total_cost

    def test_patch_bandwidth_below_full_frame(self):
        """Fig. 9: RoI patches use less bandwidth than full frames."""
        streams = make_streams(n_cams=1, n_frames=20)
        tangram = run_tangram(streams)
        frames = [baselines.FrameMeta(960, 540, 20000, t_gen=f / 10.0,
                                      slo=SLO) for f in range(20)]
        full = baselines.run_frame_baseline(
            [frames], 20e6, Platform(table(), PlatformConfig()),
            masked=False)
        assert tangram.bytes_sent < full.bytes_sent

    def test_masked_frame_saves_bandwidth_not_compute(self):
        frames = [baselines.FrameMeta(960, 540, 20000, t_gen=f / 10.0,
                                      slo=SLO) for f in range(10)]
        full = baselines.run_frame_baseline(
            [frames], 20e6, Platform(table(), PlatformConfig()), masked=False)
        masked = baselines.run_frame_baseline(
            [frames], 20e6, Platform(table(), PlatformConfig()), masked=True)
        assert masked.bytes_sent < 0.5 * full.bytes_sent
        assert masked.invocations == full.invocations

    def test_results_summary_keys(self):
        res = run_tangram(make_streams())
        s = res.summary()
        for key in ("violation_rate", "cost_usd", "bytes_mb",
                    "mean_canvas_eff", "amortized_latency_s"):
            assert key in s
