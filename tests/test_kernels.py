"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmm
from repro.core.partitioning import Patch
from repro.core.stitching import build_batch_plan, stitch
from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention.ref import decode_reference, mha_reference
from repro.kernels.gmm import ops as gmm_ops
from repro.kernels.stitch import ops as stitch_ops
from repro.kernels.stitch.ref import stitch_reference, unstitch_reference
from repro.kernels.stitch.stitch import stitch_pallas, unstitch_pallas


# ------------------------------------------------------------ attention ----

ATTN_CASES = [
    # (B, S, H, Kv, D, causal, dtype, bq, bk)
    (1, 128, 4, 4, 64, True, jnp.float32, 64, 64),
    (2, 256, 8, 2, 64, True, jnp.float32, 128, 64),
    (2, 256, 8, 8, 32, False, jnp.float32, 64, 128),
    (1, 512, 4, 1, 128, True, jnp.float32, 128, 128),
    (2, 128, 4, 4, 64, True, jnp.bfloat16, 64, 64),
]


@pytest.mark.parametrize("b,s,h,kv,d,causal,dtype,bq,bk", ATTN_CASES)
def test_flash_attention_matches_ref(b, s, h, kv, d, causal, dtype, bq, bk):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), dtype)
    ref = mha_reference(q, k, v, causal=causal)
    out = attn_ops.flash_attention(q, k, v, causal=causal, block_q=bq,
                                   block_kv=bk, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_fused_qkv_matches_split():
    """Fused (d, H+2Kv, dh) projection == separate wq/wk/wv."""
    import jax
    from repro.models import attention as A
    rng = np.random.default_rng(3)
    d, H, Kv, dh = 32, 4, 2, 8
    x = jnp.asarray(rng.normal(size=(2, 16, d)), jnp.float32)
    wq = jnp.asarray(rng.normal(size=(d, H, dh)), jnp.float32)
    wk = jnp.asarray(rng.normal(size=(d, Kv, dh)), jnp.float32)
    wv = jnp.asarray(rng.normal(size=(d, Kv, dh)), jnp.float32)
    split = A._qkv({"wq": wq, "wk": wk, "wv": wv}, x, Kv, jnp.float32)
    fused = A._qkv({"wqkv": jnp.concatenate([wq, wk, wv], axis=1)}, x, Kv,
                   jnp.float32)
    for a, b in zip(split, fused):
        # fp32 reduction order differs between the fused/split matmuls
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)


def test_flash_attention_segments():
    rng = np.random.default_rng(1)
    b, s, h, d = 2, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    seg = jnp.asarray(np.repeat([0, 1, 2, 3], s // 4)[None].repeat(b, 0))
    ref = mha_reference(q, k, v, causal=True, segment_ids=seg)
    out = attn_ops.flash_attention(q, k, v, causal=True, segment_ids=seg,
                                   block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("pos", [0, 1, 63, 64, 200, 511])
@pytest.mark.parametrize("kv", [1, 4])
def test_flash_decode_pos_sweep(pos, kv):
    rng = np.random.default_rng(2)
    b, h, d, smax = 2, 8, 64, 512
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, smax, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, smax, kv, d)), jnp.float32)
    ref = decode_reference(q, k, v, pos)
    out = attn_ops.flash_decode(q, k, v, pos, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------- stitch ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,hmax,wmax", [(64, 64, 32, 32),
                                           (128, 64, 64, 32),
                                           (64, 128, 64, 64)])
def test_stitch_kernel_random_packings(dtype, m, n, hmax, wmax):
    """Drive the kernel with REAL packer output (non-overlap guaranteed)."""
    rng = np.random.default_rng(int(m + n))
    patches = [Patch(0, 0, int(rng.integers(8, wmax + 1)),
                     int(rng.integers(8, hmax + 1))) for _ in range(9)]
    canvases = stitch(patches, m, n)
    plan = build_batch_plan(patches, canvases, m, n)
    assert plan.hmax <= hmax and plan.wmax <= wmax
    crops = [np.asarray(rng.normal(size=(p.h, p.w, 3)), np.float32)
             for p in patches]
    slots = jnp.asarray(stitch_ops.pack_plan_host(crops, plan), dtype)
    records = jnp.asarray(plan.records)
    ref = stitch_reference(slots, records, m, n)
    out = stitch_pallas(slots, records, m, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


def test_stitch_kernel_empty_canvas():
    slots = jnp.zeros((1, 16, 16, 3), jnp.float32)
    records = jnp.zeros((2, 4, 6), jnp.int32)
    out = stitch_pallas(slots, records, 32, 32, interpret=True)
    assert out.shape == (2, 32, 32, 3)
    assert float(jnp.abs(out).sum()) == 0.0


def test_stitch_kernel_zero_patch_packing():
    """A plan built from an empty queue yields a zero canvas batch without
    launching a degenerate (zero-extent) kernel grid."""
    plan = build_batch_plan([], [], 32, 32)
    assert plan.num_canvases == 0 and plan.num_patches == 0
    slots = jnp.zeros((1, plan.hmax, plan.wmax, 3), jnp.float32)
    out = stitch_pallas(slots, jnp.asarray(plan.records), 32, 32,
                        interpret=True)
    assert out.shape == (0, 32, 32, 3)
    # zero-slot records (K = 0) must also short-circuit, not launch
    out = stitch_pallas(jnp.zeros((1, 8, 8, 3), jnp.float32),
                        jnp.zeros((2, 0, 6), jnp.int32), 32, 32,
                        interpret=True)
    assert out.shape == (2, 32, 32, 3)
    assert float(jnp.abs(out).sum()) == 0.0


def test_stitch_kernel_single_patch_packing():
    rng = np.random.default_rng(11)
    patches = [Patch(0, 0, 12, 9)]
    canvases = stitch(patches, 32, 32)
    plan = build_batch_plan(patches, canvases, 32, 32)
    crops = [np.asarray(rng.normal(size=(9, 12, 3)), np.float32)]
    slots = jnp.asarray(stitch_ops.pack_plan_host(crops, plan))
    records = jnp.asarray(plan.records)
    out = stitch_pallas(slots, records, 32, 32, interpret=True)
    assert out.shape == (1, 32, 32, 3)
    np.testing.assert_array_equal(np.asarray(out[0, :9, :12]), crops[0])
    assert float(jnp.abs(out[0, 9:]).sum()) == 0.0
    assert float(jnp.abs(out[0, :, 12:]).sum()) == 0.0


def test_stitch_jit_wrapper_impls_agree():
    rng = np.random.default_rng(5)
    slots = jnp.asarray(rng.normal(size=(3, 16, 16, 3)), jnp.float32)
    records = jnp.asarray([[[1, 0, 0, 0, 16, 16], [1, 1, 16, 16, 8, 8],
                            [0, 0, 0, 0, 0, 0]]], jnp.int32)
    a = stitch_ops.stitch_canvases(slots, records, 32, 32, impl="xla")
    b = stitch_ops.stitch_canvases(slots, records, 32, 32,
                                   impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- unstitch ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_unstitch_round_trip_property(dtype, seed):
    """stitch -> unstitch is the identity on patch slot contents for any
    real packer output (placements are non-overlapping by construction)."""
    m = n = 64
    rng = np.random.default_rng(seed)
    n_patches = int(rng.integers(1, 12))
    patches = [Patch(0, 0, int(rng.integers(4, 33)), int(rng.integers(4, 33)))
               for _ in range(n_patches)]
    canvases = stitch(patches, m, n)
    plan = build_batch_plan(patches, canvases, m, n)
    crops = [np.asarray(rng.normal(size=(p.h, p.w, 3)), np.float32)
             for p in patches]
    slots = jnp.asarray(stitch_ops.pack_plan_host(crops, plan), dtype)
    records = jnp.asarray(plan.records)
    stitched = stitch_pallas(slots, records, m, n, interpret=True)
    back = unstitch_pallas(stitched, records, plan.num_patches,
                           plan.hmax, plan.wmax, interpret=True)
    # exact round trip: both directions only move pixels, never blend
    # (slots rows past num_patches are pow2-bucket padding, all zero)
    np.testing.assert_array_equal(
        np.asarray(back, np.float32),
        np.asarray(slots[:plan.num_patches], np.float32))


def test_unstitch_kernel_matches_reference():
    m, n = 64, 128
    rng = np.random.default_rng(21)
    patches = [Patch(0, 0, int(rng.integers(8, 49)), int(rng.integers(8, 49)))
               for _ in range(7)]
    canvases = stitch(patches, m, n)
    plan = build_batch_plan(patches, canvases, m, n)
    batch = jnp.asarray(rng.normal(size=(plan.num_canvases, m, n, 3)),
                        jnp.float32)
    records = jnp.asarray(plan.records)
    ref = unstitch_reference(batch, records, plan.num_patches,
                             plan.hmax, plan.wmax)
    out = unstitch_pallas(batch, records, plan.num_patches,
                          plan.hmax, plan.wmax, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_unstitch_jit_wrapper_impls_agree():
    rng = np.random.default_rng(23)
    batch = jnp.asarray(rng.normal(size=(1, 32, 32, 3)), jnp.float32)
    records = jnp.asarray([[[1, 0, 0, 0, 16, 16], [1, 1, 16, 16, 8, 8],
                            [0, 0, 0, 0, 0, 0]]], jnp.int32)
    a = stitch_ops.unstitch_patches(batch, records, 2, 16, 16, impl="xla")
    b = stitch_ops.unstitch_patches(batch, records, 2, 16, 16,
                                    impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _routing_plan():
    """One 64x64 canvas, two 32x32 placements from different frames."""
    from repro.core.stitching import BatchPlan
    records = np.asarray([[(1, 0, 0, 0, 32, 32),
                           (1, 1, 32, 0, 32, 32)]], np.int32)
    plan = BatchPlan(canvas_m=64, canvas_n=64, num_canvases=1,
                     num_patches=2, slots_per_canvas=2, hmax=32, wmax=32,
                     records=records)
    patches = [Patch(100, 100, 132, 132, frame_id=1),
               Patch(200, 50, 232, 82, frame_id=2)]
    return plan, patches


def test_route_detections_frame_assignment_and_translation():
    plan, patches = _routing_plan()
    obj = np.zeros((1, 4, 4), np.float32)
    boxes = np.zeros((1, 4, 4, 4), np.float32)
    # box center (12, 12) -> placement A -> frame 1 at (100, 100)
    obj[0, 0, 0] = 0.9
    boxes[0, 0, 0] = (4, 4, 20, 20)
    # box center (50, 20) -> placement B -> frame 2 at (200, 50)
    obj[0, 1, 2] = 0.8
    boxes[0, 1, 2] = (40, 10, 60, 30)
    # below threshold: dropped even though it lies inside placement A
    obj[0, 1, 0] = 0.2
    boxes[0, 1, 0] = (4, 20, 20, 30)
    routed = stitch_ops.route_detections(plan, patches, obj, boxes)
    assert set(routed) == {1, 2}
    (s1, b1), = routed[1]
    assert s1 == pytest.approx(0.9)
    assert b1 == pytest.approx((104, 104, 120, 120))
    (s2, b2), = routed[2]
    assert s2 == pytest.approx(0.8)
    assert b2 == pytest.approx((208, 60, 228, 80))


def test_route_detections_clips_spill_and_keeps_subcell_placements():
    plan, patches = _routing_plan()
    obj = np.zeros((1, 4, 4), np.float32)
    boxes = np.zeros((1, 4, 4, 4), np.float32)
    # box center (32, 12) is on placement B's edge; the box spills 8px
    # into placement A and must be clipped to B before translation
    obj[0, 0, 1] = 0.9
    boxes[0, 0, 1] = (24, 4, 40, 20)
    routed = stitch_ops.route_detections(plan, patches, obj, boxes)
    assert set(routed) == {2}
    (_, b2), = routed[2]
    assert b2 == pytest.approx((200, 54, 208, 70))

    # a placement narrower than one detector cell (cell = 16px here)
    # still receives detections: routing is by decoded box center
    from repro.core.stitching import BatchPlan
    narrow = BatchPlan(canvas_m=64, canvas_n=64, num_canvases=1,
                       num_patches=1, slots_per_canvas=1, hmax=10, wmax=10,
                       records=np.asarray([[(1, 0, 44, 20, 10, 10)]],
                                          np.int32))
    npatches = [Patch(300, 400, 310, 410, frame_id=7)]
    obj = np.zeros((1, 4, 4), np.float32)
    boxes = np.zeros((1, 4, 4, 4), np.float32)
    obj[0, 1, 2] = 0.95
    boxes[0, 1, 2] = (45, 21, 53, 29)     # center (49, 25) inside 10x10 rect
    routed = stitch_ops.route_detections(narrow, npatches, obj, boxes)
    assert set(routed) == {7}
    (_, b7), = routed[7]
    assert b7 == pytest.approx((301, 401, 309, 409))


def test_unstitch_empty():
    batch = jnp.zeros((1, 32, 32, 3), jnp.float32)
    out = unstitch_pallas(batch, jnp.zeros((1, 0, 6), jnp.int32), 0, 8, 8,
                          interpret=True)
    assert out.shape == (0, 8, 8, 3)


# ------------------------------------------------------------------ gmm ----

@pytest.mark.parametrize("h,w,bh,bw", [(8, 128, 8, 128), (16, 256, 8, 128),
                                       (32, 512, 8, 256)])
def test_gmm_kernel_matches_oracle(h, w, bh, bw):
    rng = np.random.default_rng(7)
    s_ref = s_pal = gmm.init_state(h, w)
    for i in range(4):
        frame = jnp.asarray(rng.random((h, w)), jnp.float32)
        s_ref, fg_ref = gmm_ops.gmm_update(s_ref, frame, impl="xla")
        s_pal, fg_pal = gmm_ops.gmm_update(s_pal, frame,
                                           impl="pallas_interpret",
                                           block_h=bh, block_w=bw)
        for key in ("w", "mu", "var"):
            np.testing.assert_allclose(np.asarray(s_ref[key]),
                                       np.asarray(s_pal[key]), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(fg_ref), np.asarray(fg_pal))


def test_gmm_background_convergence():
    """Static background absorbed; moving object flagged as foreground."""
    h, w = 16, 128
    state = gmm.init_state(h, w)
    bg = jnp.full((h, w), 0.5, jnp.float32)
    for _ in range(30):
        state, fg = gmm.update_jit(state, bg)
    assert int(fg.sum()) == 0
    frame = bg.at[4:8, 10:30].set(0.95)
    _, fg = gmm.update_jit(state, frame)
    assert int(fg[4:8, 10:30].sum()) >= 0.9 * (4 * 20)
    assert int(fg.sum()) <= 4 * 20 * 1.5
