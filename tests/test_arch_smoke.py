"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward / train / decode / gen step on CPU — output shapes + no NaNs.
(The full configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, param as param_lib
from repro import configs as reg
from repro.config import (DiTConfig, EfficientNetConfig, TransformerConfig,
                          ViTConfig)
from repro.configs.reduced import reduce_arch, reduce_shape
from repro.launch.mesh import make_unit_mesh
from repro.sharding import ShardingConfig

RULES = ShardingConfig.make().rules
ALL_ARCHS = list(reg.ARCH_IDS)


def _make_batch(plan, rng):
    """Materialize random inputs for the plan's abstract args."""
    def concretize(leaf):
        if leaf.dtype == jnp.int32:
            hi = 100
            return jnp.asarray(rng.integers(0, hi, leaf.shape), jnp.int32)
        if leaf.dtype == jnp.bool_:
            return jnp.ones(leaf.shape, jnp.bool_)
        return jnp.asarray(rng.normal(size=leaf.shape) * 0.1, leaf.dtype)
    return jax.tree_util.tree_map(concretize, plan.args[-1])


def _finite(tree):
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), "non-finite output"


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_reduced_train_or_serve_step(arch_id, rng):
    spec = reg.get(arch_id)
    model = reduce_arch(spec.model)
    # first train-like shape for trainable kinds, else first shape
    shapes = [s for s in spec.shapes if s.kind in ("train", "cls")] \
        or list(spec.shapes)
    shape = reduce_shape(model, shapes[0])
    mesh = make_unit_mesh()
    plan = api.plan_cell(model, shape, mesh, RULES)

    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   api.param_specs(model))
    if plan.kind == "train":
        from repro.training import optimizer as opt_lib
        opt_state = opt_lib.init(params)
        batch = _make_batch(plan, rng)
        step = jax.jit(plan.step_fn)
        new_params, new_opt, metrics = step(params, opt_state, batch)
        assert metrics["loss"].shape == ()
        assert bool(jnp.isfinite(metrics["loss"]))
        _finite(metrics)
        # params actually moved
        delta = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
        assert max(jax.tree_util.tree_leaves(delta)) > 0
    else:
        batch = _make_batch(plan, rng)
        out = jax.jit(plan.step_fn)(params, batch)
        _finite(out)


LM_ARCHS = [a for a in ALL_ARCHS
            if isinstance(reg.get(a).model, TransformerConfig)]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_reduced_decode_step(arch_id, rng):
    spec = reg.get(arch_id)
    model = reduce_arch(spec.model)
    from repro.models import transformer as tfm
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   api.param_specs(model))
    B, S = 2, 64
    cache = tfm.init_cache(model, B, S)
    tokens = jnp.asarray(rng.integers(0, model.vocab, (B, 1)), jnp.int32)
    step = jax.jit(lambda p, t, c, pos: tfm.decode_step(
        model, p, t, c, pos, RULES))
    logits, cache = step(params, tokens, cache, jnp.int32(0))
    assert logits.shape == (B, 1, model.vocab)
    assert bool(jnp.isfinite(logits).all())
    # a second step at pos 1 reuses the cache
    logits, cache = step(params, tokens, cache, jnp.int32(1))
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch_id", ["dit-s2", "dit-xl2"])
def test_reduced_gen_step(arch_id, rng):
    spec = reg.get(arch_id)
    model = reduce_arch(spec.model)
    from repro.models import dit as dit_lib
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   api.param_specs(model))
    side = 64 // model.vae_factor
    noise = jnp.asarray(rng.normal(size=(2, side, side, 4)), jnp.float32)
    out = jax.jit(lambda p, n: dit_lib.ddim_sample(
        model, p, n, jnp.asarray([0, 1]), RULES, n_steps=2))(params, noise)
    assert out.shape == noise.shape
    assert bool(jnp.isfinite(out).all())


def test_full_param_counts_sane():
    """Full-config param counts land in the right ballpark (the names)."""
    expect = {
        "deepseek-moe-16b": (14e9, 20e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),   # ~109B total
        "minitron-4b": (3.5e9, 6e9),
        "mistral-large-123b": (110e9, 130e9),
        "dit-s2": (25e6, 45e6),
        "dit-xl2": (550e6, 750e6),
        "deit-b": (80e6, 100e6),
        "vit-s16": (18e6, 30e6),
        "efficientnet-b7": (55e6, 80e6),
        "vit-b16": (80e6, 100e6),
    }
    for arch_id, (lo, hi) in expect.items():
        n = reg.get(arch_id).model.n_params
        assert lo <= n <= hi, f"{arch_id}: {n/1e9:.2f}B not in [{lo},{hi}]"


def test_moe_active_params_less_than_total():
    m = reg.get("deepseek-moe-16b").model
    assert m.n_active_params < m.n_params
    assert m.n_active_params > 1e9
