"""Algorithm 1 (adaptive frame partitioning): JAX + host implementations."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partitioning import (coverage, partition, partition_host,
                                     Patch)


def test_single_roi_single_patch():
    boxes = np.array([[10, 10, 50, 60]])
    patches = partition_host(boxes, 400, 300, 2, 2, align=1)
    assert len(patches) == 1
    p = patches[0]
    assert (p.x0, p.y0, p.x1, p.y1) == (10, 10, 50, 60)


def test_roi_affiliated_with_max_overlap_zone():
    # box mostly in zone (1,1) of a 2x2 grid on 400x300
    boxes = np.array([[190, 140, 390, 290]])   # mostly bottom-right
    patches = partition_host(boxes, 400, 300, 2, 2, align=1)
    assert len(patches) == 1


def test_enclosing_rect_covers_all_rois():
    boxes = np.array([[10, 10, 30, 30], [50, 50, 90, 90]])  # same zone
    patches = partition_host(boxes, 400, 300, 2, 2, align=1)
    assert len(patches) == 1
    p = patches[0]
    assert p.x0 <= 10 and p.y0 <= 10 and p.x1 >= 90 and p.y1 >= 90


def test_rois_split_across_zones():
    boxes = np.array([[10, 10, 30, 30], [310, 210, 370, 280]])
    patches = partition_host(boxes, 400, 300, 2, 2, align=1)
    assert len(patches) == 2


def test_alignment_rounds_up():
    boxes = np.array([[0, 0, 33, 17]])
    patches = partition_host(boxes, 400, 300, 2, 2, align=16)
    p = patches[0]
    assert p.w % 16 == 0 and p.h % 16 == 0
    assert p.w >= 33 and p.h >= 17


def test_jax_matches_host():
    rng = np.random.default_rng(3)
    for _ in range(10):
        n = rng.integers(1, 12)
        x0 = rng.integers(0, 350, n)
        y0 = rng.integers(0, 250, n)
        boxes = np.stack([x0, y0,
                          x0 + rng.integers(5, 50, n),
                          y0 + rng.integers(5, 50, n)], -1).astype(np.int32)
        jp, jv = partition(jnp.asarray(boxes), jnp.ones(n, bool),
                           400, 300, 4, 4, align=8)
        jboxes = sorted(map(tuple, np.asarray(jp)[np.asarray(jv)]))
        hp = partition_host(boxes, 400, 300, 4, 4, align=8)
        hboxes = sorted((p.x0, p.y0, p.x1, p.y1) for p in hp)
        assert jboxes == hboxes


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 20), st.integers(1, 6), st.integers(1, 6),
       st.integers(0, 10_000))
def test_every_roi_covered(n, zx, zy, seed):
    """Alg. 1 invariant: every RoI is fully inside its zone's patch."""
    rng = np.random.default_rng(seed)
    x0 = rng.integers(0, 300, n)
    y0 = rng.integers(0, 200, n)
    boxes = np.stack([x0, y0,
                      x0 + rng.integers(1, 90, n),
                      y0 + rng.integers(1, 90, n)], -1)
    boxes[:, 2] = boxes[:, 2].clip(max=400)
    boxes[:, 3] = boxes[:, 3].clip(max=300)
    patches = partition_host(boxes, 400, 300, zx, zy, align=1)
    assert coverage(patches, boxes) == 1.0


def test_coverage_proxy_detects_loss():
    patches = [Patch(0, 0, 50, 50)]
    boxes = np.array([[10, 10, 40, 40], [100, 100, 150, 150]])
    assert coverage(patches, boxes) == 0.5


def test_patch_metadata_deadline():
    p = Patch(0, 0, 10, 10, t_gen=2.0, slo=1.5)
    assert p.deadline == 3.5 and p.area == 100
