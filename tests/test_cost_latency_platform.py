"""Cost model (Eqn. 1), latency estimator, serverless platform model."""
import math

import pytest

from repro.core.cost import (CostMeter, P_C, P_G, P_M, P_REQ, alibaba_cost,
                             TPUCostModel)
from repro.core.latency import (AnalyticalLatencyModel, LatencyTable,
                                detector_latency_model, measure)
from repro.serverless.platform import Platform, PlatformConfig


class TestCost:
    def test_eqn1_hand_computed(self):
        # paper defaults: 2 vCPU, 4 GB mem, 6 GB GPU for 1 second
        expect = 1.0 * (2 * P_C + 4 * P_M + 6 * P_G) + P_REQ
        assert alibaba_cost(1.0) == pytest.approx(expect)
        assert alibaba_cost(0.0) == pytest.approx(P_REQ)

    def test_linear_in_time(self):
        c1 = alibaba_cost(1.0) - P_REQ
        c5 = alibaba_cost(5.0) - P_REQ
        assert c5 == pytest.approx(5 * c1)

    def test_meter_accumulates(self):
        m = CostMeter()
        a = m.charge(0.5)
        b = m.charge(1.5)
        assert m.total == pytest.approx(a + b)
        assert m.invocations == 2
        assert m.busy_seconds == pytest.approx(2.0)

    def test_tpu_model(self):
        tm = TPUCostModel(usd_per_chip_hour=3.6, chips=2)
        assert tm.cost(1.0) == pytest.approx(2 * 3.6 / 3600 + P_REQ)


class TestLatencyTable:
    def test_exact_and_interpolated(self):
        t = LatencyTable({1: (0.1, 0.01), 3: (0.3, 0.03)})
        assert t.mu_sigma(1) == (0.1, 0.01)
        mu, sg = t.mu_sigma(2)
        assert mu == pytest.approx(0.2)
        assert sg == pytest.approx(0.02)

    def test_extrapolation_above(self):
        t = LatencyTable({1: (0.1, 0.01), 2: (0.2, 0.01)})
        mu, _ = t.mu_sigma(4)
        assert mu == pytest.approx(0.4)

    def test_t_slack_conservative(self):
        t = LatencyTable({1: (0.1, 0.02)}, slack_sigmas=3.0)
        assert t.t_slack(1) == pytest.approx(0.1 + 3 * 0.02)
        assert t.t_slack(0) == 0.0

    def test_fractional_batch(self):
        t = LatencyTable({1: (0.1, 0.01), 2: (0.2, 0.02)})
        mu, _ = t.mu_sigma(1.5)
        assert mu == pytest.approx(0.15)

    def test_below_min_clamps_not_extrapolates(self):
        """Regression: a table starting at batch 4 must not scale mu
        through the origin for smaller batches — that drops the fixed
        per-invocation overhead (mu_sigma(1) used to return 0.025s here)
        and makes t_slack over-optimistic."""
        t = LatencyTable({4: (0.1, 0.01), 8: (0.18, 0.01)})
        assert t.mu_sigma(1) == (0.1, 0.01)
        assert t.mu_sigma(3) == (0.1, 0.01)
        # the conservative floor also keeps t_slack monotone in batch
        assert t.t_slack(1) == pytest.approx(t.t_slack(4))
        assert t.t_slack(0) == 0.0


class TestAnalyticalModel:
    def test_monotone_in_batch(self):
        m = detector_latency_model(256, 256)
        mus = [m.mu_sigma(b)[0] for b in (1, 2, 4, 8)]
        assert mus == sorted(mus)

    def test_overhead_floor(self):
        m = detector_latency_model(64, 64, overhead_s=0.004)
        assert m.mu_sigma(1)[0] >= 0.004

    def test_quadratic_attention_full_frame_penalty(self):
        """4K-as-one-input costs more than 8x one canvas (Masked Frame)."""
        canvas = detector_latency_model(1024, 1024)
        full4k = detector_latency_model(2160, 3840)
        ratio = full4k.flops_per_canvas / canvas.flops_per_canvas
        area_ratio = (2160 * 3840) / (1024 * 1024)
        assert ratio > area_ratio

    def test_build_table(self):
        t = detector_latency_model(256, 256).build_table(8)
        assert set(t.table) == set(range(1, 9))


class TestMeasure:
    def test_sync_hook_times_deferred_work(self):
        """An async-dispatching callable (jit-style: returns a handle
        immediately, compute finishes later) must be timed through the
        sync hook, not bare perf_counter around the dispatch."""
        import time as _time

        class Handle:
            def __init__(self, delay):
                self.delay = delay

        def dispatch(b):           # returns instantly, like jax jit
            return Handle(0.02 * b)

        def block(h):              # like jax.block_until_ready
            _time.sleep(h.delay)

        t_nosync = measure(dispatch, (1,), iters=3, warmup=0)
        t_sync = measure(dispatch, (1,), iters=3, warmup=0, sync=block)
        assert t_nosync.table[1][0] < 0.01      # dispatch only
        assert t_sync.table[1][0] >= 0.02       # waits for the "compute"

    def test_sync_hook_applied_during_warmup(self):
        seen = []
        measure(lambda b: b, (2,), iters=1, warmup=2, sync=seen.append)
        assert seen == [2, 2, 2]                # 2 warmups + 1 timed


class TestPlatform:
    def table(self):
        return LatencyTable({b: (0.05 * b, 0.0) for b in range(1, 17)})

    def test_deterministic_with_zero_sigma(self):
        p = Platform(self.table(), PlatformConfig(cold_start_s=0.1,
                                                  pre_warm=0))
        r = p.submit(0.0, 2)
        assert r.cold
        assert r.t_start == pytest.approx(0.1)
        assert r.t_finish == pytest.approx(0.1 + 0.1)

    def test_pre_warm_avoids_first_cold_start(self):
        p = Platform(self.table(), PlatformConfig(cold_start_s=0.1,
                                                  pre_warm=1))
        r = p.submit(0.0, 1)
        assert not r.cold
        assert r.t_start == pytest.approx(0.0)

    def test_warm_reuse(self):
        p = Platform(self.table(), PlatformConfig(cold_start_s=0.1,
                                                  keep_alive_s=60,
                                                  pre_warm=0))
        p.submit(0.0, 1)
        r2 = p.submit(1.0, 1)
        assert not r2.cold
        assert len(p.instances) == 1

    def test_concurrency_one_scales_out(self):
        p = Platform(self.table(), PlatformConfig(cold_start_s=0.0,
                                                  pre_warm=0))
        p.submit(0.0, 16)          # busy until 0.8
        p.submit(0.1, 16)          # needs a second instance
        assert len(p.instances) == 2

    def test_queueing_at_max_instances(self):
        p = Platform(self.table(), PlatformConfig(cold_start_s=0.0,
                                                  max_instances=1,
                                                  pre_warm=0))
        p.submit(0.0, 16)
        r = p.submit(0.1, 1)
        assert r.t_start >= 0.8    # waited for the busy instance

    def test_billing_matches_records(self):
        p = Platform(self.table(), PlatformConfig())
        for i in range(5):
            p.submit(i * 0.01, 1 + i % 3)
        assert p.total_cost == pytest.approx(sum(r.cost for r in p.records))

    def test_mru_warm_pick_fewer_cold_starts_on_bursty_trace(self):
        """_acquire prefers the most-recently-used warm instance (max
        ``warm_until``), concentrating traffic on a hot set whose leases
        the last burst already refreshed.  On this deterministic bursty
        trace (sigma=0: no sampling noise), first-free disperses work
        onto instances whose keep-alive lapses mid-burst and pays two
        extra cold starts; MRU also never leaves MORE of the fleet warm
        at the end (the idle tail cools instead of being churned)."""
        class FirstFreePlatform(Platform):
            # the pre-MRU policy, kept here as the comparison arm
            def _acquire(self, t, model=None, load_s=0.0):
                warm_free = [i for i in self.instances
                             if i.free_at <= t and i.warm_until >= t]
                if warm_free:
                    return warm_free[0], t, False, False
                return super()._acquire(t, model=model, load_s=load_s)

        bursts = [(2.259, 1), (2.358, 1), (3.924, 1), (4.034, 1), (4.14, 2),
                  (5.705, 1), (5.72, 1), (5.823, 1), (5.917, 1), (5.932, 1),
                  (6.261, 1), (7.092, 1), (7.185, 1), (7.246, 1), (8.514, 2),
                  (8.591, 1), (8.72, 1)]
        table = LatencyTable({1: (0.2, 0.0), 8: (1.6, 0.0)})
        cfg = PlatformConfig(cold_start_s=0.5, keep_alive_s=1.0,
                             max_instances=6, pre_warm=1)

        def run(cls):
            p = cls(table, cfg)
            for t, b in bursts:
                p.submit(t, b)
            t_end = max(r.t_finish for r in p.records)
            return (sum(r.cold for r in p.records),
                    sum(i.warm_until >= t_end for i in p.instances))

        mru_cold, mru_warm = run(Platform)
        ff_cold, ff_warm = run(FirstFreePlatform)
        assert mru_cold < ff_cold, (mru_cold, ff_cold)
        assert mru_warm <= ff_warm, (mru_warm, ff_warm)

    def test_hedged_backup_never_reuses_the_primary_instance(self):
        """Regression: the hedged ``_acquire`` used to run before the
        primary's busy interval was committed (``free_at`` still stale),
        so the backup could land on the very instance the primary was
        running on — two overlapping busy intervals billed against one
        concurrency-1 instance."""
        cfg = PlatformConfig(straggler_prob=1.0, straggler_factor=10,
                             backup_after_sigma=1.0, seed=1, pre_warm=2)
        p = Platform(LatencyTable({1: (0.1, 0.01)}), cfg)
        r = p.submit(0.0, 1)
        assert r.hedged
        assert r.backup_instance >= 0
        assert r.backup_instance != r.instance

    def test_no_overlapping_busy_intervals_no_double_billed_time(self):
        """Accounting audit under concurrently in-flight invocations and
        forced hedges: per-instance busy intervals never overlap, every
        billed second appears in exactly one interval
        (``sum(lengths) == busy_seconds``), and utilization over the
        makespan stays within [0, 1]."""
        cfg = PlatformConfig(cold_start_s=0.05, keep_alive_s=2.0,
                             max_instances=3, pre_warm=1,
                             straggler_prob=0.3, straggler_factor=6.0,
                             backup_after_sigma=1.0, seed=7)
        table = LatencyTable({b: (0.05 * b, 0.01) for b in range(1, 17)})
        p = Platform(table, cfg)
        for i, t in enumerate([0.0, 0.02, 0.05, 0.3, 0.31, 0.6, 0.9,
                               1.4, 1.41, 1.8]):
            p.submit(t, 1 + i % 4)
        assert any(r.hedged for r in p.records)

        intervals = p.busy_intervals()
        assert set(intervals) <= set(range(len(p.instances)))
        total = 0.0
        for idx, iv in intervals.items():
            for (a0, a1), (b0, b1) in zip(iv, iv[1:]):
                assert a1 <= b0 + 1e-9, \
                    f"overlapping busy intervals on instance {idx}"
            total += sum(e - s for s, e in iv)
        assert total == pytest.approx(p.meter.busy_seconds)
        horizon = max(r.t_finish for r in p.records)
        assert 0.0 < p.utilization(horizon) <= 1.0

    def test_straggler_hedging_bounds_tail(self):
        cfg_nohedge = PlatformConfig(straggler_prob=1.0, straggler_factor=10,
                                     seed=1)
        cfg_hedge = PlatformConfig(straggler_prob=1.0, straggler_factor=10,
                                   backup_after_sigma=1.0, seed=1)
        t = LatencyTable({1: (0.1, 0.01)})
        slow = Platform(t, cfg_nohedge).submit(0.0, 1)
        # hedged backup is also a straggler here, but it starts early and
        # the min() still bounds the tail vs no hedging at all
        hedged = Platform(t, cfg_hedge).submit(0.0, 1)
        assert hedged.hedged
        assert hedged.t_finish <= slow.t_finish + 1e-9
