"""Int8-resident serving through the model registry: spec economics,
distinct latency profiles, ServeConfig round-trips, and quantized builds
that track their full-precision base model."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ServeConfig
from repro.core.latency import LatencyBank, LatencyTable
from repro.core.models import ModelSpec, make_model


def test_int8_specs_registered_with_smaller_weights():
    for base in ("tangram", "vit_s16"):
        fp = make_model(base)
        q = make_model(f"{base}_int8")
        assert q.dtype == "int8"
        assert q.weight_bytes < fp.weight_bytes, base
        # same trunk, same canvas geometry — only residency differs
        assert (q.canvas_m, q.canvas_n) == (fp.canvas_m, fp.canvas_n)


def test_int8_latency_profile_is_distinct():
    fp = make_model("tangram").latency_table(max_batch=8)
    q = make_model("tangram_int8").latency_table(max_batch=8)
    mu_fp, _ = fp.mu_sigma(8)
    mu_q, _ = q.mu_sigma(8)
    # 2x MXU rate + halved weight streaming: faster in both regimes
    assert mu_q < mu_fp

    bank = LatencyBank({"tangram": fp, "tangram_int8": q})
    assert bank.table("tangram") is not bank.table("tangram_int8")
    assert bank.table("tangram_int8").mu_sigma(8)[0] < \
        bank.table("tangram").mu_sigma(8)[0]


def test_serve_config_int8_fused_roundtrip():
    cfg = ServeConfig(executor="device", fuse=True, quantize=True,
                      classify="slo", model="tangram",
                      model_map={"0.6": "tangram_int8"})
    d = json.loads(json.dumps(cfg.to_dict()))
    back = ServeConfig.from_dict(d)
    assert back == cfg
    assert back.fuse and back.quantize
    assert back.model_names() == ["tangram", "tangram_int8"]
    assert back.resolve_model(0.6) == "tangram_int8"
    assert back.resolve_model(2.0) == "tangram"


def test_int8_build_is_quantized_base_model():
    """tangram_int8 builds the tangram weights quantized: int8 leaves in
    the trunk, quant_weights threaded into the config, and outputs that
    track the full-precision build closely."""
    cfg_q, params_q, serve_q, _ = make_model("tangram_int8").build(canvas=128)
    cfg_fp, params_fp, serve_fp, _ = make_model("tangram").build(canvas=128)
    assert cfg_q.quant_weights and not cfg_fp.quant_weights

    leaves_q = jax.tree_util.tree_leaves(params_q)
    assert any(l.dtype == jnp.int8 for l in leaves_q)
    assert not any(l.dtype == jnp.int8
                   for l in jax.tree_util.tree_leaves(params_fp))
    nbytes = lambda ls: sum(np.asarray(l).nbytes for l in ls)
    assert nbytes(leaves_q) < nbytes(jax.tree_util.tree_leaves(params_fp))

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 128, 128, 3)), jnp.float32)
    obj_q, _ = serve_q(params_q, x)
    obj_fp, _ = serve_fp(params_fp, x)
    a = np.asarray(obj_q, np.float32).ravel()
    b = np.asarray(obj_fp, np.float32).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.98, corr


def test_modelspec_rejects_unknown_dtype():
    with pytest.raises(ValueError, match="unsupported dtype"):
        ModelSpec(name="bad-dtype", canvas_m=64, canvas_n=64,
                  weight_bytes=1e6,
                  table=LatencyTable({1: (0.1, 0.01)}), dtype="int4")
