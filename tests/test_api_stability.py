"""Public-API stability: the exported surface is a contract.

A snapshot of the names downstream code (benchmarks, the serve driver,
sibling PRs) imports from each public module, plus the registry contents
behind the named-reference factories.  Renaming or dropping any of these
is a breaking change and must update this file *deliberately* — the test
failing is the review speed-bump.

New names may be added freely (the assertions are superset checks);
removals and renames fail.
"""
import dataclasses

import pytest

#: module -> names that must stay importable from it
PUBLIC_API = {
    "repro.core.engine": {
        "AsyncDeviceExecutor", "DeviceExecutor", "ExecHandle", "Invocation",
        "InvokerPool", "ModelRuntime", "PatchOutcome", "Results",
        "ServingEngine", "SimExecutor", "make_executor", "shard_canvases",
        "slo_class", "uniform_pool",
    },
    "repro.core.scheduler": {
        "PatchOutcome", "Results", "ServeConfig", "TangramScheduler",
    },
    "repro.core.config": {
        "ServeConfig", "make_classify", "register_classify",
    },
    "repro.core.clock": {
        "BarrierVirtualClock", "Clock", "VirtualClock", "WallClock",
        "make_clock",
    },
    "repro.core.parallel": {
        "ParallelShardedEngine", "ShardRunner",
    },
    "repro.core.framestore": {
        "FrameStore",
    },
    "repro.core.latency": {
        "LatencyBank", "LatencyTable", "OnlineLatencyTable",
        "latency_from_dict", "measure",
    },
    "repro.core.workers": {
        "ReservedClassPlacement", "WeightCache", "WorkerPoolExecutor",
        "device_worker_pool", "make_placement", "weight_caches",
    },
    "repro.core.fleet": {
        "EqualSplitPlanner", "FleetCostModel", "FleetInvokerPool",
        "FleetPlan", "FleetPlanner", "ReservedClassPlacement",
        "ShardedEngine", "fleet_uniform_pool", "make_planner",
    },
    "repro.core.models": {
        "ModelSpec", "make_model", "model_names", "register_model",
    },
    "repro.core.rois": {
        "RoIConfig", "extract_rois", "extract_rois_jit",
    },
    "repro.core.adaptive": {
        "AIMDConfig", "adaptive_uniform_pool",
    },
    "repro.data.video": {
        "Arrival", "Uplink", "load_frames", "merge_arrivals",
        "patch_bytes", "shape_arrivals",
    },
    "repro.sources": {
        "EdgePipeline", "FileStreamSource", "FleetCameraSource",
        "LiveSource", "MergedSource", "RateProfile", "Source",
        "SourceStats", "SyntheticCameraSource", "TraceSource",
        "make_source", "register_source",
    },
}

#: factory -> names that must stay registered (ServeConfig's named
#: references and the CLI choices resolve through these)
REGISTRIES = {
    "source": ("trace", "synthetic", "file", "fleet"),
    "clock": ("virtual", "wall"),
    "executor": ("sim", "device", "async_device"),
    "placement": ("least", "round", "affinity", "model"),
    "model": ("tangram", "vit_s16", "efficientnet_b7",
              "tangram_int8", "vit_s16_int8"),
    "planner": ("cost", "equal"),
}

#: the ServeConfig record itself is serialized into benchmark JSON;
#: field renames/removals break old reports' from_dict
SERVE_CONFIG_FIELDS = {
    "max_canvases", "incremental", "classify", "adaptive",
    "executor", "use_pallas", "fuse", "quantize", "max_inflight",
    "clock", "wall_speed", "check_invariants", "n_workers", "placement",
    "online_latency", "source", "ingestion_window", "model", "model_map",
    "shards", "planner", "parallel",
}


@pytest.mark.parametrize("module_name", sorted(PUBLIC_API))
def test_module_exports(module_name):
    import importlib
    mod = importlib.import_module(module_name)
    missing = {n for n in PUBLIC_API[module_name] if not hasattr(mod, n)}
    assert not missing, (f"{module_name} lost public names: "
                         f"{sorted(missing)}")


def test_source_registry():
    from repro.sources.base import _SOURCES
    assert set(REGISTRIES["source"]) <= set(_SOURCES)


def test_clock_registry():
    from repro.core.clock import _CLOCKS
    assert set(REGISTRIES["clock"]) <= set(_CLOCKS)


def test_executor_registry():
    from repro.core.engine import _EXECUTORS
    assert set(REGISTRIES["executor"]) <= set(_EXECUTORS)


def test_placement_registry():
    from repro.core.workers import make_placement
    for name in REGISTRIES["placement"]:
        assert make_placement(name) is not None


def test_planner_registry():
    from repro.core.fleet import _PLANNERS
    assert set(REGISTRIES["planner"]) <= set(_PLANNERS)


def test_model_registry():
    from repro.core.models import model_names
    assert set(REGISTRIES["model"]) <= set(model_names())


def test_serve_config_fields_stable():
    from repro.core.config import ServeConfig
    fields = {f.name for f in dataclasses.fields(ServeConfig)}
    missing = SERVE_CONFIG_FIELDS - fields
    assert not missing, f"ServeConfig lost fields: {sorted(missing)}"


def test_sources_all_is_accurate():
    import repro.sources as sources
    for name in sources.__all__:
        assert hasattr(sources, name), name
