"""Sequence packing — the stitching idea applied to LM serving."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.latency import LatencyTable
from repro.core.sequence_packing import (Request, SequencePacker,
                                         attention_mask_blocks, pack,
                                         packing_efficiency)


def reqs(lengths, slo=1.0):
    return [Request(n, t_gen=0.0, slo=slo, request_id=i)
            for i, n in enumerate(lengths)]


class TestPack:
    def test_best_fit_chooses_tightest_row(self):
        rows = pack(reqs([700, 200, 300]), 1024)
        # 200 joins the 700 row (free 324); 300 no longer fits -> new row
        assert len(rows) == 2
        assert rows[0].used == 900
        assert rows[1].used == 300
        # best-fit: a later 100 prefers row0 (free 124) over row1 (free 724)
        rows = pack(reqs([700, 200, 300, 100]), 1024)
        assert rows[0].used == 1000

    def test_oversized_raises(self):
        with pytest.raises(ValueError):
            pack(reqs([2000]), 1024)

    def test_mask_blocks_align_with_spans(self):
        rows = pack(reqs([100, 200]), 512)
        blocks = attention_mask_blocks(rows)
        assert blocks[0] == [(0, 100), (100, 300)]

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(1, 1024), min_size=1, max_size=50))
    def test_invariants(self, lengths):
        rows = pack(reqs(lengths), 1024)
        # every request placed exactly once, spans within rows, no overlap
        seen = []
        for row in rows:
            pos = 0
            for (idx, s, e) in row.spans:
                assert s == pos and e <= 1024
                pos = e
                seen.append(idx)
        assert sorted(seen) == list(range(len(lengths)))
        assert sum(r.used for r in rows) == sum(lengths)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(1, 1024), min_size=1, max_size=50))
    def test_row_lower_bound(self, lengths):
        rows = pack(reqs(lengths), 1024)
        assert len(rows) >= math.ceil(sum(lengths) / 1024)


class TestSequencePackerInvoker:
    def test_reuses_slo_invoker(self):
        table = LatencyTable({b: (0.05 * b, 0.005) for b in range(1, 65)})
        sp = SequencePacker(1024, table)
        assert sp.on_request(0.0, Request(600, 0.0, 1.0, 0)) == []
        assert sp.on_request(0.1, Request(300, 0.1, 1.0, 1)) == []
        t = sp.next_timer()
        assert 0 < t < 1.0
        inv = sp.poll(t)
        assert inv is not None
        assert len(inv.patches) == 2
        assert inv.batch_size == 1        # both packed into one row
