"""int8-resident weights: numerics vs fp, decode path, spec structure."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import param as P
from repro.config import MoEConfig, TransformerConfig
from repro.models import quantize, transformer as tfm
from repro.sharding import DEFAULT_RULES as R

BASE = TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, param_dtype="float32", compute_dtype="float32",
    remat=False)


def _pair(cfg):
    params = P.init_params(jax.random.PRNGKey(0), tfm.param_specs(cfg))
    qcfg = dataclasses.replace(cfg, quant_weights=True)
    qparams = quantize.quantize_params(tfm.param_specs(qcfg), params)
    return cfg, params, qcfg, qparams


def test_quant_loss_close_to_fp():
    cfg, params, qcfg, qparams = _pair(BASE)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    l0 = float(tfm.lm_loss(cfg, params, batch, R))
    l1 = float(tfm.lm_loss(qcfg, qparams, batch, R))
    assert abs(l1 - l0) / l0 < 0.05, (l0, l1)


def test_quant_decode_matches_fp_top1():
    cfg, params, qcfg, qparams = _pair(BASE)
    tokens = jnp.asarray([[7], [13]], jnp.int32)
    cache = tfm.init_cache(cfg, 2, 16)
    qcache = tfm.init_cache(qcfg, 2, 16)
    l0, _ = tfm.decode_step(cfg, params, tokens, cache, jnp.int32(0), R)
    l1, _ = tfm.decode_step(qcfg, qparams, tokens, qcache, jnp.int32(0), R)
    assert bool(jnp.isfinite(l1).all())
    # logits correlation stays high under int8
    c = np.corrcoef(np.asarray(l0).ravel(), np.asarray(l1).ravel())[0, 1]
    assert c > 0.99, c


def test_quant_moe_variant():
    cfg = dataclasses.replace(
        BASE, moe=MoEConfig(n_experts=4, top_k=2, n_shared=1,
                            d_ff_expert=32, group_size=32))
    cfg, params, qcfg, qparams = _pair(cfg)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    l0 = float(tfm.lm_loss(cfg, params, batch, R))
    l1 = float(tfm.lm_loss(qcfg, qparams, batch, R))
    assert abs(l1 - l0) / l0 < 0.08, (l0, l1)


def test_quant_kv_cache_decode():
    """int8 KV cache: multi-step decode stays close to the fp cache."""
    cfg, params, _, _ = _pair(BASE)
    qkv_cfg = dataclasses.replace(BASE, quant_kv=True)
    rng = np.random.default_rng(0)
    cache_fp = tfm.init_cache(cfg, 2, 32)
    cache_q = tfm.init_cache(qkv_cfg, 2, 32)
    assert cache_q["k"].dtype == jnp.int8
    for pos in range(6):
        tok = jnp.asarray(rng.integers(0, 256, (2, 1)), jnp.int32)
        l_fp, cache_fp = tfm.decode_step(cfg, params, tok, cache_fp,
                                         jnp.int32(pos), R)
        l_q, cache_q = tfm.decode_step(qkv_cfg, params, tok, cache_q,
                                       jnp.int32(pos), R)
    c = np.corrcoef(np.asarray(l_fp).ravel(), np.asarray(l_q).ravel())[0, 1]
    assert c > 0.995, c


def test_quant_param_bytes_shrink():
    qcfg = dataclasses.replace(BASE, quant_weights=True)
    def nbytes(specs):
        return sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
                   for s in jax.tree_util.tree_leaves(
                       specs, is_leaf=lambda x: isinstance(x, P.ParamSpec)))
    fp = nbytes(tfm.param_specs(BASE))
    q = nbytes(tfm.param_specs(qcfg))
    assert q < 0.45 * fp  # ~4x on the quantized kernels (fp32 baseline)
