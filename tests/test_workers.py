"""Multi-worker device pool: placement, out-of-order harvest, determinism,
per-worker capacity agreement, and the online latency estimator loop.

Covers the PR acceptance criteria:

* boundary identity — a 1-worker ``WorkerPoolExecutor`` groups patches
  into the exact invocations (and routes the exact detections) of the
  plain ``AsyncDeviceExecutor``, and Sim (per-worker platform capacity
  shards) agrees with Device (per-worker executors) on boundaries;
* head-of-line harvest fix — a slow batch on one worker no longer pins
  completed batches on another worker in flight;
* deterministic event ordering — simultaneously-ready completions
  deliver in pinned ``(worker index, submit seq)`` order;
* drifted device — an ``OnlineLatencyTable`` fed by the pool cuts SLO
  violations versus the static profile when the device is slower than
  profiled;
* per-worker utilization and per-class violation breakdown in
  ``Results.summary()``.
"""
import math

import numpy as np
import pytest

from repro.core.clock import WallClock
from repro.core.devicestub import StubAccelerator, VirtualAccelerator
from repro.core.engine import (AsyncDeviceExecutor, Completion, ExecHandle,
                               ServingEngine, SimExecutor, slo_class,
                               uniform_pool)
from repro.core.invoker import Invocation
from repro.core.latency import LatencyTable, OnlineLatencyTable
from repro.core.partitioning import Patch
from repro.core.workers import (ClassAffinityPlacement,
                                LeastOutstandingPlacement,
                                RoundRobinPlacement, WorkerPoolExecutor,
                                device_worker_pool, make_placement,
                                share_frame_store)
from repro.data.video import Arrival
from repro.serverless.platform import (Platform, PlatformConfig,
                                       split_platform)


def table(mu=0.1, sigma=0.01, n=32):
    return LatencyTable({b: (mu * b, sigma) for b in range(1, n + 1)},
                        slack_sigmas=3.0)


def arrivals_of(patches):
    return [Arrival(p.t_gen, p, 0.0) for p in patches]


def fake_serve_fn(params, x):
    import jax.numpy as jnp
    return (jnp.zeros((x.shape[0], 2, 2)),
            jnp.zeros((x.shape[0], 2, 2, 4)))


def trace_for_device(n=24, seed=3):
    rng = np.random.default_rng(seed)
    ps = []
    for i in range(n):
        t = round(float(rng.uniform(0, 4.0)), 3)
        w = int(rng.integers(8, 64))
        h = int(rng.integers(8, 64))
        ps.append(Patch(0, 0, w, h, frame_id=i // 3, t_gen=t,
                        slo=float(rng.choice([0.6, 2.0]))))
    return sorted(ps, key=lambda p: p.t_gen)


def _groups(engine, trace):
    idx = {id(p): i for i, p in enumerate(trace)}
    return [[idx[id(p)] for p in inv.patches] for inv in engine.invocations]


def _inv(key=None, n_patches=1, t=0.0):
    ps = [Patch(0, 0, 16, 16, t_gen=t, slo=1.0) for _ in range(n_patches)]
    return Invocation(t, [], ps, 0.0, "timer", key=key)


class _ManualWorker:
    """Submit/complete worker with hand-controlled readiness: handles
    become ready only when the test releases them, and every completion
    reports the same finish time — the pinned-tie-break scenario."""

    def __init__(self, t_finish=1.0, max_inflight=None):
        self.t_finish = t_finish
        self.released = False
        self.submitted = []
        if max_inflight is not None:
            self.max_inflight = max_inflight

    def submit(self, inv):
        self.submitted.append(inv)
        return ExecHandle(inv, t_finish=None)

    def ready(self, handle):
        return self.released

    def resolve(self, handle):
        return Completion(handle.invocation, self.t_finish)


class _FixedPlacement:
    """Route invocation k to ``sequence[k]`` (test determinism helper)."""

    def __init__(self, sequence):
        self.sequence = list(sequence)
        self._k = 0

    def choose(self, inv, pool):
        idx = self.sequence[self._k % len(self.sequence)]
        self._k += 1
        return idx


# ------------------------------------------------- boundary identity ----

def test_one_worker_pool_matches_async_executor_boundaries():
    """Acceptance: the pool facade is invisible at 1 worker — identical
    invocation boundaries to the plain AsyncDeviceExecutor."""
    trace = trace_for_device()
    lat = table()

    def run(executor):
        eng = ServingEngine(uniform_pool(64, 64, lat, classify=slo_class),
                            executor)
        eng.run(arrivals_of(trace))
        return eng

    plain = run(AsyncDeviceExecutor(fake_serve_fn, None, 64, 64,
                                    max_inflight=2))
    pooled = run(device_worker_pool(
        1, lambda i: AsyncDeviceExecutor(fake_serve_fn, None, 64, 64,
                                         max_inflight=2)))
    assert _groups(pooled, trace) == _groups(plain, trace)


def test_sim_and_device_pools_agree_with_per_worker_capacity():
    """Acceptance: per-worker platform capacity shards (Sim) and
    per-worker device executors (Device) produce identical invocation
    boundaries for the same trace and pool size."""
    trace = trace_for_device()
    lat = table()

    def run(executor):
        eng = ServingEngine(uniform_pool(64, 64, lat, classify=slo_class),
                            executor)
        eng.run(arrivals_of(trace))
        return eng

    base = Platform(lat, PlatformConfig(max_instances=8))
    sim = run(WorkerPoolExecutor(
        [SimExecutor(p) for p in split_platform(base, 2)]))
    dev = run(device_worker_pool(
        2, lambda i: AsyncDeviceExecutor(fake_serve_fn, None, 64, 64,
                                         max_inflight=2)))
    assert _groups(sim, trace) == _groups(dev, trace)
    assert len(sim.outcomes) == len(dev.outcomes) == len(trace)


def detecting_serve_fn(params, x):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def go(x):
        b, m, n, _ = x.shape
        s = 4
        obj = x.reshape(b, s, m // s, s, n // s, 3).mean(axis=(2, 4, 5))
        ys, xs = jnp.meshgrid(jnp.arange(s), jnp.arange(s), indexing="ij")
        cw, ch = n // s, m // s
        boxes = jnp.stack([xs * cw, ys * ch, (xs + 1) * cw, (ys + 1) * ch],
                          axis=-1).astype(jnp.float32)
        return obj, jnp.broadcast_to(boxes, (b, s, s, 4))

    return go(x)


class _CaptureAsync(AsyncDeviceExecutor):
    def __init__(self, captured, *a, **k):
        super().__init__(*a, **k)
        self.captured = captured

    def on_complete(self, comp):
        per_frame, _ = comp.outputs
        for fid, dets in per_frame.items():
            self.captured.setdefault(fid, []).extend(dets)
        super().on_complete(comp)


def _frames_and_trace(n_frames=4, per_frame=3, seed=7):
    rng = np.random.default_rng(seed)
    frames, ps = {}, []
    for fid in range(n_frames):
        px = rng.uniform(0.0, 1.0, size=(64, 128, 3)).astype(np.float32)
        px[:, : 32 * (fid % 3)] = 0.9
        frames[fid] = px
        for j in range(per_frame):
            x0 = int(rng.integers(0, 64))
            y0 = int(rng.integers(0, 32))
            ps.append(Patch(x0, y0, x0 + int(rng.integers(16, 64)),
                            y0 + int(rng.integers(16, 32)), frame_id=fid,
                            t_gen=round(0.3 * fid + 0.07 * j, 3), slo=0.5))
    return frames, sorted(ps, key=lambda p: p.t_gen)


def _sorted_dets(captured):
    return {fid: sorted((round(s, 5), tuple(round(v, 3) for v in box))
                        for s, box in dets)
            for fid, dets in captured.items()}


@pytest.mark.parametrize("n_workers", [1, 2])
def test_pool_routes_identical_detections_to_plain_async(n_workers):
    """Acceptance: routed detections are identical between the plain
    async executor and an n-worker pool (shared frame store, any
    placement interleaving)."""
    frames, trace = _frames_and_trace()
    counts = {}
    for p in trace:
        counts[p.frame_id] = counts.get(p.frame_id, 0) + 1

    def run(executor):
        for fid, px in frames.items():
            executor.add_frame(fid, px, counts.get(fid, 0))
        eng = ServingEngine(uniform_pool(64, 64, table()), executor)
        eng.run(arrivals_of(trace))
        return eng

    plain_cap = {}
    plain = _CaptureAsync(plain_cap, detecting_serve_fn, None, 64, 64,
                          max_inflight=2)
    run(plain)

    pool_cap = {}
    pool = device_worker_pool(
        n_workers,
        lambda i: _CaptureAsync(pool_cap, detecting_serve_fn, None, 64, 64,
                                max_inflight=2))
    eng = run(pool)

    assert plain_cap, "trace produced no detections to compare"
    assert _sorted_dets(pool_cap) == _sorted_dets(plain_cap)
    assert pool.n_detections == plain.n_detections
    # shared frame store fully drained even when different workers route
    # different patches of the same frame
    assert pool.frames == {}
    for w in pool.workers:
        assert w.frames == {} and w._refs == {}
    assert len(eng.outcomes) == len(trace)


# ------------------------------------------- head-of-line harvest fix ----

def _warm_stitch_jits():
    """Compile the stitch/unstitch jits for the 64x64/32x32 shapes the
    wall-clock test below uses, so compilation time cannot eat into its
    timing margins on a cold process."""
    with StubAccelerator(service_s=0.0) as stub:
        dev = AsyncDeviceExecutor(stub.serve_fn, None, 64, 64,
                                  max_inflight=1, sync=stub.sync)
        eng = ServingEngine(uniform_pool(64, 64, table()), dev)
        eng.run(arrivals_of([Patch(0, 0, 32, 32, frame_id=0, t_gen=0.0,
                                   slo=1e-6)]))


def test_slow_worker_does_not_pin_fast_workers_completions():
    """Regression (head-of-line harvest bug): only the FIFO head used to
    be probed, so one slow batch pinned completed later batches in
    flight.  Two stub workers with very unequal service times: the fast
    worker's completion must be delivered while the slow one is still in
    flight."""
    _warm_stitch_jits()
    with StubAccelerator(service_s=0.5) as slow, \
            StubAccelerator(service_s=0.02) as fast:
        stubs = [slow, fast]
        workers = [AsyncDeviceExecutor(s.serve_fn, None, 64, 64,
                                       max_inflight=4, sync=s.sync)
                   for s in stubs]
        share_frame_store(workers)
        pool = WorkerPoolExecutor(workers,
                                  placement=_FixedPlacement([0, 1, 1]))
        # immediate "late" fires: one single-patch invocation per arrival
        ps = [Patch(0, 0, 32, 32, frame_id=i, t_gen=0.05 * i, slo=1e-6)
              for i in range(3)]
        eng = ServingEngine(uniform_pool(64, 64, table()), pool,
                            clock=WallClock(speed=1.0))
        # the trailing arrival lands ~0.25s (wall) after the fast worker
        # finished and while the slow worker is still busy: the harvest
        # at that arrival must deliver the fast completion out of order
        ps.append(Patch(0, 0, 32, 32, frame_id=3, t_gen=0.35, slo=1e-6))
        eng.run(arrivals_of(ps))

    assert len(eng.completions) == 4
    first = eng.completions[0]
    assert first.worker == 1, (
        "fast worker's completion was pinned behind the slow FIFO head: "
        f"delivered {[c.worker for c in eng.completions]}")
    # and the slow invocation still completes, after the fast ones
    assert {c.worker for c in eng.completions} == {0, 1}
    # the fast worker's finish is not clamped up to the slow worker's
    # (monotone clamp is per worker, not global)
    w0_first = next(c.t_finish for c in eng.completions if c.worker == 0)
    w1_first = next(c.t_finish for c in eng.completions if c.worker == 1)
    assert w1_first < w0_first
    by_worker = {}
    for c in eng.completions:
        by_worker.setdefault(c.worker, []).append(c.t_finish)
    for fins in by_worker.values():
        assert fins == sorted(fins)     # per-worker monotone preserved


# ------------------------------------------- deterministic ordering ----

def test_simultaneously_ready_completions_deliver_in_worker_seq_order():
    """Pinned tie-break: when several in-flight handles report ready at
    the same harvest, delivery order is (worker index, submit seq) —
    multi-worker replays are reproducible."""

    def run_once():
        workers = [_ManualWorker() for _ in range(3)]
        pool = WorkerPoolExecutor(workers,
                                  placement=RoundRobinPlacement())
        eng = ServingEngine(uniform_pool(64, 64, table()), pool)
        ps = [Patch(0, 0, 32, 32, frame_id=i, t_gen=0.0, slo=1e-6)
              for i in range(6)]
        for a in arrivals_of(ps):
            eng.offer(a)
        assert len(eng._inflight) == 6
        for w in workers:
            w.released = True          # everything becomes ready at once
        eng.finish()
        return [c.invocation.patches[0].frame_id for c in eng.completions]

    order = run_once()
    # round-robin over 3 workers: submit order 0..5 lands on workers
    # [0,1,2,0,1,2]; (worker, seq) delivery groups by worker first
    assert order == [0, 3, 1, 4, 2, 5]
    assert run_once() == order          # reproducible across replays


# ------------------------------------------------- placement policies ----

def test_least_outstanding_placement_spreads_load():
    workers = [_ManualWorker() for _ in range(3)]
    pool = WorkerPoolExecutor(workers, placement=LeastOutstandingPlacement())
    for _ in range(6):
        pool.submit(_inv())
    assert pool.outstanding == [2, 2, 2]
    assert [len(w.submitted) for w in workers] == [2, 2, 2]


def test_least_outstanding_prefers_drained_worker():
    workers = [_ManualWorker() for _ in range(2)]
    pool = WorkerPoolExecutor(workers)
    h0 = pool.submit(_inv())
    pool.submit(_inv())
    workers[0].released = True
    pool.resolve(h0)                    # worker 0 drains
    pool.submit(_inv())
    assert len(workers[0].submitted) == 2


def test_class_affinity_reserves_workers_for_tight_class():
    workers = [_ManualWorker() for _ in range(3)]
    pool = WorkerPoolExecutor(
        workers,
        placement=ClassAffinityPlacement(reserved={0.2: (0,)}))
    for _ in range(2):
        pool.submit(_inv(key=0.2))      # tight class -> reserved worker 0
    for _ in range(4):
        pool.submit(_inv(key=2.0))      # loose class -> workers 1 and 2
    assert len(workers[0].submitted) == 2
    assert all(inv.key == 0.2 for inv in workers[0].submitted)
    assert len(workers[1].submitted) == 2 and len(workers[2].submitted) == 2
    assert all(inv.key == 2.0
               for w in workers[1:] for inv in w.submitted)


def test_class_affinity_reserve_tightest_dynamic():
    workers = [_ManualWorker() for _ in range(2)]
    pool = WorkerPoolExecutor(
        workers, placement=ClassAffinityPlacement(reserve_tightest=1))
    pool.submit(_inv(key=0.5))          # single class yet: no reservation
    pool.submit(_inv(key=2.0))          # second class appears -> worker 1
    pool.submit(_inv(key=2.0))
    assert len(workers[0].submitted) == 1
    assert len(workers[1].submitted) == 2


def test_class_affinity_single_class_uses_whole_pool():
    """reserve_tightest must not degenerate a single-class workload to
    one worker: with no second class there is nothing to protect, so
    placement spreads least-outstanding over every worker."""
    workers = [_ManualWorker() for _ in range(3)]
    pool = WorkerPoolExecutor(
        workers, placement=ClassAffinityPlacement(reserve_tightest=1))
    for _ in range(6):
        pool.submit(_inv(key=None))     # serve driver's default classify
    assert [len(w.submitted) for w in workers] == [2, 2, 2]


def test_make_placement_names():
    assert isinstance(make_placement("least"), LeastOutstandingPlacement)
    assert isinstance(make_placement("round"), RoundRobinPlacement)
    assert isinstance(make_placement("affinity"), ClassAffinityPlacement)
    with pytest.raises(ValueError):
        make_placement("nope")


def test_pool_requires_workers_and_valid_placement_choice():
    with pytest.raises(ValueError):
        WorkerPoolExecutor([])
    pool = WorkerPoolExecutor([_ManualWorker()],
                              placement=_FixedPlacement([5]))
    with pytest.raises(ValueError):
        pool.submit(_inv())


def test_pool_max_inflight_sums_worker_bounds():
    workers = [AsyncDeviceExecutor(fake_serve_fn, None, 64, 64,
                                   max_inflight=3) for _ in range(2)]
    assert WorkerPoolExecutor(workers).max_inflight == 6
    assert not hasattr(WorkerPoolExecutor([_ManualWorker()]), "max_inflight")


def test_per_worker_inflight_bound_is_hard_under_skewed_placement():
    """A worker's own max_inflight is a device-memory bound: a placement
    that keeps choosing a saturated worker is overridden and the
    overflow re-routed to a worker with room."""
    workers = [_ManualWorker(max_inflight=2) for _ in range(2)]
    pool = WorkerPoolExecutor(workers, placement=_FixedPlacement([0]))
    for _ in range(4):
        pool.submit(_inv())
    assert pool.outstanding == [2, 2]
    assert len(workers[0].submitted) == 2
    assert len(workers[1].submitted) == 2


# ------------------------------------------------ online latency loop ----

def _drift_run(online: bool, service_s=0.06, n=20, slo=0.1, spacing=0.15):
    """Serve evenly-spaced single-patch invocations on a deterministic
    engine-time device that is much slower than its profile."""
    seed = LatencyTable({1: (0.004, 0.0005), 2: (0.008, 0.001)},
                        slack_sigmas=3.0)
    lat = OnlineLatencyTable(seed) if online else seed
    dev = VirtualAccelerator(service_s)
    pool = WorkerPoolExecutor([dev],
                              estimator=lat if online else None)
    eng = ServingEngine(uniform_pool(64, 64, lat), pool)
    ps = [Patch(0, 0, 32, 32, frame_id=i, t_gen=round(i * spacing, 4),
                slo=slo) for i in range(n)]
    eng.run(arrivals_of(ps))
    assert len(eng.outcomes) == len(ps)
    return eng


def test_online_latency_cuts_violations_on_drifted_device():
    """Acceptance: the device runs 15x slower than its offline profile;
    the static table keeps firing too late (every deadline missed), the
    online table learns the real service time after the first completions
    and the violation rate collapses."""
    static = _drift_run(online=False)
    online = _drift_run(online=True)
    v_static = sum(o.violated for o in static.outcomes)
    v_online = sum(o.violated for o in online.outcomes)
    assert v_static == len(static.outcomes), \
        "static arm unexpectedly met deadlines — drift scenario broken"
    assert v_online < v_static
    assert v_online <= 2                # only the pre-feedback prefix


def test_pool_over_sync_device_executor_feeds_estimator():
    """A 1-worker pool around the *sync* DeviceExecutor (the serve
    driver's --online-latency without --async-device) keeps synchronous
    execution semantics while feeding every completion to the
    estimator."""
    from repro.core.engine import DeviceExecutor

    est = OnlineLatencyTable(table())
    pool = WorkerPoolExecutor([DeviceExecutor(fake_serve_fn, None, 64, 64)],
                              estimator=est)
    eng = ServingEngine(uniform_pool(64, 64, est), pool)
    ps = [Patch(0, 0, 32, 32, frame_id=i, t_gen=0.3 * i, slo=1.0)
          for i in range(4)]
    eng.run(arrivals_of(ps))
    assert len(eng.outcomes) == len(ps)
    assert eng.inflight_high_water == 0     # still fully synchronous
    assert est.n_observations == len(eng.invocations) > 0


def test_online_latency_estimator_tracks_per_worker_drift():
    seed = table(mu=0.01, sigma=0.0)
    est = OnlineLatencyTable(seed, alpha=0.5)
    fast = VirtualAccelerator(0.01)
    slow = VirtualAccelerator(0.08)
    pool = WorkerPoolExecutor([fast, slow],
                              placement=RoundRobinPlacement(),
                              estimator=est)
    eng = ServingEngine(uniform_pool(64, 64, est), pool)
    ps = [Patch(0, 0, 32, 32, frame_id=i, t_gen=round(0.2 * i, 4), slo=1e-6)
          for i in range(8)]
    eng.run(arrivals_of(ps))
    assert est.n_observations == 8
    assert est.drift(worker=1) > est.drift(worker=0) > 0
    # the aggregate estimate moved toward the observed service times
    mu1, _ = est.mu_sigma(1)
    assert 0.01 < mu1 < 0.08


# -------------------------------------------- platform capacity shards ----

def test_split_platform_shards_capacity_and_shares_meter():
    lat = table()
    base = Platform(lat, PlatformConfig(max_instances=8, pre_warm=2, seed=7))
    shards = split_platform(base, 4)
    assert len(shards) == 4
    for i, sh in enumerate(shards):
        assert sh.cfg.max_instances == 2
        assert sh.cfg.seed == 7 + i
        assert sh.meter is base.meter
    # pre-warm remainder goes to the lowest-index workers
    assert [sh.cfg.pre_warm for sh in shards] == [1, 1, 0, 0]
    shards[0].submit(0.0, 1)
    shards[1].submit(0.0, 2)
    assert base.meter.invocations == 2
    assert base.total_cost > 0


def test_per_worker_config_conserves_total_capacity():
    cfg = PlatformConfig(max_instances=7, pre_warm=3)
    shards = [cfg.per_worker(3, worker=i) for i in range(3)]
    assert [s.max_instances for s in shards] == [3, 2, 2]   # sums to 7
    assert [s.pre_warm for s in shards] == [1, 1, 1]
    assert [s.seed for s in shards] == [cfg.seed + i for i in range(3)]
    with pytest.raises(ValueError):
        cfg.per_worker(0)
    with pytest.raises(ValueError):
        cfg.per_worker(3, worker=3)
    with pytest.raises(ValueError):
        PlatformConfig(max_instances=2).per_worker(4)   # worker would be
                                                        # zero-capacity


# --------------------------------------------------- results summary ----

def test_results_summary_has_per_worker_and_class_breakdown():
    from repro.core.scheduler import TangramScheduler

    lat = table()
    rng = np.random.default_rng(0)
    streams = [[Patch(0, 0, int(rng.integers(16, 64)),
                      int(rng.integers(16, 64)), frame_id=f, camera_id=cam,
                      t_gen=f / 10.0, slo=float(rng.choice([0.4, 2.0])))
                for f in range(12)] for cam in range(2)]
    sched = TangramScheduler(64, 64, lat,
                             Platform(lat, PlatformConfig(max_instances=8)),
                             classify=slo_class, n_workers=2,
                             placement="least", online_latency=True)
    res = sched.run(streams, bandwidth_bps=50e6)
    s = res.summary()

    assert set(s["class_violations"]) == {"0.4", "2.0"}
    total = sum(v["patches"] for v in s["class_violations"].values())
    assert total == res.n_patches
    for v in s["class_violations"].values():
        assert 0.0 <= v["violation_rate"] <= 1.0

    assert len(s["per_worker"]) == 2
    assert sum(w["invocations"] for w in s["per_worker"]) == res.invocations
    for w in s["per_worker"]:
        # busy_s is an interval union, so utilization is a true fraction
        assert 0.0 <= w["utilization"] <= 1.0
        assert "drift" in w                 # online estimator attached
    assert sched.estimator is not None
    assert sched.estimator.n_observations == res.invocations


def test_scheduler_worker_pool_keeps_boundaries_and_reports_stats():
    """The scheduler's worker-pool path batches identically to the plain
    path (placement cannot leak into batching) and attaches per-worker
    stats only when a pool actually served the run."""
    from repro.core.scheduler import TangramScheduler

    lat = table()
    rng = np.random.default_rng(1)
    streams = [[Patch(0, 0, int(rng.integers(16, 64)),
                      int(rng.integers(16, 64)), frame_id=f,
                      t_gen=f / 10.0, slo=1.0) for f in range(10)]]

    def run(**kw):
        plat = Platform(lat, PlatformConfig())
        return TangramScheduler(64, 64, lat, plat, **kw).run(
            streams, bandwidth_bps=50e6)

    plain = run()
    pooled = run(n_workers=2)
    assert plain.n_patches == pooled.n_patches
    assert plain.patches_per_batch == pooled.patches_per_batch
    assert plain.worker_stats is None
    assert pooled.worker_stats is not None and len(pooled.worker_stats) == 2
