"""Fleet-scale sharding: equivalence, ordering, planner, and wiring.

The load-bearing guarantees:

* ``FleetInvokerPool`` (event-heap timers) is *decision-identical* to
  the stock scanning ``InvokerPool`` — same fired invocations in the
  same order, same ``next_timer`` answers — under randomized arrival /
  poll / flush sequences;
* a 1-shard ``ShardedEngine`` is event-identical to driving the inner
  ``ServingEngine`` directly, and an N-shard split whose camera groups
  respect the batching classes routes every patch to the *same outcome*
  as the single engine (deterministic executor);
* cross-shard completion ties deliver in pinned ``(t_finish, shard
  index, local order)`` order, so N-shard replays are reproducible;
* the cost planner's layout beats the naive equal split on a
  heterogeneous (id-correlated) fleet, and plans round-trip through
  JSON;
* the ``ServeConfig.shards`` / ``planner`` path through
  ``TangramScheduler`` produces per-shard rows in
  ``Results.summary()``.
"""
import json
import math

import numpy as np
import pytest

from repro.core.config import ServeConfig
from repro.core.engine import (InvokerPool, ServingEngine, SimExecutor,
                               uniform_pool)
from repro.core.fleet import (EqualSplitPlanner, FleetCostModel,
                              FleetInvokerPool, FleetPlan, FleetPlanner,
                              ShardedEngine, fleet_uniform_pool,
                              make_planner)
from repro.core.latency import LatencyTable, OnlineLatencyTable
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.core.workers import ReservedClassPlacement
from repro.data.video import Arrival
from repro.serverless.platform import Platform, PlatformConfig
from repro.sources import FleetCameraSource, make_source

TABLE = LatencyTable({1: (0.05, 0.0), 2: (0.08, 0.0), 4: (0.12, 0.0),
                      8: (0.2, 0.0)})
GROUP = 4


def classify(p):
    return (p.slo, p.camera_id // GROUP)


def det_platform(instances=64, seed=0):
    """Deterministic platform: sigma-0 table, no cold starts, enough
    pre-warmed instances that capacity never skews a comparison."""
    return Platform(TABLE, PlatformConfig(
        max_instances=instances, pre_warm=instances, cold_start_s=0.0,
        keep_alive_s=1e9, seed=seed))


def fleet_arrivals(n_cameras=40, duration_s=3.0, seed=7, **kw):
    return FleetCameraSource(n_cameras=n_cameras, duration_s=duration_s,
                             seed=seed, **kw).arrivals()


def outcome_key(o):
    return (o.patch.camera_id, o.patch.frame_id, o.patch.x0, o.patch.y0,
            round(o.t_arrive, 9), round(o.t_submit, 9),
            round(o.t_finish, 9))


# ------------------------------------------------- pool equivalence ----


def _drive_pools(events):
    """Run the same event script against both pool types; return the
    (fired, timers) transcripts."""
    transcripts = []
    for make in (lambda: uniform_pool(256, 256, TABLE, classify=classify),
                 lambda: fleet_uniform_pool(256, 256, TABLE,
                                            classify=classify)):
        pool = make()
        fired, timers = [], []
        for kind, t, patch in events:
            if kind == "patch":
                fired.extend(pool.on_patch(t, patch))
            else:
                step = pool.poll if kind == "poll" else pool.flush
                while True:
                    inv = step(t)
                    if inv is None:
                        break
                    fired.append(inv)
            timers.append(pool.next_timer())
        transcripts.append((fired, timers))
    return transcripts


def test_fleet_pool_decision_identical_to_stock_pool():
    rng = np.random.default_rng(0)
    events = []
    t = 0.0
    for i in range(400):
        t += float(rng.uniform(0.0, 0.02))
        roll = rng.uniform()
        if roll < 0.70:
            cam = int(rng.integers(0, 24))
            slo = (0.2, 0.7)[cam % 2]
            w = int(rng.integers(16, 120))
            h = int(rng.integers(16, 120))
            events.append(("patch", t,
                           Patch(0, 0, w, h, frame_id=i, camera_id=cam,
                                 t_gen=t, slo=slo)))
        elif roll < 0.95:
            events.append(("poll", t, None))
        else:
            events.append(("flush", t, None))
    (stock_fired, stock_timers), (fleet_fired, fleet_timers) = \
        _drive_pools(events)
    assert len(stock_fired) == len(fleet_fired) > 0
    for a, b in zip(stock_fired, fleet_fired):
        assert (a.t_submit, a.key, a.reason) == (b.t_submit, b.key, b.reason)
        assert [p.frame_id for p in a.patches] \
            == [p.frame_id for p in b.patches]
    assert stock_timers == fleet_timers


def test_fleet_pool_heap_compacts_under_churn():
    # churn regression: every arrival to a class with a live timer
    # stales its old heap entry, so a long run on a small class set used
    # to grow the heap without bound (the old compaction only ran when a
    # class's timer went to inf).  The stale-entry counter now compacts
    # once dead entries exceed 2x the live classes — the heap stays
    # O(classes) — and the decisions stay identical to the stock pool.
    stock = uniform_pool(256, 256, TABLE, classify=classify)
    fleet = fleet_uniform_pool(256, 256, TABLE, classify=classify)
    n_classes, t, max_heap = 6, 0.0, 0
    stock_fired, fleet_fired = [], []
    for i in range(3000):
        t += 0.001
        # long SLO: the class always holds a live timer, so every
        # arrival stales an entry and the old code never compacted
        p = Patch(0, 0, 32, 32, frame_id=i,
                  camera_id=(i % n_classes) * GROUP, t_gen=t, slo=5.0)
        stock_fired.extend(stock.on_patch(t, p))
        fleet_fired.extend(fleet.on_patch(t, p))
        assert stock.next_timer() == fleet.next_timer()
        max_heap = max(max_heap, len(fleet._heap))
    live = len(fleet.invokers)
    assert live == n_classes
    assert max_heap <= 3 * live + 32, \
        f"heap peaked at {max_heap} entries for {live} live classes"
    # drain both pools once the timers come due: identical decisions
    for pool, fired in ((stock, stock_fired), (fleet, fleet_fired)):
        for step in (pool.poll, pool.flush):
            while True:
                inv = step(10.0)
                if inv is None:
                    break
                fired.append(inv)
    assert len(stock_fired) == len(fleet_fired) > 0
    for a, b in zip(stock_fired, fleet_fired):
        assert (a.t_submit, a.key) == (b.t_submit, b.key)
        assert [p.frame_id for p in a.patches] \
            == [p.frame_id for p in b.patches]
    assert len(fleet._heap) <= 3 * live + 32


def test_fleet_pool_tie_prefers_first_registered_class():
    # two classes with identical timers: the stock pool's dict-order min
    # fires the first-registered class first — the heap must reproduce it
    for make in (lambda: uniform_pool(256, 256, TABLE, classify=classify),
                 lambda: fleet_uniform_pool(256, 256, TABLE,
                                            classify=classify)):
        pool = make()
        p0 = Patch(0, 0, 32, 32, camera_id=0, t_gen=0.0, slo=1.0)
        p1 = Patch(0, 0, 32, 32, camera_id=GROUP, t_gen=0.0, slo=1.0)
        assert pool.on_patch(0.0, p0) == []
        assert pool.on_patch(0.0, p1) == []
        fired = []
        while True:
            inv = pool.poll(10.0)
            if inv is None:
                break
            fired.append(inv)
        assert [inv.key for inv in fired] == [classify(p0), classify(p1)]


# --------------------------------------------- sharded-engine identity ----


def build_sharded(arrivals, n_shards, camera_block=GROUP, n_cameras=40,
                  window=None):
    """A ShardedEngine whose camera groups respect the batching classes
    (contiguous blocks of ``camera_block`` cameras stay together)."""
    groups = [[] for _ in range(n_shards)]
    for blk in range((n_cameras + camera_block - 1) // camera_block):
        cams = range(blk * camera_block,
                     min((blk + 1) * camera_block, n_cameras))
        groups[blk % n_shards].extend(cams)
    plan = FleetPlan(n_shards=n_shards,
                     camera_groups=tuple(tuple(g) for g in groups))
    engines = [ServingEngine(
        fleet_uniform_pool(256, 256, TABLE, classify=classify),
        SimExecutor(det_platform(seed=s)), ingestion_window=window)
        for s in range(n_shards)]
    return ShardedEngine(engines, plan.shard_of, plan=plan)


def test_one_shard_identical_to_single_engine():
    arrivals = fleet_arrivals(burst_prob=0.3, burst_factor=4.0)
    single = ServingEngine(
        uniform_pool(256, 256, TABLE, classify=classify),
        SimExecutor(det_platform()))
    single.run(arrivals)
    sharded = build_sharded(arrivals, n_shards=1)
    sharded.run(arrivals)
    assert len(sharded.outcomes) == len(single.outcomes) == len(arrivals)
    for a, b in zip(single.outcomes, sharded.outcomes):
        assert a.patch is b.patch
        assert (a.t_arrive, a.t_submit, a.t_finish) \
            == (b.t_arrive, b.t_submit, b.t_finish)
    assert len(sharded.invocations) == len(single.invocations)
    assert all(inv.shard == 0 for inv in sharded.invocations)


@pytest.mark.parametrize("n_shards", [2, 5])
def test_n_shards_route_every_patch_to_the_same_outcome(n_shards):
    # camera groups aligned to the batching classes: each class's queue
    # sees the same patches in the same order whether it lives in the
    # single engine or in its shard, and the deterministic executor
    # makes t_finish a pure function of (t_submit, batch)
    arrivals = fleet_arrivals()
    single = ServingEngine(
        uniform_pool(256, 256, TABLE, classify=classify),
        SimExecutor(det_platform()))
    single.run(arrivals)
    sharded = build_sharded(arrivals, n_shards=n_shards)
    sharded.run(arrivals)
    assert sorted(map(outcome_key, sharded.outcomes)) \
        == sorted(map(outcome_key, single.outcomes))
    shards_used = {inv.shard for inv in sharded.invocations}
    assert len(shards_used) > 1, "trace never exercised a second shard"


def test_cross_shard_tie_delivery_order_pinned():
    # two cameras on two shards emit identical-geometry patches at the
    # same instant: both complete at the same t_finish, and the merged
    # stream must order shard 0 before shard 1 — every run
    def trace():
        out = []
        for t in (0.0, 0.5):
            for cam in (0, 1):
                p = Patch(0, 0, 32, 32, frame_id=int(t * 10),
                          camera_id=cam, t_gen=t, slo=0.5)
                out.append(Arrival(t, p, 0.0))
        return out

    def run_once():
        plan = FleetPlan(n_shards=2, camera_groups=((0,), (1,)))
        engines = [ServingEngine(
            fleet_uniform_pool(256, 256, TABLE, classify=classify),
            SimExecutor(det_platform(seed=s))) for s in range(2)]
        sh = ShardedEngine(engines, plan.shard_of, plan=plan)
        sh.run(trace())
        return sh.outcomes

    first = run_once()
    again = run_once()
    finishes = [o.t_finish for o in first]
    assert len(first) == 4
    # ties exist (same geometry, same deterministic table, same submit)
    assert finishes[0] == finishes[1] and finishes[2] == finishes[3]
    assert [o.patch.camera_id for o in first] == [0, 1, 0, 1]
    assert list(map(outcome_key, first)) == list(map(outcome_key, again))


def test_sharded_engine_aggregates_and_stats():
    arrivals = fleet_arrivals()
    sharded = build_sharded(arrivals, n_shards=3, window=30)
    sharded.run(arrivals)
    assert sharded.arrivals_total == len(arrivals)
    assert sharded.backlog() == 0 and not sharded.overloaded()
    assert sharded.ingestion_window == 90      # per-shard windows summed
    rows = sharded.shard_stats()
    assert [r["shard"] for r in rows] == [0, 1, 2]
    assert sum(r["arrivals"] for r in rows) == len(arrivals)
    assert all(r["backlog_high_water"] >= 0 for r in rows)
    assert sum(r["violations"] for r in rows) \
        == sum(o.violated for o in sharded.outcomes)
    json.dumps(rows)                           # benchmark-JSON safe


def test_sharded_engine_requires_shards():
    with pytest.raises(ValueError):
        ShardedEngine([], lambda cam: 0)


# ----------------------------------------------------------- planner ----


def skewed_rates(n=64):
    """Id-correlated heterogeneous fleet: low ids are hot (cameras
    numbered by site, busiest first)."""
    return {c: 8.0 / (1.0 + c) for c in range(n)}


def test_planner_balances_and_allocates_proportionally():
    plan = FleetPlanner(FleetCostModel(latency=TABLE),
                        worker_budget=16).plan(skewed_rates(), n_shards=4)
    rates = skewed_rates()
    loads = [sum(rates[c] for c in g) for g in plan.camera_groups]
    assert max(loads) < 2.0 * min(loads), \
        "LPT grouping left the fleet imbalanced"
    assert sum(plan.workers) == 16
    # equal split piles the hot low-id cameras onto shard 0
    eq = EqualSplitPlanner(worker_budget=16).plan(skewed_rates(),
                                                 n_shards=4)
    eq_loads = [sum(rates[c] for c in g) for g in eq.camera_groups]
    assert max(eq_loads) > 2.0 * max(loads)


def test_planner_beats_equal_split_on_heterogeneous_fleet():
    src = FleetCameraSource(n_cameras=64, duration_s=4.0, rate_sigma=1.5,
                            sorted_by_rate=True, seed=5)
    arrivals = src.arrivals()
    rates = src.camera_rates()
    budget, shards = 4, 2

    def run(plan):
        engines = []
        for s in range(plan.n_shards):
            w = max(plan.workers_of(s), 1)
            engines.append(ServingEngine(
                fleet_uniform_pool(256, 256, TABLE, classify=classify),
                SimExecutor(Platform(TABLE, PlatformConfig(
                    max_instances=w, pre_warm=w, cold_start_s=0.0,
                    keep_alive_s=1e9, seed=s)))))
        sh = ShardedEngine(engines, plan.shard_of, plan=plan)
        sh.run(arrivals)
        return sum(o.violated for o in sh.outcomes)

    cost = FleetCostModel(latency=TABLE)
    planned = FleetPlanner(cost, worker_budget=budget).plan(
        rates, n_shards=shards, camera_block=GROUP)
    equal = EqualSplitPlanner(cost, worker_budget=budget).plan(
        rates, n_shards=shards)
    # id-correlated load at a tight worker budget: the contiguous equal
    # split piles the hot sites onto shard 0 while the rate-aware LPT
    # layout spreads them — strictly fewer deadline misses
    assert run(planned) < run(equal)


def test_planner_camera_block_keeps_classes_together():
    rates = {c: 1.0 + (c % 3) for c in range(32)}
    plan = FleetPlanner(FleetCostModel(latency=TABLE),
                        worker_budget=4).plan(rates, n_shards=4,
                                              camera_block=GROUP)
    for group in plan.camera_groups:
        blocks = {c // GROUP for c in group}
        for b in blocks:
            members = [c for c in range(b * GROUP, (b + 1) * GROUP)
                       if c in rates]
            assert all(c in group for c in members), \
                "a batching class was split across shards"


def test_planner_search_prefers_one_shard_at_trivial_load():
    rates = {c: 0.5 for c in range(8)}
    plan = FleetPlanner(FleetCostModel(latency=TABLE),
                        worker_budget=8).plan(rates)
    assert plan.n_shards == 1


def test_replan_folds_drift_into_the_cost_model():
    online = OnlineLatencyTable(TABLE)
    for _ in range(50):
        online.observe(4, 3.0 * TABLE.mu_sigma(4)[0])
    planner = FleetPlanner(FleetCostModel(latency=TABLE), worker_budget=8)
    rates = {c: 30.0 for c in range(64)}
    refreshed = planner.replan(rates, online, n_shards=4)
    baseline = planner.plan(rates, n_shards=4)
    assert refreshed.predicted["drift"] > 1.5
    assert baseline.predicted["drift"] == 1.0
    assert refreshed.predicted["shards"][0]["device_util"] \
        > baseline.predicted["shards"][0]["device_util"]


def test_fleet_plan_round_trips_through_json():
    plan = FleetPlanner(FleetCostModel(latency=TABLE),
                        worker_budget=8).plan(
        skewed_rates(16), class_rates={0.5: 3.0, 2.0: 1.0}, n_shards=2)
    rebuilt = FleetPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert rebuilt == plan
    assert all(rebuilt.shard_of(c) == plan.shard_of(c) for c in range(16))
    assert rebuilt.shard_of(999) == 999 % plan.n_shards   # modulo fallback


def test_make_planner_registry():
    assert isinstance(make_planner(
        "cost", cost_model=FleetCostModel(latency=TABLE)), FleetPlanner)
    assert isinstance(make_planner("equal"), EqualSplitPlanner)
    with pytest.raises(ValueError, match="unknown planner"):
        make_planner("nope")


def test_reserved_class_placement_partitions_workers():
    placement = ReservedClassPlacement({"(0.5, 0)": 2})

    class FakePool:
        n_workers = 4
        outstanding = [5, 0, 0, 0]

    class FakeInv:
        key = (0.5, 0)

    # reserved class stays inside its [0, 2) range despite worker 1
    # being idle outside it
    assert placement.choose(FakeInv(), FakePool()) == 1
    FakeInv.key = (2.0, 1)
    assert placement.choose(FakeInv(), FakePool()) == 2   # first free


# --------------------------------------------------- scheduler wiring ----


def test_scheduler_sharded_path_reports_per_shard_rows():
    cfg = ServeConfig(classify="slo", shards=3, planner="cost",
                      n_workers=6, source="fleet")
    sched = TangramScheduler(256, 256, TABLE,
                             Platform(TABLE, PlatformConfig(
                                 max_instances=24, pre_warm=12)),
                             config=cfg)
    src = make_source("fleet", n_cameras=24, duration_s=2.0, seed=2)
    res = sched.serve_source(src, name="fleet-test")
    assert res.n_patches == src.stats().arrivals > 0
    rows = res.summary()["per_shard"]
    assert [r["shard"] for r in rows] == [0, 1, 2]
    assert sum(r["arrivals"] for r in rows) == res.n_patches
    assert sum(r["workers"] for r in rows) == 6
    json.dumps(res.summary())


def test_scheduler_sharded_equal_planner_and_rateless_fallback():
    cfg = ServeConfig(shards=2, planner="equal", n_workers=2)
    sched = TangramScheduler(256, 256, TABLE,
                             Platform(TABLE, PlatformConfig(
                                 max_instances=8, pre_warm=4)),
                             config=cfg)
    res = sched.serve_source(
        make_source("fleet", n_cameras=6, duration_s=2.0, seed=3))
    assert res.n_patches > 0 and len(res.summary()["per_shard"]) == 2
    # a source with no camera_rates() feed falls back to modulo routing
    streams = [[Patch(0, 0, 32, 32, frame_id=i, camera_id=cam,
                      t_gen=i * 0.2, slo=1.0) for i in range(6)]
               for cam in range(4)]
    res2 = sched.run(streams, bandwidth_bps=50e6)
    assert res2.n_patches == 24
    assert len(res2.summary()["per_shard"]) == 2


def test_serve_config_validates_fleet_fields():
    with pytest.raises(ValueError, match="shards"):
        ServeConfig(shards=0)
    with pytest.raises(ValueError, match="planner"):
        ServeConfig(planner="cost")
    cfg = ServeConfig(shards=4, planner="equal")
    assert ServeConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg


# ------------------------------------------------------- fleet source ----


def test_fleet_source_deterministic_sorted_and_rated():
    src = FleetCameraSource(n_cameras=12, duration_s=2.0, seed=9)
    a = src.arrivals()
    b = FleetCameraSource(n_cameras=12, duration_s=2.0, seed=9).arrivals()
    assert [(x.t_arrive, x.patch.camera_id, x.patch.frame_id)
            for x in a] \
        == [(x.t_arrive, x.patch.camera_id, x.patch.frame_id) for x in b]
    times = [x.t_arrive for x in a]
    assert times == sorted(times)
    rates = src.camera_rates()
    assert set(rates) == set(range(12))
    assert math.isclose(sum(rates.values()), src.total_rate())
    assert math.isclose(sum(src.class_rates().values()), src.total_rate())
    assert {x.patch.slo for x in a} == {0.5, 2.0}


def test_fleet_source_sorted_by_rate_is_id_correlated():
    src = FleetCameraSource(n_cameras=50, duration_s=1.0, rate_sigma=1.0,
                            sorted_by_rate=True, seed=1)
    fps = list(src.fps)
    assert fps == sorted(fps, reverse=True)
