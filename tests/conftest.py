import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real 1-device world.  Multi-device dry-run coverage runs in a subprocess
# (tests/test_dryrun_multidevice.py) which sets its own flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
