import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real 1-device world.  Multi-device dry-run coverage runs in a subprocess
# (tests/test_dryrun_multidevice.py) which sets its own flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401  (the real package, when installed)
except ModuleNotFoundError:
    # fall back to the vendored shim so property tests collect and run in
    # environments without hypothesis (see tests/_vendor/hypothesis)
    sys.path.append(os.path.join(os.path.dirname(__file__), "_vendor"))

import signal
import threading

import numpy as np
import pytest

# Per-test deadline (seconds).  The parallel fleet runtime joins shard
# threads at finish(); a deadlocked shard would otherwise hang the whole
# lane silently.  SIGALRM turns a hang into a loud TimeoutError with a
# traceback pointing at the stuck join/barrier.  pytest-timeout is not a
# repo dependency — this is the conftest-alarm variant.
TEST_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "300"))


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    if (TEST_TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        return (yield)

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"test exceeded {TEST_TIMEOUT_S}s "
            "(deadlocked shard thread?)")

    old_handler = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(TEST_TIMEOUT_S)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)

# Modules excluded from the CI fast lane.  The former tracked-red modules
# (arch smoke, sharding API, multi-device dry-run, elastic re-mesh) went
# green with the version-gated sharding compat layer
# (src/repro/compat/shardingx.py) and now run in the enforced lane; only
# genuinely heavyweight sweeps belong here.
SLOW_MODULES: set = set()


def pytest_collection_modifyitems(config, items):
    for item in items:
        module = item.module.__name__ if item.module else ""
        if module in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
        else:
            item.add_marker(pytest.mark.fast)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
