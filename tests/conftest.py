import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real 1-device world.  Multi-device dry-run coverage runs in a subprocess
# (tests/test_dryrun_multidevice.py) which sets its own flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401  (the real package, when installed)
except ModuleNotFoundError:
    # fall back to the vendored shim so property tests collect and run in
    # environments without hypothesis (see tests/_vendor/hypothesis)
    sys.path.append(os.path.join(os.path.dirname(__file__), "_vendor"))

import numpy as np
import pytest

# Modules excluded from the CI fast lane.  The former tracked-red modules
# (arch smoke, sharding API, multi-device dry-run, elastic re-mesh) went
# green with the version-gated sharding compat layer
# (src/repro/compat/shardingx.py) and now run in the enforced lane; only
# genuinely heavyweight sweeps belong here.
SLOW_MODULES: set = set()


def pytest_collection_modifyitems(config, items):
    for item in items:
        module = item.module.__name__ if item.module else ""
        if module in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
        else:
            item.add_marker(pytest.mark.fast)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
