"""Sharding rules, divisibility fixups, plan_cell metadata."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import api
from repro import configs as reg
from repro.config import ShapeConfig, TransformerConfig
from repro.configs.reduced import reduce_arch
from repro.launch.mesh import make_unit_mesh as mesh11
from repro.sharding import (DEFAULT_RULES, ShardingConfig, divisible_spec,
                            logical_to_spec, merge_rules)


class TestRules:
    def test_logical_to_spec_basic(self):
        spec = logical_to_spec(("batch", "seq", "embed"), DEFAULT_RULES)
        assert spec == P(("data", "pod"))

    def test_duplicate_mesh_axis_dropped(self):
        # batch takes data; a second data-mapped axis must be dropped
        rules = merge_rules(DEFAULT_RULES, {"embed": "data"})
        spec = logical_to_spec(("batch", "embed"), rules)
        assert spec == P(("data", "pod"))

    def test_fsdp_overlay(self):
        rules = ShardingConfig.make(fsdp=True).rules
        assert rules["embed"] == "data"
        assert ShardingConfig.make().rules["embed"] is None

    def test_sequence_overlay(self):
        rules = ShardingConfig.make(sequence_parallel=True).rules
        assert rules["kv_seq"] == "model"


class TestDivisibleSpec:
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 16)

    def test_drops_non_dividing_axis(self):
        # 40 heads cannot shard over 16
        spec = divisible_spec((64, 40, 128), ("embed", "heads", "head_dim"),
                              DEFAULT_RULES, self.FakeMesh)
        assert spec == P()

    def test_keeps_dividing_axis(self):
        spec = divisible_spec((64, 32, 128), ("embed", "heads", "head_dim"),
                              DEFAULT_RULES, self.FakeMesh)
        assert spec == P(None, "model")

    def test_greedy_prefix_for_tuples(self):
        # batch 8 over data(4) x pod(absent): keeps data only
        spec = divisible_spec((8, 10), ("batch", None), DEFAULT_RULES,
                              self.FakeMesh)
        assert spec == P("data")

    def test_partial_product(self):
        # batch 2: data(4) doesn't divide -> dropped entirely
        spec = divisible_spec((2, 10), ("batch", None), DEFAULT_RULES,
                              self.FakeMesh)
        assert spec == P()


class TestPlans:
    def test_plan_kinds(self):
        spec = reg.get("deepseek-moe-16b")
        model = reduce_arch(spec.model)
        mesh = mesh11()
        rules = ShardingConfig.make().rules
        kinds = {}
        for shape in spec.shapes:
            from repro.configs.reduced import reduce_shape
            plan = api.plan_cell(model, reduce_shape(model, shape), mesh,
                                 rules)
            kinds[shape.name] = plan.kind
        assert kinds == {"train_4k": "train", "prefill_32k": "prefill",
                         "decode_32k": "decode", "long_500k": "decode"}

    def test_dryrun_unit_scaling_train(self):
        spec = reg.get("mistral-large-123b")
        model = reduce_arch(spec.model)
        mesh = mesh11()
        rules = ShardingConfig.make().rules
        shape = ShapeConfig("t", "train", seq_len=128, global_batch=8)
        plan = api.plan_cell(model, shape, mesh, rules, accum_steps=4,
                             dryrun=True)
        assert plan.scale == 4.0
        # microbatch: batch dim of tokens = 8 / 4 = 2
        assert plan.args[2]["tokens"].shape == (2, 128)

    def test_dryrun_unit_scaling_gen(self):
        spec = reg.get("dit-s2")
        model = reduce_arch(spec.model)
        mesh = mesh11()
        shape = ShapeConfig("g", "gen", img_res=64, global_batch=2, steps=10)
        plan = api.plan_cell(model, shape, mesh,
                             ShardingConfig.make().rules, dryrun=True)
        assert plan.scale == 10.0

    def test_depth_override(self):
        spec = reg.get("vit-b16")
        model = reduce_arch(spec.model)
        mesh = mesh11()
        shape = ShapeConfig("s", "serve", img_res=64, global_batch=1)
        plan = api.plan_cell(model, shape, mesh,
                             ShardingConfig.make().rules, dryrun=True,
                             depth_override=1)
        # 1-layer unit has fewer params than the 2-layer reduced model
        n1 = sum(x.size for x in jax.tree_util.tree_leaves(plan.args[0]))
        plan2 = api.plan_cell(model, shape, mesh,
                              ShardingConfig.make().rules, dryrun=True,
                              depth_override=2)
        n2 = sum(x.size for x in jax.tree_util.tree_leaves(plan2.args[0]))
        assert n2 > n1

    def test_all_cells_enumerates_40(self):
        cells = list(reg.all_cells())
        assert len(cells) == 40


class TestChunkedAttentionParity:
    def test_chunked_matches_xla(self, rng):
        from repro.models import transformer as tfm
        from repro import param as param_lib
        from repro.sharding import DEFAULT_RULES as R
        cfg = TransformerConfig(
            name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
            d_ff=128, vocab=256, head_dim=16, param_dtype="float32",
            compute_dtype="float32", remat=False)
        params = param_lib.init_params(jax.random.PRNGKey(0),
                                       tfm.param_specs(cfg))
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (2, 4096)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 256, (2, 4096)),
                                       jnp.int32)}
        a = tfm.lm_loss(cfg, params, batch, R, impl="xla")
        b = tfm.lm_loss(cfg, params, batch, R, impl="chunked")
        assert float(abs(a - b)) < 1e-4
