"""SLO-aware batching invoker (Alg. 2 lines 1-23)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch


def table(mu=0.1, sigma=0.01, n=32):
    return LatencyTable({b: (mu * b, sigma) for b in range(1, n + 1)},
                        slack_sigmas=3.0)


def patch(t_gen, slo=1.0, w=64, h=64):
    return Patch(0, 0, w, h, t_gen=t_gen, slo=slo)


def test_timer_fires_at_t_remain():
    inv = SLOAwareInvoker(256, 256, table())
    assert inv.on_patch(0.0, patch(0.0, slo=1.0)) == []
    # t_remain = 1.0 - (0.1 + 3*0.01) = 0.87
    assert inv.next_timer() == pytest.approx(0.87)
    assert inv.poll(0.5) is None
    fired = inv.poll(0.87)
    assert fired is not None and fired.reason == "timer"
    assert fired.batch_size == 1
    assert inv.next_timer() == math.inf


def test_waits_to_accumulate_under_slack():
    inv = SLOAwareInvoker(256, 256, table())
    inv.on_patch(0.0, patch(0.0))
    assert inv.on_patch(0.1, patch(0.1)) == []   # still meets earliest ddl
    fired = inv.poll(inv.next_timer())
    assert fired.batch_size == 1                 # both fit one canvas
    assert len(fired.patches) == 2


def test_slo_pressure_dispatches_old_canvases():
    # big patches: each fills a canvas; low slack; arrival near deadline
    inv = SLOAwareInvoker(256, 256, table(mu=0.4), max_canvases=8)
    inv.on_patch(0.0, patch(0.0, slo=2.0, w=256, h=256))
    # second patch arrives late: adding it would need 2 canvases ->
    # t_slack(2) = 0.8+0.03 -> t_remain = 2.0-0.83 = 1.17 < t_now = 1.5
    fired = inv.on_patch(1.5, patch(1.5, slo=2.0, w=256, h=256))
    assert len(fired) == 1
    assert fired[0].reason == "slo_pressure"
    assert len(fired[0].patches) == 1            # the OLD queue
    assert len(inv.queue) == 1                   # new patch seeds next queue


def test_memory_overflow_dispatches():
    inv = SLOAwareInvoker(64, 64, table(mu=1e-4, sigma=0.0),
                          max_canvases=2)
    fired = []
    for i in range(4):
        fired += inv.on_patch(0.0, patch(0.0, slo=100.0, w=64, h=64))
    reasons = [f.reason for f in fired]
    assert "memory" in reasons


def test_lone_late_patch_fires_immediately():
    inv = SLOAwareInvoker(256, 256, table(mu=0.5))
    fired = inv.on_patch(10.0, patch(0.0, slo=0.2))   # deadline long past
    assert [f.reason for f in fired] == ["late"]
    assert inv.queue == []


def test_flush():
    inv = SLOAwareInvoker(256, 256, table())
    inv.on_patch(0.0, patch(0.0))
    f = inv.flush(0.5)
    assert f is not None and f.reason == "flush"
    assert inv.flush(0.6) is None


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 10), st.integers(16, 256),
                          st.integers(16, 256)), min_size=1, max_size=30))
def test_never_exceeds_max_canvases(arrivals):
    inv = SLOAwareInvoker(256, 256, table(), max_canvases=3)
    arrivals = sorted(arrivals)
    for t, w, h in arrivals:
        while inv.next_timer() < t:
            if inv.poll(inv.next_timer()) is None:
                break
        for f in inv.on_patch(t, patch(t, slo=1.0, w=w, h=h)):
            assert f.batch_size <= 3 + 1   # old set may be at the limit
    # invariant: the live canvas set respects the memory bound
    assert len(inv.canvases) <= 3 + 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0.05, 3.0),
                          st.integers(16, 256), st.integers(16, 256)),
                min_size=1, max_size=40))
def test_eviction_invariants_under_pressure(arrivals):
    """Memory/slo-pressure eviction invariants (Alg. 2 lines 11-17): after
    ANY sequence of arrivals — mixed SLOs force slo_pressure/late firing,
    mixed sizes force memory overflow — the live canvas set respects the
    memory bound and no Invocation ever fires with an empty patch list."""
    max_canvases = 3
    inv = SLOAwareInvoker(256, 256, table(), max_canvases=max_canvases)
    fired = []
    for t, slo, w, h in sorted(arrivals):
        while inv.next_timer() < t:
            f = inv.poll(inv.next_timer())
            if f is None:
                break
            fired.append(f)
        fired += inv.on_patch(t, patch(t, slo=slo, w=w, h=h))
        assert len(inv.canvases) <= max_canvases, \
            "canvas set exceeds the memory bound after an arrival"
    f = inv.flush(11.0)
    if f is not None:
        fired.append(f)
    for f in fired:
        assert f.patches, f"empty-patch Invocation fired ({f.reason})"
        assert f.canvases, f"patch-bearing Invocation with no canvases"
    assert len(inv.canvases) <= max_canvases
    assert inv.queue == []


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 6), st.floats(0.1, 3.0),
                          st.integers(16, 256), st.integers(16, 256)),
                min_size=1, max_size=40))
def test_incremental_restitch_equals_from_scratch(arrivals):
    """The incremental invoker (live PackState, probe-then-append) must
    fire the exact same invocation stream — times, reasons, patch sets,
    canvas counts, placements — as the paper's literal
    restitch-everything-per-arrival semantics, under timers, SLO
    pressure, memory overflow, and the final flush."""
    from repro.core.stitching import validate

    trace = [(t, patch(t, slo=slo, w=w, h=h))
             for t, slo, w, h in sorted(arrivals)]
    runs = []
    for incremental in (True, False):
        inv = SLOAwareInvoker(256, 256, table(), max_canvases=3,
                              incremental=incremental)
        fired = []
        for t, p in trace:
            while inv.next_timer() < t:
                f = inv.poll(inv.next_timer())
                if f is None:
                    break
                fired.append(f)
            fired += inv.on_patch(t, p)
        f = inv.flush(99.0)
        if f is not None:
            fired.append(f)
        runs.append(fired)

    a, b = runs
    assert len(a) == len(b)
    for fa, fb in zip(a, b):
        assert (fa.t_submit, fa.reason) == (fb.t_submit, fb.reason)
        assert [id(p) for p in fa.patches] == [id(p) for p in fb.patches]
        assert len(fa.canvases) == len(fb.canvases)
        assert [(pl.patch_idx, pl.canvas_idx, pl.x, pl.y, pl.w, pl.h)
                for c in fa.canvases for pl in c.placements] == \
            [(pl.patch_idx, pl.canvas_idx, pl.x, pl.y, pl.w, pl.h)
             for c in fb.canvases for pl in c.placements]
        validate(fa.canvases)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 5), min_size=1, max_size=25))
def test_all_patches_eventually_dispatched(times):
    inv = SLOAwareInvoker(256, 256, table(), max_canvases=8)
    times = sorted(times)
    total = 0
    for t in times:
        while inv.next_timer() < t:
            f = inv.poll(inv.next_timer())
            if f is None:
                break
            total += len(f.patches)
        for f in inv.on_patch(t, patch(t)):
            total += len(f.patches)
    while inv.next_timer() < math.inf:
        f = inv.poll(inv.next_timer())
        if f is None:
            break
        total += len(f.patches)
    assert total == len(times)
