"""Training substrate: optimizer, grad accumulation, checkpointing,
elastic re-mesh, failure drills."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt_lib
from repro.training.elastic import (ElasticState, FailureEvent,
                                    FailureInjector, rescale_batch,
                                    shrink_mesh)
from repro.training.train_state import make_train_step


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean(jnp.square(pred - batch["y"]))


def toy_params(key):
    return {"w": jax.random.normal(key, (4, 2)) * 0.1,
            "b": jnp.zeros((2,))}


def toy_batch(rng, n=32):
    x = rng.normal(size=(n, 4)).astype(np.float32)
    w_true = np.array([[1., 0.], [0., 2.], [3., 0.], [0., -1.]], np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true + 0.5)}


class TestOptimizer:
    def test_loss_decreases(self, rng):
        params = toy_params(jax.random.PRNGKey(0))
        state = opt_lib.init(params)
        cfg = opt_lib.OptimizerConfig(lr=0.05, warmup_steps=5,
                                      total_steps=200, weight_decay=0.0)
        step = jax.jit(make_train_step(quad_loss, cfg))
        batch = toy_batch(rng)
        losses = []
        for _ in range(100):
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.05 * losses[0]

    def test_clip_norm_bounds_update(self, rng):
        params = toy_params(jax.random.PRNGKey(0))
        state = opt_lib.init(params)
        cfg = opt_lib.OptimizerConfig(lr=1.0, clip_norm=1e-9,
                                      warmup_steps=0, total_steps=10,
                                      weight_decay=0.0)
        grads = jax.grad(quad_loss)(params, toy_batch(rng))
        new_params, _, m = opt_lib.update(cfg, grads, state, params)
        # with a tiny clip the Adam moments are ~0 -> update ~0 + no decay
        assert float(m["grad_norm"]) > 0

    def test_lr_schedule_shape(self):
        cfg = opt_lib.OptimizerConfig(lr=1.0, warmup_steps=10,
                                      total_steps=100, min_lr_ratio=0.1)
        warm = float(opt_lib.lr_schedule(cfg, jnp.asarray(5)))
        peak = float(opt_lib.lr_schedule(cfg, jnp.asarray(10)))
        end = float(opt_lib.lr_schedule(cfg, jnp.asarray(100)))
        assert warm < peak
        assert end == pytest.approx(0.1, abs=1e-3)

    def test_grad_accum_matches_full_batch(self, rng):
        """accum=2 over the same data == one full-batch step (linear loss
        in batch -> averaged grads identical)."""
        params = toy_params(jax.random.PRNGKey(1))
        cfg = opt_lib.OptimizerConfig(lr=0.01, warmup_steps=0,
                                      total_steps=10, weight_decay=0.0)
        batch = toy_batch(rng, n=32)
        p1, _, m1 = make_train_step(quad_loss, cfg, accum_steps=1)(
            params, opt_lib.init(params), batch)
        p2, _, m2 = make_train_step(quad_loss, cfg, accum_steps=2)(
            params, opt_lib.init(params), batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   atol=1e-6)


class TestCheckpoint:
    def test_atomic_commit_and_keep_k(self):
        tree = {"a": jnp.arange(4.0)}
        with tempfile.TemporaryDirectory() as d:
            for s in (10, 20, 30, 40):
                ckpt.save(d, s, tree, keep=2)
            assert ckpt.committed_steps(d) == [30, 40]
            assert not any(n.endswith(".tmp") for n in os.listdir(d))

    def test_restore_latest_roundtrip(self):
        tree = {"w": jnp.ones((3, 3), jnp.bfloat16),
                "opt": {"m": jnp.zeros(5)}}
        with tempfile.TemporaryDirectory() as d:
            assert ckpt.restore_latest(d, tree) == (None, None)
            ckpt.save(d, 7, tree)
            restored, step = ckpt.restore_latest(d, tree)
            assert step == 7
            assert restored["w"].dtype == jnp.bfloat16

    def test_torn_write_ignored(self):
        """A crashed (uncommitted) save must be invisible to restore."""
        tree = {"a": jnp.arange(4.0)}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, tree)
            os.makedirs(os.path.join(d, "step_00000002.tmp"))
            assert ckpt.latest_step(d) == 1

    def test_shape_mismatch_raises(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"a": jnp.zeros((2, 2))})
            with pytest.raises(AssertionError):
                ckpt.restore(d, 1, {"a": jnp.zeros((3, 3))})


class TestElastic:
    def test_shrink_mesh_drops_data_rows(self):
        from repro.launch.mesh import make_unit_mesh
        mesh = make_unit_mesh()
        with pytest.raises(RuntimeError):
            shrink_mesh(mesh, [0])

    def test_rescale_batch_keeps_per_replica(self):
        assert rescale_batch(256, 16, 12) == 192
        assert rescale_batch(256, 16, 8) == 128

    def test_failure_injector_fires_once(self):
        inj = FailureInjector([FailureEvent(5, "chip", 1)])
        assert inj.poll(4) == []
        assert len(inj.poll(5)) == 1
        assert inj.poll(5) == []


class TestFailureDrill:
    def test_resume_after_drill(self, rng):
        """train.py-style drill: checkpoint, 'fail', restore, continue."""
        from repro.launch.train import train
        from repro.config import DetectorConfig, ShapeConfig
        model = DetectorConfig(name="drill", canvas=64, patch=32, n_layers=1,
                               d_model=32, n_heads=2, d_ff=64,
                               param_dtype="float32",
                               compute_dtype="float32")
        shape = ShapeConfig("train", "train", img_res=64, global_batch=2)
        with tempfile.TemporaryDirectory() as d:
            inj = FailureInjector([FailureEvent(6, "host", 0)])
            _, losses = train(model, shape, steps=8, ckpt_dir=d,
                              ckpt_every=2, injector=inj, log_every=100)
            assert len(losses) == 8
            assert ckpt.latest_step(d) == 8
