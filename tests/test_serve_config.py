"""ServeConfig: the one pipeline record, its shim, and its round-trips.

Covers the api_redesign guarantees:

* the legacy ``TangramScheduler(**kwargs)`` surface still works, warns
  exactly once per process (DeprecationWarning), and produces runs
  identical to the equivalent ``config=ServeConfig(...)``;
* configs and latency tables serialize to plain JSON (named references,
  no callables/meshes) and rebuild exactly — the benchmark-logging
  bugfix;
* the factory quartet (``make_clock`` / ``make_executor`` /
  ``make_classify`` / ``make_source``) resolves names and rejects
  unknowns.
"""
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core import scheduler as scheduler_mod
from repro.core.adaptive import AIMDConfig
from repro.core.clock import VirtualClock, WallClock, make_clock
from repro.core.config import ServeConfig, make_classify, register_classify
from repro.core.engine import SimExecutor, make_executor, slo_class
from repro.core.latency import (LatencyTable, OnlineLatencyTable,
                                latency_from_dict)
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform

TABLE = LatencyTable({1: (0.05, 0.005), 2: (0.08, 0.008), 4: (0.12, 0.01)})


def streams(n_cams=2, n=20):
    rng = np.random.default_rng(0)
    return [[Patch(0, 0, int(rng.integers(16, 96)), int(rng.integers(16, 96)),
                   frame_id=i, camera_id=cam, t_gen=i * 0.1, slo=1.0)
             for i in range(n)] for cam in range(n_cams)]


@pytest.fixture
def fresh_warning_flag(monkeypatch):
    """Each test sees the warn-once machinery as a fresh process."""
    monkeypatch.setattr(scheduler_mod, "_legacy_warned", False)


# -------------------------------------------------------- deprecation shim ----

def test_legacy_kwargs_warn_once_and_forward(fresh_warning_flag):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s = TangramScheduler(128, 128, TABLE, Platform(TABLE),
                             max_canvases=4, classify="slo", n_workers=2)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "ServeConfig" in str(dep[0].message)
    # forwarded onto the config record
    assert s.config.max_canvases == 4
    assert s.config.classify == "slo"
    assert s.config.n_workers == 2

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        TangramScheduler(128, 128, TABLE, Platform(TABLE), max_canvases=2)
        assert not [x for x in w
                    if issubclass(x.category, DeprecationWarning)]


def test_legacy_run_identical_to_config_run(fresh_warning_flag):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = TangramScheduler(128, 128, TABLE, Platform(TABLE),
                               max_canvases=4, classify=slo_class)
    new = TangramScheduler(128, 128, TABLE, Platform(TABLE),
                           config=ServeConfig(max_canvases=4,
                                              classify="slo"))
    ss = streams()
    key = lambda r: [(o.patch.frame_id, o.t_arrive, o.t_finish)
                     for o in r.outcomes]
    r_old, r_new = old.run(ss, 20e6), new.run(ss, 20e6)
    assert key(r_old) == key(r_new)
    assert r_old.invocations == r_new.invocations
    assert r_old.bytes_sent == r_new.bytes_sent


def test_legacy_instance_values_become_overrides(fresh_warning_flag):
    """Callable classify / Clock instances can't live in a config — the
    shim honours them as direct overrides instead."""
    clk = VirtualClock(t0=3.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        s = TangramScheduler(128, 128, TABLE, Platform(TABLE),
                             classify=lambda p: 0, clock=clk)
    assert s.clock is clk
    assert s.config.classify is None      # not expressible -> not recorded
    assert s._clock() is clk


def test_unknown_kwarg_raises(fresh_warning_flag):
    with pytest.raises(TypeError, match="unexpected"):
        TangramScheduler(128, 128, TABLE, Platform(TABLE), max_canvas=4)


# ------------------------------------------------------------ serialization ----

def test_config_json_roundtrip():
    cfg = ServeConfig(max_canvases=4, classify="slo",
                      adaptive=AIMDConfig(), executor="async_device",
                      clock="wall", wall_speed=25.0, n_workers=2,
                      placement="round", online_latency=True,
                      source="synthetic", ingestion_window=32)
    blob = json.dumps(cfg.to_dict())
    assert ServeConfig.from_dict(json.loads(blob)) == cfg
    # nothing non-JSON leaks into the record
    assert all(isinstance(v, (int, float, str, bool, dict, type(None)))
               for v in cfg.to_dict().values())


def test_config_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown ServeConfig"):
        ServeConfig.from_dict({"max_canvases": 4, "max_canvas": 2})


def test_config_replace_sweeps():
    base = ServeConfig()
    swept = [base.replace(n_workers=n) for n in (1, 2, 4)]
    assert [c.n_workers for c in swept] == [1, 2, 4]
    assert base.n_workers == 1            # frozen: base untouched
    assert dataclasses.replace(base, clock="wall").clock == "wall"


def test_config_validation():
    for bad in (dict(n_workers=0), dict(max_inflight=0),
                dict(wall_speed=0.0), dict(ingestion_window=0)):
        with pytest.raises(ValueError):
            ServeConfig(**bad)


def test_latency_table_json_roundtrip():
    blob = json.dumps(TABLE.to_dict())
    t2 = latency_from_dict(json.loads(blob))
    assert isinstance(t2, LatencyTable)
    assert t2.table == TABLE.table        # int keys restored
    assert t2.mu_sigma(2) == TABLE.mu_sigma(2)


def test_online_latency_table_json_roundtrip():
    online = OnlineLatencyTable(TABLE)
    online.observe(2, 0.5)                # learned state is NOT serialized
    blob = json.dumps(online.to_dict())
    t2 = latency_from_dict(json.loads(blob))
    assert isinstance(t2, OnlineLatencyTable)
    assert t2.seed.table == TABLE.table
    # deserialized estimator starts at the seed profile
    assert t2.mu_sigma(2) == TABLE.mu_sigma(2)
    assert online.mu_sigma(2) != TABLE.mu_sigma(2)


def test_latency_from_dict_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        latency_from_dict({"kind": "mystery"})


# ---------------------------------------------------------------- factories ----

def test_make_clock_by_name():
    assert isinstance(make_clock("virtual"), VirtualClock)
    w = make_clock("wall", speed=50.0)
    assert isinstance(w, WallClock) and w.speed == 50.0
    # one config dict drives either: virtual ignores speed
    assert isinstance(make_clock("virtual", speed=50.0), VirtualClock)
    with pytest.raises(ValueError, match="unknown clock"):
        make_clock("sundial")


def test_make_executor_by_name():
    ex = make_executor("sim", platform=Platform(TABLE))
    assert isinstance(ex, SimExecutor)
    # max_inflight is dropped for sync executors (shared config dict)
    ex2 = make_executor("sim", platform=Platform(TABLE), max_inflight=4)
    assert isinstance(ex2, SimExecutor)
    with pytest.raises(ValueError, match="unknown executor"):
        make_executor("gpu-farm")


def test_make_classify_by_name():
    assert make_classify(None) is None
    assert make_classify("slo") is slo_class
    with pytest.raises(ValueError, match="unknown classifier"):
        make_classify("priority")
    register_classify("camera", lambda p: p.camera_id)
    assert make_classify("camera")(Patch(0, 0, 8, 8, camera_id=3)) == 3
