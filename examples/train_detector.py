"""Train the Tangram canvas detector end-to-end on stitched canvases.

The data loader runs the REAL pipeline (scene -> Alg. 1 -> stitching ->
canvas compositing) and trains the ViT-backbone detector for a few hundred
steps with checkpointing; a failure drill at step 60 exercises the
restore-and-resume path.  Reduced config (CPU container); on a pod the
same driver trains the full ~100M tangram-detector.

    PYTHONPATH=src python examples/train_detector.py [--steps 200]
"""
import argparse
import tempfile

from repro.config import DetectorConfig, ShapeConfig
from repro.launch.train import train
from repro.training.elastic import FailureEvent, FailureInjector


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=120)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--canvas", type=int, default=128)
    args = p.parse_args()

    model = DetectorConfig(
        name="detector-cpu", canvas=args.canvas, patch=32, n_layers=2,
        d_model=96, n_heads=4, d_ff=192, param_dtype="float32",
        compute_dtype="float32")
    shape = ShapeConfig("train", "train", img_res=args.canvas,
                        global_batch=args.batch)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        injector = FailureInjector(
            [FailureEvent(min(60, args.steps // 2), "host", 0)])
        _, losses = train(model, shape, steps=args.steps, ckpt_dir=ckpt_dir,
                          ckpt_every=20, injector=injector, log_every=10)
    k = max(len(losses) // 10, 1)
    print(f"\nloss: first-{k} mean {sum(losses[:k])/k:.4f} -> "
          f"last-{k} mean {sum(losses[-k:])/k:.4f}")
    assert sum(losses[-k:]) < sum(losses[:k]), "training did not learn"
    print("detector training learns + survives the failure drill")


if __name__ == "__main__":
    main()
