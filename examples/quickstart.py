"""Quickstart: the Tangram pipeline in ~60 lines.

Synthetic camera -> GMM background subtraction -> RoIs -> adaptive frame
partitioning (Alg. 1) -> patch stitching + SLO-aware batching (Alg. 2) ->
serverless platform simulation -> cost / SLO report.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import gmm, partitioning, rois
from repro.core.latency import detector_latency_model
from repro.core.scheduler import TangramScheduler
from repro.data.synthetic import Scene, preset
from repro.serverless.platform import Platform, PlatformConfig

WIDTH, HEIGHT, CANVAS, SLO = 480, 272, 128, 1.0


def main():
    # --- edge side -------------------------------------------------------
    scene = Scene(preset(0, width=WIDTH, height=HEIGHT))
    state = gmm.init_state(HEIGHT, WIDTH)
    stream = []
    for t, frame, gt in scene.frames(40):
        state, fg = gmm.update_jit(state, jnp.asarray(frame))
        if t < 1.0:                       # background model warmup
            continue
        boxes, valid = rois.extract_rois_jit(jnp.asarray(fg))
        b = np.asarray(boxes)[np.asarray(valid)]
        patches = partitioning.partition_host(
            b, WIDTH, HEIGHT, 4, 4, frame_id=scene.t, t_gen=t, slo=SLO)
        # enclosing rects can exceed zones; clamp to the canvas tile
        stream.extend(partitioning.Patch(
            p.x0, p.y0, min(p.x1, p.x0 + CANVAS), min(p.y1, p.y0 + CANVAS),
            p.frame_id, p.camera_id, p.t_gen, p.slo) for p in patches)
    print(f"edge produced {len(stream)} patches over "
          f"{scene.t} frames (mean {len(stream)/scene.t:.1f}/frame)")

    # --- cloud side ------------------------------------------------------
    # offline latency profile (mu + 3 sigma slack, Section III-C)
    table = detector_latency_model(CANVAS, CANVAS, chips=4).build_table(16)
    platform = Platform(table, PlatformConfig())
    scheduler = TangramScheduler(CANVAS, CANVAS, table, platform,
                                 check_invariants=True)
    res = scheduler.run([stream], bandwidth_bps=20e6)

    print("\n--- Tangram report (20 Mbps uplink, SLO 1.0 s) ---")
    for k, v in res.summary().items():
        print(f"  {k:22s} {v}")
    print(f"  canvases/invocation    "
          f"{np.mean(res.batch_sizes):.2f}")


if __name__ == "__main__":
    main()
