"""End-to-end serving driver: batched requests through a REAL jit'd model.

This is the paper's deployment loop with actual tensors: the edge pipeline
emits patches, the unified serving engine (``core.engine``) batches them
through the SLO-aware invoker pool, the Pallas stitch kernel (interpret
mode on CPU) assembles canvases, and a jit-compiled ViT detector serves
each batch on the ``DeviceExecutor`` — the exact control plane the
simulator benchmarks run on.

    PYTHONPATH=src python examples/serve_e2e.py
"""
from repro.launch import serve

if __name__ == "__main__":
    serve.main(["--frames", "40", "--canvas", "192", "--slo", "2.0",
                "--use-pallas-stitch"])
