"""Tangram's idea applied to LM serving: SLO-aware sequence packing.

Variable-length prefill requests are packed into fixed (rows x seq_len)
buffers with the same best-fit rule and the *same* SLO-aware invoker as
the vision canvases (DESIGN.md §5), then served by a small decoder LM with
the flash-attention kernel's segment masking so packed requests never
attend across boundaries.

    PYTHONPATH=src python examples/lm_sequence_packing.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import param as param_lib
from repro.config import TransformerConfig
from repro.core.latency import LatencyTable
from repro.core.sequence_packing import Request, SequencePacker, pack
from repro.kernels.attention import ops as attn_ops
from repro.models import transformer as tfm
from repro.sharding import DEFAULT_RULES

SEQ = 512


def main():
    rng = np.random.default_rng(0)

    # a burst of requests with zipf-ish lengths and 300 ms SLOs
    reqs = [Request(int(np.clip(rng.lognormal(4.5, 0.8), 8, SEQ)),
                    t_gen=float(i) * 0.01, slo=0.3, request_id=i)
            for i in range(24)]
    rows = pack(reqs, SEQ)
    eff = sum(r.used for r in rows) / (len(rows) * SEQ)
    print(f"packed {len(reqs)} requests -> {len(rows)} rows "
          f"(efficiency {eff:.2f}; unpacked would need {len(reqs)} rows)")

    # SLO-aware invoker over rows (identical control path to canvases)
    table = LatencyTable({b: (0.02 * b, 0.002) for b in range(1, 65)})
    packer = SequencePacker(SEQ, table)
    fired = []
    for r in reqs:
        fired += packer.on_request(r.t_gen, r)
        while (inv := packer.poll(r.t_gen)) is not None:
            fired.append(inv)
    if (final := packer.invoker.flush(1.0)) is not None:
        fired.append(final)
    print(f"invoker dispatched {len(fired)} batched prefills "
          f"(reasons: {[f.reason for f in fired]})")

    # serve one packed row through a real model with segment masking
    cfg = TransformerConfig(name="packlm", n_layers=2, d_model=128,
                            n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                            head_dim=32, param_dtype="float32",
                            compute_dtype="float32", remat=False)
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   tfm.param_specs(cfg))
    tokens = jnp.asarray(rng.integers(0, 512, (1, SEQ)), jnp.int32)
    seg = np.zeros((1, SEQ), np.int32)
    for j, (_, s, e) in enumerate(rows[0].spans):
        seg[0, s:e] = j + 1
    seg = jnp.asarray(seg)

    h, _ = tfm.forward(cfg, params, tokens, DEFAULT_RULES)
    # flash kernel with block-diagonal segment mask (packed-batch serving)
    q = jnp.ones((1, SEQ, 4, 32), jnp.float32)
    out = attn_ops.flash_attention(q, q[:, :, :2], q[:, :, :2],
                                   causal=True, segment_ids=seg,
                                   block_q=128, block_kv=128,
                                   interpret=True)
    print(f"packed-forward OK: hidden {h.shape}, "
          f"segment-masked flash attention {out.shape}")


if __name__ == "__main__":
    main()
