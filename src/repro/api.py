"""Cell planning: (architecture x shape x mesh) -> jittable step + shardings.

``plan_cell`` is the single entry point used by the dry-run, the roofline
harness, training/serving launchers and the smoke tests.  It returns the
step function, abstract (ShapeDtypeStruct) arguments — so nothing is
allocated for 100B-param cells — and the in/out shardings resolved from
the logical-axis rules with divisibility fixups for the concrete mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import param as param_lib
from repro.compat import shardingx
from repro.config import (DetectorConfig, DiTConfig, EfficientNetConfig,
                          ShapeConfig, TransformerConfig, ViTConfig, dtype_of)
from repro.models import detector as detector_lib
from repro.models import dit as dit_lib
from repro.models import efficientnet as effnet_lib
from repro.models import transformer as tfm_lib
from repro.models import vit as vit_lib
from repro.sharding import Rules, divisible_sharding
from repro.training import optimizer as opt_lib
from repro.training import train_state


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    kind: str                    # train | prefill | decode | gen | serve
    step_fn: Callable
    args: Tuple[Any, ...]        # abstract trees (ShapeDtypeStructs)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    n_params: int
    n_active_params: int
    notes: str = ""
    # dry-run scaling: the compiled program is one repeated unit (a
    # microbatch / one sampler step); the full step = scale x this unit.
    scale: float = 1.0


def _shard_tree(mesh, abstract_tree, axes_tree, rules: Rules):
    """Zip an abstract tree with a parallel tree of logical-axes tuples."""
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x))
    ab_leaves, treedef = jax.tree_util.tree_flatten(abstract_tree)
    ax_leaves = jax.tree_util.tree_flatten(axes_tree, is_leaf=is_axes_leaf)[0]
    assert len(ab_leaves) == len(ax_leaves), (len(ab_leaves), len(ax_leaves))
    out = [divisible_sharding(mesh, a.shape, ax, rules)
           for a, ax in zip(ab_leaves, ax_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _param_shardings(mesh, specs, rules: Rules):
    pspecs = param_lib.param_pspecs(specs, rules, mesh)
    return param_lib.tree_map_specs(
        lambda s: None, specs) if mesh is None else jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps), pspecs,
        is_leaf=lambda x: isinstance(x, P))


def _opt_shardings(mesh, param_shardings):
    return {
        "m": param_shardings,
        "v": param_shardings,
        "count": NamedSharding(mesh, P()),
    }


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _metric_shardings(mesh):
    rep = _replicated(mesh)
    return {"grad_norm": rep, "lr": rep, "loss": rep}


# ------------------------------------------------------------- factories ----


def _model_module(cfg):
    if isinstance(cfg, TransformerConfig):
        return tfm_lib
    if isinstance(cfg, ViTConfig):
        return vit_lib
    if isinstance(cfg, DiTConfig):
        return dit_lib
    if isinstance(cfg, EfficientNetConfig):
        return effnet_lib
    if isinstance(cfg, DetectorConfig):
        return detector_lib
    raise TypeError(type(cfg))


def param_specs(cfg):
    return _model_module(cfg).param_specs(cfg)


def _loss_fn(cfg, rules: Rules, impl: str = "xla",
             unroll_loss: bool = False):
    if isinstance(cfg, TransformerConfig):
        return lambda p, b: tfm_lib.lm_loss(cfg, p, b, rules, impl=impl,
                                            unroll_loss=unroll_loss)
    if isinstance(cfg, ViTConfig):
        return lambda p, b: vit_lib.cls_loss(cfg, p, b, rules)
    if isinstance(cfg, DiTConfig):
        return lambda p, b: dit_lib.diffusion_loss(cfg, p, b, rules)
    if isinstance(cfg, EfficientNetConfig):
        return lambda p, b: effnet_lib.cls_loss(cfg, p, b, rules)
    if isinstance(cfg, DetectorConfig):
        return lambda p, b: detector_lib.detection_loss(cfg, p, b, rules)
    raise TypeError(type(cfg))


def train_batch_specs(cfg, shape: ShapeConfig):
    """Abstract batch tree + logical axes tree for the train step input."""
    B = shape.global_batch
    if isinstance(cfg, TransformerConfig):
        S = shape.seq_len
        ab = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
              "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        ax = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        return ab, ax
    if isinstance(cfg, DiTConfig):
        side = shape.img_res // cfg.vae_factor
        lat = jax.ShapeDtypeStruct((B, side, side, cfg.latent_channels),
                                   jnp.float32)
        ab = {"latents": lat, "noise": lat,
              "t": jax.ShapeDtypeStruct((B,), jnp.int32),
              "labels": jax.ShapeDtypeStruct((B,), jnp.int32)}
        ax = {"latents": ("batch", None, None, None),
              "noise": ("batch", None, None, None),
              "t": ("batch",), "labels": ("batch",)}
        return ab, ax
    if isinstance(cfg, (ViTConfig, EfficientNetConfig)):
        r = shape.img_res
        ab = {"images": jax.ShapeDtypeStruct((B, r, r, 3), jnp.float32),
              "labels": jax.ShapeDtypeStruct((B,), jnp.int32)}
        ax = {"images": ("batch", "img_h", "img_w", None),
              "labels": ("batch",)}
        return ab, ax
    if isinstance(cfg, DetectorConfig):
        ab = {"canvases": jax.ShapeDtypeStruct((B, cfg.canvas, cfg.canvas, 3),
                                               jnp.float32),
              "boxes": jax.ShapeDtypeStruct((B, 64, 4), jnp.float32),
              "valid": jax.ShapeDtypeStruct((B, 64), jnp.bool_)}
        ax = {"canvases": ("canvas", None, None, None),
              "boxes": ("canvas", None, None), "valid": ("canvas", None)}
        return ab, ax
    raise TypeError(type(cfg))


def plan_train(cfg, shape: ShapeConfig, mesh, rules: Rules, *,
               opt_cfg: Optional[opt_lib.OptimizerConfig] = None,
               accum_steps: int = 1, impl: str = "xla",
               unroll_loss: bool = False, scale: float = 1.0,
               notes: str = "", grad_rs: bool = False) -> CellPlan:
    opt_cfg = opt_cfg or opt_lib.OptimizerConfig()
    specs = param_specs(cfg)
    ab_params = param_lib.abstract_params(specs)
    ab_opt = opt_lib.abstract_state(ab_params)
    ab_batch, batch_axes = train_batch_specs(cfg, shape)

    grad_pspecs = (param_lib.param_pspecs(specs, rules, mesh)
                   if grad_rs else None)
    step = train_state.make_train_step(
        _loss_fn(cfg, rules, impl=impl, unroll_loss=unroll_loss), opt_cfg,
        accum_steps=accum_steps, grad_pspecs=grad_pspecs)
    p_sh = _param_shardings(mesh, specs, rules)
    o_sh = _opt_shardings(mesh, p_sh)
    b_sh = _shard_tree(mesh, ab_batch, batch_axes, rules)
    return CellPlan(
        arch=cfg.name, shape=shape.name, kind="train", step_fn=step,
        args=(ab_params, ab_opt, ab_batch),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, _metric_shardings(mesh)),
        n_params=cfg.n_params, n_active_params=cfg.n_active_params,
        notes=notes or f"accum={accum_steps}", scale=scale)


def plan_prefill(cfg: TransformerConfig, shape: ShapeConfig, mesh,
                 rules: Rules, *, impl: str = "xla") -> CellPlan:
    specs = param_specs(cfg)
    ab_params = param_lib.abstract_params(specs)
    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def step(params, tokens):
        logits, h = tfm_lib.prefill(cfg, params, tokens, rules, impl=impl)
        return logits

    p_sh = _param_shardings(mesh, specs, rules)
    t_sh = divisible_sharding(mesh, (B, S), ("batch", "seq"), rules)
    out_sh = divisible_sharding(mesh, (B, 1, cfg.vocab),
                                ("batch", None, "vocab"), rules)
    return CellPlan(cfg.name, shape.name, "prefill", step,
                    (ab_params, tokens), (p_sh, t_sh), out_sh,
                    cfg.n_params, cfg.n_active_params)


def plan_decode(cfg: TransformerConfig, shape: ShapeConfig, mesh,
                rules: Rules) -> CellPlan:
    specs = param_specs(cfg)
    ab_params = param_lib.abstract_params(specs)
    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache = tfm_lib.init_cache(cfg, B, S, abstract=True)
    cache_ax = tfm_lib.cache_axes(cfg)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, tokens, cache, pos):
        return tfm_lib.decode_step(cfg, params, tokens, cache, pos, rules)

    p_sh = _param_shardings(mesh, specs, rules)
    t_sh = divisible_sharding(mesh, (B, 1), ("decode_batch", None), rules)
    c_sh = _shard_tree(mesh, cache, cache_ax, rules)
    logits_sh = divisible_sharding(mesh, (B, 1, cfg.vocab),
                                   ("decode_batch", None, "vocab"), rules)
    return CellPlan(cfg.name, shape.name, "decode", step,
                    (ab_params, tokens, cache, pos),
                    (p_sh, t_sh, c_sh, _replicated(mesh)),
                    (logits_sh, c_sh),
                    cfg.n_params, cfg.n_active_params,
                    notes=f"kv_cache_len={S}")


def plan_gen(cfg: DiTConfig, shape: ShapeConfig, mesh, rules: Rules, *,
             steps_override: Optional[int] = None, scale: float = 1.0,
             notes: str = "") -> CellPlan:
    specs = param_specs(cfg)
    ab_params = param_lib.abstract_params(specs)
    B = shape.global_batch
    side = shape.img_res // cfg.vae_factor
    noise = jax.ShapeDtypeStruct((B, side, side, cfg.latent_channels),
                                 jnp.float32)
    labels = jax.ShapeDtypeStruct((B,), jnp.int32)
    n_steps = steps_override or shape.steps

    def step(params, noise, labels):
        return dit_lib.ddim_sample(cfg, params, noise, labels, rules,
                                   n_steps=n_steps)

    p_sh = _param_shardings(mesh, specs, rules)
    n_sh = divisible_sharding(mesh, noise.shape, ("batch", None, None, None),
                              rules)
    l_sh = divisible_sharding(mesh, (B,), ("batch",), rules)
    return CellPlan(cfg.name, shape.name, "gen", step,
                    (ab_params, noise, labels), (p_sh, n_sh, l_sh), n_sh,
                    cfg.n_params, cfg.n_active_params,
                    notes=notes or f"sampler_steps={n_steps}", scale=scale)


def plan_serve(cfg, shape: ShapeConfig, mesh, rules: Rules) -> CellPlan:
    specs = param_specs(cfg)
    ab_params = param_lib.abstract_params(specs)
    B, r = shape.global_batch, shape.img_res
    if isinstance(cfg, DetectorConfig):
        images = jax.ShapeDtypeStruct((B, cfg.canvas, cfg.canvas, 3),
                                      jnp.float32)
        step = lambda p, x: detector_lib.serve(cfg, p, x, rules)
        out_sh = None
    else:
        images = jax.ShapeDtypeStruct((B, r, r, 3), jnp.float32)
        mod = _model_module(cfg)
        step = lambda p, x: mod.serve(cfg, p, x, rules)
        out_sh = divisible_sharding(mesh, (B, cfg.n_classes),
                                    ("batch", "vocab"), rules)
    p_sh = _param_shardings(mesh, specs, rules)
    i_sh = divisible_sharding(mesh, images.shape,
                              ("batch", "img_h", "img_w", None), rules)
    return CellPlan(cfg.name, shape.name, "serve", step,
                    (ab_params, images), (p_sh, i_sh), out_sh,
                    cfg.n_params, cfg.n_active_params)


CHUNKED_SEQ = 2048       # LM seq length at/above which the pure-XLA
                         # chunked flash stand-in replaces naive attention


def plan_cell(cfg, shape: ShapeConfig, mesh, rules: Rules, *,
              accum_steps: int = 1,
              opt_cfg: Optional[opt_lib.OptimizerConfig] = None,
              dryrun: bool = False,
              depth_override: Optional[int] = None,
              grad_rs: bool = False) -> CellPlan:
    """Plan a cell.

    Exec mode (default): the production program — scan-over-layers as
    configured, chunked (flash-equivalent) attention for LM cells with
    seq >= CHUNKED_SEQ, microbatch accumulation as configured.

    Unit mode (``dryrun=True``): one *repeated unit* with exact HLO
    accounting — unrolled layers (optionally ``depth_override`` of them),
    unrolled loss chunks, one microbatch, one sampler step — with
    ``scale`` = units per full step.  XLA's cost_analysis counts
    while-loop bodies once, so scanned programs undercount
    flops/collectives by the trip count; the dry-run derives exact totals
    from two unit compiles at depths 1 and 2 (secant over depth, see
    launch/dryrun.py and EXPERIMENTS.md §Dry-run).
    """
    if dryrun:
        replace = {}
        if getattr(cfg, "scan_layers", False):
            replace["scan_layers"] = False
        if depth_override is not None and hasattr(cfg, "n_layers"):
            replace["n_layers"] = depth_override
        if replace:
            cfg = dataclasses.replace(cfg, **replace)

    lm_seq = shape.seq_len if isinstance(cfg, TransformerConfig) else 0

    if shape.kind in ("train", "cls"):
        impl = "chunked" if lm_seq >= CHUNKED_SEQ else "xla"
        if dryrun and accum_steps > 1:
            micro = dataclasses.replace(
                shape, global_batch=shape.global_batch // accum_steps)
            return plan_train(
                cfg, micro, mesh, rules, opt_cfg=opt_cfg, accum_steps=1,
                impl=impl, unroll_loss=dryrun, scale=float(accum_steps),
                notes=f"unit=microbatch({micro.global_batch}) "
                      f"x{accum_steps}; optimizer counted per unit",
                grad_rs=grad_rs)
        return plan_train(cfg, shape, mesh, rules, opt_cfg=opt_cfg,
                          accum_steps=accum_steps, impl=impl,
                          unroll_loss=dryrun, grad_rs=grad_rs)
    if shape.kind == "prefill":
        impl = "chunked" if lm_seq >= CHUNKED_SEQ else "xla"
        return plan_prefill(cfg, shape, mesh, rules, impl=impl)
    if shape.kind == "decode":
        return plan_decode(cfg, shape, mesh, rules)
    if shape.kind == "gen":
        if dryrun and shape.steps > 1:
            return plan_gen(cfg, shape, mesh, rules, steps_override=1,
                            scale=float(shape.steps),
                            notes=f"unit=1 sampler step x{shape.steps}")
        return plan_gen(cfg, shape, mesh, rules)
    if shape.kind == "serve":
        return plan_serve(cfg, shape, mesh, rules)
    raise ValueError(shape.kind)


def lower_cell(plan: CellPlan, mesh):
    """Lower (not compile) the planned step on the mesh."""
    with shardingx.use_mesh(mesh):
        jitted = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings)
        return jitted.lower(*plan.args)
