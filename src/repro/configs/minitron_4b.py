"""minitron-4b [arXiv:2407.14679; hf] — pruned nemotron.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, dense.
24 heads / 8 kv heads do not divide the 16-way model axis — attention
projections replicated; MLP (9216) and vocab (256000) shard on "model".
"""
from repro.config import LM_SHAPES, TransformerConfig
from repro.configs import CellOverride

ARCH = TransformerConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256_000,
    head_dim=128,
)

SHAPES = LM_SHAPES

OVERRIDES = {
    "train_4k": CellOverride(accum_steps=2, fsdp=True, act_seq=True,
                             remat_policy="minimal"),
    "decode_32k": CellOverride(sequence_parallel=True),
    "long_500k": CellOverride(sequence_parallel=True),
}
