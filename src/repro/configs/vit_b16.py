"""vit-b16 [arXiv:2010.11929; paper] — ViT-B/16."""
from repro.config import VISION_SHAPES, ViTConfig

ARCH = ViTConfig(
    name="vit-b16",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=768,
    n_heads=12,
    d_ff=3072,
)

SHAPES = VISION_SHAPES
