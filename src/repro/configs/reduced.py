"""Reduced same-family configs for CPU smoke tests and examples.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); these shrink width/depth/experts/resolution while keeping the
family structure (MoE stays MoE with shared experts, DeiT keeps its
distillation token, EfficientNet keeps compound scaling, etc.).
"""
from __future__ import annotations

import dataclasses

from repro.config import (DetectorConfig, DiTConfig, EfficientNetConfig,
                          ShapeConfig, TransformerConfig, ViTConfig)


def reduce_arch(model):
    """Any full config -> small CPU-runnable config of the same family."""
    if isinstance(model, TransformerConfig):
        moe = None
        if model.moe is not None:
            moe = dataclasses.replace(
                model.moe, n_experts=min(model.moe.n_experts, 8),
                top_k=min(model.moe.top_k, 2),
                n_shared=min(model.moe.n_shared, 1),
                d_ff_expert=64, group_size=64)
        return dataclasses.replace(
            model, n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2 if model.n_kv_heads < model.n_heads else 4,
            d_ff=256, vocab=512, head_dim=32, moe=moe,
            param_dtype="float32", compute_dtype="float32", remat=False)
    if isinstance(model, ViTConfig):
        return dataclasses.replace(
            model, img_res=64, patch=16, n_layers=2, d_model=64, n_heads=4,
            d_ff=128, n_classes=16, param_dtype="float32",
            compute_dtype="float32", remat=False)
    if isinstance(model, DiTConfig):
        return dataclasses.replace(
            model, img_res=64, patch=2, n_layers=2, d_model=64, n_heads=4,
            n_classes=16, param_dtype="float32", compute_dtype="float32",
            remat=False)
    if isinstance(model, EfficientNetConfig):
        return dataclasses.replace(
            model, img_res=64, width_mult=0.35, depth_mult=0.35,
            n_classes=16, param_dtype="float32", compute_dtype="float32")
    if isinstance(model, DetectorConfig):
        return dataclasses.replace(
            model, canvas=128, patch=32, n_layers=2, d_model=64, n_heads=4,
            d_ff=128, param_dtype="float32", compute_dtype="float32")
    raise TypeError(type(model))


def reduce_shape(model, shape: ShapeConfig) -> ShapeConfig:
    """Shrink a shape cell to smoke-test size for the reduced config."""
    kw = dict(seq_len=min(shape.seq_len, 128) if shape.seq_len else 0,
              global_batch=min(shape.global_batch, 4) or 2,
              steps=min(shape.steps, 2) if shape.steps else 0)
    if shape.img_res:
        if isinstance(model, DiTConfig):
            kw["img_res"] = 64 if shape.img_res <= 512 else 128
        else:
            kw["img_res"] = 64 if shape.img_res <= 300 else 128
    return ShapeConfig(shape.name, shape.kind, **kw)
