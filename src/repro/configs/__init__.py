"""Arch registry: ``--arch <id>`` resolves here.

Each module defines ``ARCH`` (the model config), ``SHAPES`` (its shape
set), and optionally ``OVERRIDES`` (per-shape plan knobs: accum steps,
sharding overlay flags).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.config import ShapeConfig

ARCH_IDS = (
    "deepseek-moe-16b",
    "llama4-scout-17b-a16e",
    "minitron-4b",
    "mistral-large-123b",
    "dit-s2",
    "dit-xl2",
    "deit-b",
    "vit-s16",
    "efficientnet-b7",
    "vit-b16",
    # the paper's own serving model
    "tangram-detector",
)


@dataclasses.dataclass(frozen=True)
class CellOverride:
    accum_steps: int = 1
    fsdp: bool = False
    sequence_parallel: bool = False      # KV-cache seq over "model" (decode)
    act_seq: bool = False                # activation seq-sharding (train)
    remat_policy: Optional[str] = None   # override model remat policy
    extra_rules: Optional[dict] = None   # arch-specific rule overlay
    quant_weights: bool = False          # int8-resident weights (serving)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    model: object
    shapes: Tuple[ShapeConfig, ...]
    overrides: Dict[str, CellOverride]

    def override(self, shape_name: str) -> CellOverride:
        return self.overrides.get(shape_name, CellOverride())


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_"))
    return ArchSpec(arch_id, mod.ARCH, tuple(mod.SHAPES),
                    getattr(mod, "OVERRIDES", {}))


def all_cells():
    """Yield every (arch_id, shape) dry-run cell (40 for the pool)."""
    for arch_id in ARCH_IDS:
        if arch_id == "tangram-detector":
            continue
        spec = get(arch_id)
        for shape in spec.shapes:
            yield arch_id, shape
