"""deit-b [arXiv:2012.12877; paper] — DeiT-Base with distillation token."""
from repro.config import VISION_SHAPES, ViTConfig

ARCH = ViTConfig(
    name="deit-b",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=768,
    n_heads=12,
    d_ff=3072,
    distill_token=True,
)

SHAPES = VISION_SHAPES
