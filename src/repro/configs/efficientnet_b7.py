"""efficientnet-b7 [arXiv:1905.11946; paper] — w2.0 d3.1 r600.

Conv-dominant: weights replicated (DP regime, see DESIGN.md §4);
classifier head shards over "model"; BN is cross-replica (sync-BN via
sharded batch means).  Vision shape cells run at their own resolutions
(cls_224/cls_384/serve_*), the native 600px resolution is exercised by
the per-arch smoke test and the roofline extras.
"""
from repro.config import EfficientNetConfig, VISION_SHAPES
from repro.configs import CellOverride

ARCH = EfficientNetConfig(
    name="efficientnet-b7",
    img_res=600,
    width_mult=2.0,
    depth_mult=3.1,
)

SHAPES = VISION_SHAPES

# Conv nets don't use tensor parallelism at 66M params: fold the "model"
# axis into data parallelism (batch shards over data x model) so all 256
# chips do useful work instead of replicating convs 16x.
_DP_ALL = {"batch": ("data", "model", "pod")}
OVERRIDES = {s.name: CellOverride(extra_rules=_DP_ALL) for s in SHAPES}
