"""The paper's own serving model: ViT-backbone detector on 1024^2 canvases.

~100M params (ViT-B trunk at patch 32): the model the serverless function
executes on stitched canvases and the one trained in
``examples/train_detector.py``.
"""
from repro.config import DetectorConfig, ShapeConfig

ARCH = DetectorConfig(
    name="tangram-detector",
    canvas=1024,
    patch=32,
    n_layers=12,
    d_model=768,
    n_heads=12,
    d_ff=3072,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SHAPES = (
    ShapeConfig("serve_c8", "serve", img_res=1024, global_batch=8),
    ShapeConfig("serve_c1", "serve", img_res=1024, global_batch=1),
    ShapeConfig("train_c32", "train", img_res=1024, global_batch=32),
)
