"""dit-s2 [arXiv:2212.09748; paper] — DiT-S/2: 12L d=384 6H, patch 2."""
from repro.config import DIFFUSION_SHAPES, DiTConfig
from repro.configs import CellOverride

ARCH = DiTConfig(
    name="dit-s2",
    img_res=256,
    patch=2,
    n_layers=12,
    d_model=384,
    n_heads=6,
)

SHAPES = DIFFUSION_SHAPES

# batch 4 < 16 data rows: token context-parallelism (see dit_xl2.py)
OVERRIDES = {
    "gen_1024": CellOverride(extra_rules={"seq": "data"}),
}
