"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
~109B total / ~17B active parameters; FSDP overlay shards optimizer state.
Note: 40 heads / 8 kv heads do not divide the 16-way model axis — attention
projections stay replicated (see DESIGN.md §4 / sharding.divisible_spec);
experts (16) shard 1-per-device on "model".
"""
from repro.config import LM_SHAPES, MoEConfig, TransformerConfig
from repro.configs import CellOverride

ARCH = TransformerConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    head_dim=128,
    # group_size 128: MoE dispatch-einsum cost is ~linear in group size
    # (§Perf llama4 v7: 512 -> 128 cut the collective term a further 26 %)
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=0, d_ff_expert=8192,
                  capacity_factor=1.25, group_size=128),
)

SHAPES = LM_SHAPES

OVERRIDES = {
    # accum 1 (single FSDP param-gather per step) + act_seq (doubles as
    # context parallelism for the replicated 40-head attention): §Perf v7
    "train_4k": CellOverride(accum_steps=1, fsdp=True, act_seq=True,
                             remat_policy="minimal"),
    "prefill_32k": CellOverride(fsdp=True),
    # int8-resident weights: no per-token FSDP regathers (§Perf v3)
    "decode_32k": CellOverride(sequence_parallel=True, quant_weights=True),
    "long_500k": CellOverride(fsdp=True, sequence_parallel=True,
                              quant_weights=True),
}
