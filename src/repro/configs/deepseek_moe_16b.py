"""deepseek-moe-16b [arXiv:2401.06066; hf]

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed top-6, fine-grained experts (d_ff_expert=1408).
"""
from repro.config import LM_SHAPES, MoEConfig, TransformerConfig
from repro.configs import CellOverride

ARCH = TransformerConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  capacity_factor=1.25, group_size=512),
)

SHAPES = LM_SHAPES

OVERRIDES = {
    "train_4k": CellOverride(accum_steps=2, fsdp=True, act_seq=True,
                             remat_policy="minimal"),
    "prefill_32k": CellOverride(fsdp=True),
}
