"""vit-s16 [arXiv:2010.11929; paper] — ViT-S/16."""
from repro.config import VISION_SHAPES, ViTConfig

ARCH = ViTConfig(
    name="vit-s16",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=384,
    n_heads=6,
    d_ff=1536,
)

SHAPES = VISION_SHAPES
