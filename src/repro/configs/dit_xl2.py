"""dit-xl2 [arXiv:2212.09748; paper] — DiT-XL/2: 28L d=1152 16H, patch 2."""
from repro.config import DIFFUSION_SHAPES, DiTConfig
from repro.configs import CellOverride

ARCH = DiTConfig(
    name="dit-xl2",
    img_res=256,
    patch=2,
    n_layers=28,
    d_model=1152,
    n_heads=16,
)

SHAPES = DIFFUSION_SHAPES

OVERRIDES = {
    "train_1024": CellOverride(accum_steps=1),
    # batch 4 < 16 data rows: shard the 4096 latent tokens over the idle
    # data axis (context parallelism) — §Perf dit_gen v1: dominant
    # memory term 9.28 s -> 0.81 s (11.5x)
    "gen_1024": CellOverride(extra_rules={"seq": "data"}),
}
