"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified]

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768, dense.
123B params: FSDP overlay (params + optimizer state sharded over "data"
as well as "model") and 4-way microbatch accumulation for train_4k.
"""
from repro.config import LM_SHAPES, TransformerConfig
from repro.configs import CellOverride

ARCH = TransformerConfig(
    name="mistral-large-123b",
    n_layers=88,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=32_768,
    head_dim=128,
)

SHAPES = LM_SHAPES

OVERRIDES = {
    "train_4k": CellOverride(accum_steps=4, fsdp=True, act_seq=True,
                             remat_policy="minimal"),
    "prefill_32k": CellOverride(fsdp=True),
    # int8-resident weights (123B x 1B / 16 = 7.7 GiB/chip): kills the
    # per-token FSDP parameter regathers — §Perf mistral_decode v3:
    # collective term 0.615 s -> 0.0036 s (172x)
    "decode_32k": CellOverride(sequence_parallel=True, quant_weights=True),
    # batch=1: activations are tiny so GSPMD keeps weights sharded under
    # FSDP (no per-token gathers measured); FSDP + int8 leaves headroom
    # beside the 11.8 GiB/dev KV cache
    "long_500k": CellOverride(fsdp=True, sequence_parallel=True,
                              quant_weights=True),
}
