"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state, so smoke tests keep their 1-device world.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(*, multi_pod: bool = False):
    """Small stand-in meshes for CI (8 fake host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
