"""Mesh construction for every launch surface.

FUNCTIONS, not module-level constants: importing this module never touches
jax device state, so smoke tests keep their 1-device world.  All factories
route through the version-gated compat layer (``repro.compat.shardingx``),
which papers over the ``jax.make_mesh`` / axis-types API drift.
"""
from __future__ import annotations

import jax

from repro.compat import shardingx


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shardingx.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small stand-in meshes for CI (8 fake host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shardingx.make_mesh(shape, axes)


def make_unit_mesh():
    """1x1 (data, model) mesh for single-device smoke tests: the same
    rule tables resolve, every axis collapses to size 1."""
    return shardingx.make_mesh((1, 1), ("data", "model"))


def make_serve_mesh(n_devices: int | None = None):
    """Data-parallel serving mesh over the local device set.

    The canvas batch shards its leading axis over "data"; "model" is kept
    (size 1) so the standard rule tables resolve unchanged.  On a
    1-device world this degenerates to the unit mesh and sharding is a
    no-op — the serve driver runs identically either way.
    """
    devices = jax.devices()
    n = n_devices if n_devices is not None else len(devices)
    return shardingx.make_mesh((n, 1), ("data", "model"),
                               devices=devices[:n])


def make_worker_meshes(n_workers: int, devices=None):
    """Split the device set into ``n_workers`` independent serve meshes.

    Each worker mesh is a contiguous (data, model) slice the shape of
    :func:`make_serve_mesh`, so per-worker executors shard their canvas
    batches data-parallel *within* their slice while the worker pool
    routes concurrent invocations *across* slices.  With fewer devices
    than workers the devices are reused round-robin (worker i pins device
    ``i % n_devices`` — on a 1-device host every worker degenerates to
    the unit mesh and the pool still exercises the full routing path).
    Leftover devices (``n_devices % n_workers``) are left unused so every
    worker has identical capacity and the latency profile of one worker
    holds for all.
    """
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) >= n_workers:
        per = len(devices) // n_workers
        slices = [devices[i * per:(i + 1) * per] for i in range(n_workers)]
    else:
        slices = [[devices[i % len(devices)]] for i in range(n_workers)]
    return [shardingx.make_mesh((len(sl), 1), ("data", "model"), devices=sl)
            for sl in slices]


def mesh_chips(mesh) -> int:
    return mesh.devices.size
