import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms from the compiled artifact.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices for the
2 x 16 x 16 multi-pod mesh.  (Smoke tests / benches import other modules
and keep a 1-device world.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch vit-b16
  PYTHONPATH=src python -m repro.launch.dryrun --all --json out/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch dit-xl2 --multi-pod
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro import api
from repro import configs as cfg_registry
from repro.compat import shardingx
from repro.config import HardwareConfig, shapes_for
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, make_test_mesh, mesh_chips
from repro.sharding import ShardingConfig


def input_specs(arch_id: str, shape_name: str = None):
    """ShapeDtypeStruct stand-ins for every model input of the arch's
    cells: {shape_name: args tuple} (weak-type-correct, no allocation)."""
    spec = cfg_registry.get(arch_id)
    mesh = make_test_mesh()
    out = {}
    for shape in spec.shapes:
        if shape_name and shape.name != shape_name:
            continue
        ov = spec.override(shape.name)
        rules = ShardingConfig.make(fsdp=ov.fsdp,
                                    sequence_parallel=ov.sequence_parallel).rules
        plan = api.plan_cell(spec.model, shape, mesh, rules,
                             accum_steps=ov.accum_steps)
        out[shape.name] = plan.args
    return out


def _compile_metrics(plan, mesh):
    compiled = api.lower_cell(plan, mesh).compile()
    ca = shardingx.cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    coll = hlo_analysis.parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll.total_bytes,
        "coll_by_kind": coll.bytes_by_kind,
        "coll_count": coll.total_count,
        "args": ma.argument_size_in_bytes if ma else 0,
        "temp": ma.temp_size_in_bytes if ma else 0,
        "out": ma.output_size_in_bytes if ma else 0,
    }


def run_cell(arch_id: str, shape, mesh, mesh_name: str, hw: HardwareConfig,
             verbose: bool = True, rules_override=None, accum_override=None,
             model_override=None, quick: bool = False,
             grad_rs: bool = False):
    """Three compiles per cell:

    1. the full production program (proves it compiles; memory analysis;
       collective schedule),
    2-3. unit programs at depths 1 and 2 (unrolled; exact HLO accounting).
    Totals = secant over depth: R + L*B with B = m2 - m1, R = m1 - B,
    times the unit scale (microbatch accum / sampler steps).  This is
    exact for repeated-layer models; XLA's cost_analysis counts scanned
    while bodies once, which the full program alone cannot correct.
    """
    spec = cfg_registry.get(arch_id)
    model = model_override if model_override is not None else spec.model
    ov = spec.override(shape.name)
    rules = rules_override if rules_override is not None else \
        ShardingConfig.make(fsdp=ov.fsdp,
                            sequence_parallel=ov.sequence_parallel,
                            act_seq=ov.act_seq,
                            extra=ov.extra_rules).rules
    if ov.remat_policy and hasattr(model, "remat_policy"):
        model = dataclasses.replace(model, remat_policy=ov.remat_policy)
    if ov.quant_weights and hasattr(model, "quant_weights"):
        model = dataclasses.replace(model, quant_weights=True)
    accum = accum_override or ov.accum_steps

    t0 = time.time()
    full_plan = api.plan_cell(model, shape, mesh, rules, accum_steps=accum,
                              grad_rs=grad_rs)
    full = _compile_metrics(full_plan, mesh)

    if not quick and hasattr(model, "n_layers") and model.n_layers > 1:
        u1_plan = api.plan_cell(model, shape, mesh, rules, accum_steps=accum,
                                dryrun=True, depth_override=1,
                                grad_rs=grad_rs)
        u1 = _compile_metrics(u1_plan, mesh)
        u2_plan = api.plan_cell(model, shape, mesh, rules, accum_steps=accum,
                                dryrun=True, depth_override=2,
                                grad_rs=grad_rs)
        u2 = _compile_metrics(u2_plan, mesh)
        L, scale = model.n_layers, u1_plan.scale

        def total(key):
            b = u2[key] - u1[key]
            return (u1[key] + (L - 1) * b) * scale
        flops, by, coll = total("flops"), total("bytes"), total("coll")
        method = f"secant(L={L}, scale={scale:g})"
    else:
        flops, by, coll = full["flops"], full["bytes"], full["coll"]
        method = "direct"
    compile_s = time.time() - t0

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_size = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
    terms = hlo_analysis.RooflineTerms(
        arch=arch_id, shape=shape.name, mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=by,
        collective_bytes_per_device=coll,
        peak_flops=hw.peak_flops, hbm_bw=hw.hbm_bw, ici_bw=hw.ici_bw,
        model_flops_global=hlo_analysis.model_flops(
            full_plan.n_params, full_plan.n_active_params, shape,
            full_plan.kind, model),
        chips=mesh_chips(mesh),
        arg_bytes=full["args"],
        temp_bytes=full["temp"],
        out_bytes=full["out"],
        analytic_act_bytes=hlo_analysis.estimate_activation_bytes(
            model, shape, full_plan.kind, data_size,
            axis_sizes.get("model", 1), accum, act_seq=ov.act_seq),
        notes=f"{full_plan.notes}; {method}")
    coll_by_kind = full["coll_by_kind"]
    coll_count = full["coll_count"]

    if verbose:
        print(f"== {arch_id} x {shape.name} on {mesh_name} "
              f"(3 compiles, {compile_s:.1f}s) ==")
        print(f"  memory/dev: args={terms.arg_bytes/2**30:.3f}GiB "
              f"analytic_act={terms.analytic_act_bytes/2**30:.3f}GiB "
              f"(xla-cpu temp={terms.temp_bytes/2**30:.1f}GiB, pessimistic) "
              f"HBM {hw.hbm_bytes/2**30:.0f}GiB [{terms.notes}]")
        print(f"  per-step totals/dev: flops={terms.flops_per_device:.3e} "
              f"bytes={terms.bytes_per_device:.3e} "
              f"collective={terms.collective_bytes_per_device:.3e}B")
        print(f"  schedule (full program, scan bodies once): "
              f"{coll_count} collective ops "
              f"{ {k: f'{v:.2e}' for k, v in coll_by_kind.items() if v} }")
        print(f"  roofline: t_comp={terms.t_compute:.3e}s "
              f"t_mem={terms.t_memory:.3e}s t_coll={terms.t_collective:.3e}s "
              f"-> {terms.bottleneck}-bound, "
              f"useful_flops={terms.useful_flops_ratio:.2f}, "
              f"frac={terms.roofline_fraction:.2f}")
    fits = terms.hbm_estimate <= hw.hbm_bytes
    if verbose and not fits:
        print("  !! estimated footprint exceeds per-chip HBM")
    return terms, compile_s, fits


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=cfg_registry.ARCH_IDS)
    p.add_argument("--shape")
    p.add_argument("--all", action="store_true", help="all 40 pool cells")
    p.add_argument("--multi-pod", action="store_true",
                   help="2x16x16 (512 chips) instead of 16x16")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--mesh", choices=("production", "test"),
                   default="production")
    p.add_argument("--json", help="write results JSON here")
    p.add_argument("--quick", action="store_true",
                   help="compile-proof only (skip secant unit compiles; "
                        "totals are scan-undercounted — multi-pod pass)")
    args = p.parse_args(argv)

    hw = HardwareConfig()
    meshes = []
    make = make_production_mesh if args.mesh == "production" else make_test_mesh
    if args.both_meshes:
        meshes = [(make(multi_pod=False), "16x16"),
                  (make(multi_pod=True), "2x16x16")]
    else:
        mesh = make(multi_pod=args.multi_pod)
        meshes = [(mesh, "2x16x16" if args.multi_pod else "16x16")]
    if args.mesh == "test":
        meshes = [(m, n + "-test") for m, n in meshes]

    cells = []
    if args.all:
        cells = list(cfg_registry.all_cells())
    elif args.arch:
        spec = cfg_registry.get(args.arch)
        cells = [(args.arch, s) for s in spec.shapes
                 if not args.shape or s.name == args.shape]
    else:
        p.error("--arch or --all required")

    results, failures = [], []
    for mesh, mesh_name in meshes:
        for arch_id, shape in cells:
            try:
                terms, compile_s, fits = run_cell(arch_id, shape, mesh,
                                                  mesh_name, hw,
                                                  quick=args.quick)
                row = terms.row()
                row.update(compile_s=round(compile_s, 1), fits_hbm=fits,
                           flops_per_device=terms.flops_per_device,
                           bytes_per_device=terms.bytes_per_device,
                           collective_bytes_per_device=
                           terms.collective_bytes_per_device,
                           arg_bytes=terms.arg_bytes,
                           temp_bytes=terms.temp_bytes,
                           analytic_act_bytes=terms.analytic_act_bytes,
                           hbm_estimate=terms.hbm_estimate,
                           model_flops_global=terms.model_flops_global,
                           chips=terms.chips)
                results.append(row)
            except Exception as e:  # a failing cell is a bug in the system
                traceback.print_exc()
                failures.append((arch_id, shape.name, mesh_name, repr(e)))

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"results": results,
                       "failures": failures}, f, indent=1)
        print(f"wrote {args.json}")

    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
