import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: named variants per chosen cell, re-lowered and
re-analysed on the production mesh; results accumulate in
out/hillclimb.json for the EXPERIMENTS.md §Perf log.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --cell llama4_train --variant v1_grad_rs
  PYTHONPATH=src python -m repro.launch.hillclimb --cell all
"""
import argparse
import dataclasses
import json

from repro import configs as cfg_registry
from repro.compat import shardingx
from repro.config import HardwareConfig
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh
from repro.sharding import ShardingConfig

OUT = "out/hillclimb.json"


def _shape(arch_id, name):
    return [s for s in cfg_registry.get(arch_id).shapes if s.name == name][0]


def _moe_group(model, group):
    return dataclasses.replace(
        model, moe=dataclasses.replace(model.moe, group_size=group))


# variant -> kwargs for run_cell (model_override built lazily)
CELLS = {
    "llama4_train": {
        "arch": "llama4-scout-17b-a16e", "shape": "train_4k",
        "variants": {
            "base": {},
            "v1_grad_rs": {"grad_rs": True},
            "v2_accum4": {"accum_override": 4},
            "v3_moe_group2048": {"model_fn": lambda m: _moe_group(m, 2048)},
            "v4_rs_accum4": {"grad_rs": True, "accum_override": 4},
            "v5_rs_accum4_group2048": {
                "grad_rs": True, "accum_override": 4,
                "model_fn": lambda m: _moe_group(m, 2048)},
            # round 2: one param-gather per step + cheap dispatch
            "v6_accum1": {"accum_override": -1},     # -1 -> accum 1
            "v7_accum1_group128": {
                "accum_override": -1,
                "model_fn": lambda m: _moe_group(m, 128)},
            # round 3: drop activation seq-sharding (its per-layer seq
            # all-gathers get replayed 3x under minimal remat); accum 4
            # keeps the unsharded carries within HBM
            "v8_accum4_group128_noactseq": {
                "accum_override": 4,
                "model_fn": lambda m: _moe_group(m, 128),
                "rules": {"act_seq": False}},
            "v9_accum2_group128": {
                "accum_override": 2,
                "model_fn": lambda m: _moe_group(m, 128)},
        },
    },
    "mistral_decode": {
        "arch": "mistral-large-123b", "shape": "decode_32k",
        "variants": {
            "base_dus": {"model_fn": lambda m: dataclasses.replace(
                m, cache_update="dus")},
            "v1_masked_update": {"model_fn": lambda m: dataclasses.replace(
                m, cache_update="masked")},
            "v2_masked_fused_qkv": {"model_fn": lambda m: dataclasses.replace(
                m, cache_update="masked", fused_qkv=True)},
            # round 2: int8-resident weights, no FSDP -> no per-token
            # parameter regathers (the measured collective source)
            "v3_int8_resident": {
                "model_fn": lambda m: dataclasses.replace(
                    m, cache_update="masked", quant_weights=True),
                "rules": {"fsdp": False, "sequence_parallel": True}},
            # round 3: int8 KV cache halves the remaining streaming bound
            "v4_int8_weights_and_kv": {
                "model_fn": lambda m: dataclasses.replace(
                    m, cache_update="masked", quant_weights=True,
                    quant_kv=True),
                "rules": {"fsdp": False, "sequence_parallel": True}},
        },
    },
    "dit_gen": {
        # bonus cell: dit-xl2 gen_1024 wastes 12/16 data rows (batch 4);
        # latent tokens (4096) can shard over the idle data axis —
        # context parallelism for the bidirectional encoder
        "arch": "dit-xl2", "shape": "gen_1024",
        "variants": {
            "base": {},
            "v1_token_cp": {"rules": {"extra": {"seq": "data"}}},
        },
    },
    "vit_serve": {
        "arch": "vit-b16", "shape": "serve_b128",
        "variants": {
            "base": {},
            "v1_fused_qkv": {"model_fn": lambda m: dataclasses.replace(
                m, fused_qkv=True)},
            "v2_conv_patch": {"model_fn": lambda m: dataclasses.replace(
                m, patch_embed="conv")},
            "v3_fused_conv": {"model_fn": lambda m: dataclasses.replace(
                m, fused_qkv=True, patch_embed="conv")},
            # round 2: shard head_dim (64/16 divides; heads 12 does not)
            "v4_head_dim_tp": {
                "rules": {"extra": {"heads": None, "kv_heads": None,
                                    "head_dim": "model"}}},
            # round 2: spatial-partition the patch-embed stem
            "v5_spatial_stem": {
                "model_fn": lambda m: dataclasses.replace(
                    m, patch_embed="conv"),
                "rules": {"extra": {"img_h": "model"}}},
        },
    },
}


def run_variant(cell_name: str, variant: str, mesh, hw):
    cell = CELLS[cell_name]
    spec = cfg_registry.get(cell["arch"])
    shape = _shape(cell["arch"], cell["shape"])
    kw = dict(cell["variants"][variant])
    model_fn = kw.pop("model_fn", None)
    model = model_fn(spec.model) if model_fn else None
    ov = spec.override(shape.name)
    rules_kw = kw.pop("rules", None)
    if rules_kw is not None:
        base_kw = dict(fsdp=ov.fsdp, sequence_parallel=ov.sequence_parallel,
                       act_seq=ov.act_seq, extra=ov.extra_rules)
        base_kw.update(rules_kw)
        kw["rules_override"] = ShardingConfig.make(**base_kw).rules
    if kw.get("accum_override") == -1:
        kw["accum_override"] = None
        kw["accum_override"] = 1
    # apply per-cell remat override exactly as the baseline dry-run does
    if ov.remat_policy and model is not None and hasattr(model,
                                                         "remat_policy"):
        model = dataclasses.replace(model, remat_policy=ov.remat_policy)
    terms, compile_s, fits = run_cell(
        cell["arch"], shape, mesh, "16x16", hw, verbose=False,
        model_override=model, **kw)
    row = {
        "cell": cell_name, "variant": variant,
        "t_compute": terms.t_compute, "t_memory": terms.t_memory,
        "t_collective": terms.t_collective,
        "bottleneck": terms.bottleneck,
        "useful": terms.useful_flops_ratio,
        "frac": terms.roofline_fraction,
        "hbm_gib": terms.hbm_estimate / 2**30,
        "fits": fits, "compile_s": compile_s,
    }
    print(f"{cell_name:16s} {variant:24s} "
          f"t_comp={row['t_compute']:.3e} t_mem={row['t_memory']:.3e} "
          f"t_coll={row['t_collective']:.3e} [{row['bottleneck']}] "
          f"frac={row['frac']:.3f} fits={fits}")
    return row


def run_detector_stitch(mesh, hw):
    """Extra §Perf experiment: Tangram serving with device-side stitching.

    base: the serverless function receives pre-assembled canvases
          (B, 1024, 1024, 3) — the paper's host-assembly model.
    v1:   the function receives compact patch slots (P, 256, 256, 3) +
          records and assembles canvases on-device (stitch kernel; the
          XLA stand-in here is the jnp oracle).  At the measured 0.65
          mean canvas efficiency the input bytes drop ~35 %.
    """
    import jax
    import jax.numpy as jnp
    from repro import api, param as param_lib
    from repro.kernels.stitch.ref import stitch_reference
    from repro.models import detector as det
    from repro.launch.dryrun import _compile_metrics

    spec = cfg_registry.get("tangram-detector")
    model = spec.model
    shape = _shape("tangram-detector", "serve_c8")
    rules = ShardingConfig.make().rules
    rows = []

    base_plan = api.plan_cell(model, shape, mesh, rules)
    base = _compile_metrics(base_plan, mesh)

    B, M = shape.global_batch, model.canvas
    P, K, slot = 84, 12, 256            # 0.65 efficiency worth of slots
    specs = api.param_specs(model)
    ab_params = param_lib.abstract_params(specs)
    slots = jax.ShapeDtypeStruct((P, slot, slot, 3), jnp.float32)
    records = jax.ShapeDtypeStruct((B, K, 6), jnp.int32)

    def step(params, slots, records):
        canvases = stitch_reference(slots, records, M, M)
        return det.serve(model, params, canvases, rules)

    from repro.sharding import divisible_sharding
    p_sh = api._param_shardings(mesh, specs, rules)
    s_sh = divisible_sharding(mesh, slots.shape, ("canvas", None, None, None),
                              rules)
    r_sh = api._replicated(mesh)
    with shardingx.use_mesh(mesh):
        compiled = jax.jit(step, in_shardings=(p_sh, s_sh, r_sh)).lower(
            ab_params, slots, records).compile()
    ca = shardingx.cost_analysis_dict(compiled)
    v1 = {"flops": float(ca.get("flops", 0)),
          "bytes": float(ca.get("bytes accessed", 0)),
          "args": compiled.memory_analysis().argument_size_in_bytes}

    canvas_in = B * M * M * 3 * 4
    slot_in = P * slot * slot * 3 * 4
    for name, m_ in (("base_host_assembled", base),
                     ("v1_device_stitch", v1)):
        rows.append({"cell": "detector_stitch", "variant": name,
                     "t_memory": m_["bytes"] / hw.hbm_bw,
                     "arg_bytes": m_["args"]})
        print(f"detector_stitch  {name:24s} bytes/dev={m_['bytes']:.3e} "
              f"args={m_['args']/2**20:.0f}MiB")
    print(f"  input bytes: canvases {canvas_in/2**20:.0f} MiB vs slots "
          f"{slot_in/2**20:.0f} MiB ({100*(1-slot_in/canvas_in):.0f}% less "
          f"host->device traffic)")
    return rows


#: block_rows candidates for the fused stitch->embed kernel's embed
#: matmul chunking (patch rows per MXU dispatch)
KERNEL_BLOCK_CANDIDATES = (1, 2, 4, 8)


def pick_block_rows(m: int, n: int, patch: int, default=None):
    """Best fused-embed ``block_rows`` for this canvas geometry from a
    prior ``--cell kernel_blocks`` run (cached in out/hillclimb.json);
    ``default`` when the cell never ran for this geometry."""
    try:
        rows = json.load(open(OUT))
    except (OSError, ValueError):
        return default
    best = None
    for r in rows:
        if (r.get("cell") == "kernel_blocks" and r.get("m") == m
                and r.get("n") == n and r.get("patch") == patch):
            if best is None or r["mu_s"] < best["mu_s"]:
                best = r
    return best["block_rows"] if best else default


def run_kernel_blocks(m: int = 128, n: int = 128, patch: int = 32,
                      d_model: int = 64, smoke: bool = False):
    """§Perf: block-shape search for the fused stitch->embed kernel.

    Times the interpret-mode kernel per ``block_rows`` candidate (the
    embed phase's patch-row chunk) on a packer-built plan; the winning
    row is what ``benchmarks/roofline.py --kernels`` (and TPU runs)
    read back through :func:`pick_block_rows`.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.latency import measure
    from repro.core.partitioning import Patch
    from repro.core.stitching import build_batch_plan, stitch
    from repro.kernels.stitch import ops as stitch_ops

    rng = np.random.default_rng(7)
    patches = [Patch(0, 0, int(rng.integers(patch, n // 2 + 1)),
                     int(rng.integers(patch, m // 2 + 1)))
               for _ in range(12)]
    plan = build_batch_plan(patches, stitch(patches, m, n), m, n)
    crops = [np.asarray(rng.normal(size=(p.h, p.w, 3)), np.float32)
             for p in patches]
    slots = jnp.asarray(stitch_ops.pack_plan_host(crops, plan))
    records = jnp.asarray(plan.records)
    kern = jnp.asarray(rng.normal(size=(patch * patch * 3, d_model)),
                       jnp.float32) * 0.05
    bias = jnp.zeros((d_model,), jnp.float32)

    rows = []
    iters = 2 if smoke else 8
    for cand in KERNEL_BLOCK_CANDIDATES:
        if cand > m // patch:
            continue
        tbl = measure(
            lambda b, _c=cand: stitch_ops.stitch_embed(
                slots, records, kern, bias, m, n, patch, block_rows=_c,
                impl="pallas_interpret"),
            batch_sizes=(plan.num_canvases,), iters=iters, warmup=1,
            sync=jax.block_until_ready)
        mu, sigma = tbl.table[plan.num_canvases]
        rows.append({"cell": "kernel_blocks", "variant": f"rows{cand}",
                     "m": m, "n": n, "patch": patch,
                     "block_rows": cand, "mu_s": mu, "sigma_s": sigma})
        print(f"kernel_blocks    rows{cand:<2d} "
              f"mu={mu:.4f}s sigma={sigma:.4f}s "
              f"(B={plan.num_canvases}, {m}x{n}/p{patch})")
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--cell", default="all",
                   choices=list(CELLS) + ["all", "detector_stitch",
                                          "kernel_blocks"])
    p.add_argument("--variant")
    args = p.parse_args(argv)

    results = []
    if os.path.exists(OUT):
        results = json.load(open(OUT))
    if args.cell == "kernel_blocks":
        rows = run_kernel_blocks()
        results = [r for r in results if r["cell"] != "kernel_blocks"]
        results.extend(rows)
        os.makedirs("out", exist_ok=True)
        json.dump(results, open(OUT, "w"), indent=1)
        print(f"wrote {OUT} ({len(results)} rows)")
        return

    mesh = make_production_mesh()
    hw = HardwareConfig()
    if args.cell == "detector_stitch":
        rows = run_detector_stitch(mesh, hw)
        results = [r for r in results if r["cell"] != "detector_stitch"]
        results.extend(rows)
        json.dump(results, open(OUT, "w"), indent=1)
        return
    cells = list(CELLS) if args.cell == "all" else [args.cell]
    for cell in cells:
        variants = ([args.variant] if args.variant
                    else list(CELLS[cell]["variants"]))
        for v in variants:
            row = run_variant(cell, v, mesh, hw)
            results = [r for r in results
                       if not (r["cell"] == cell and r["variant"] == v)]
            results.append(row)
    os.makedirs("out", exist_ok=True)
    json.dump(results, open(OUT, "w"), indent=1)
    print(f"wrote {OUT} ({len(results)} rows)")


if __name__ == "__main__":
    main()
