"""Serving driver: the full Tangram pipeline against a real jit'd model.

Edge side per frame: GMM background subtraction -> RoI extraction ->
adaptive frame partitioning (Alg. 1).  Cloud side: the unified serving
engine (``core.engine``) drives the per-SLO-class invoker pool over
bandwidth-shaped arrivals and executes every fired invocation on the
device pipeline — batched stitch -> (data-parallel) detect -> inverse
unstitch -> per-frame routing.  Timers fire at their scheduled times
(not at the next arrival), and the executor's frame store is refcounted:
a frame is evicted the moment every patch cut from it has been routed.

Where arrivals come from is a ``--source`` choice (:mod:`repro.sources`):

* ``trace`` (default) — the edge pipeline runs up front and the
  pre-shaped arrivals replay through a
  :class:`~repro.sources.TraceSource`: the historical batch path.
* ``synthetic`` — live ingestion: ``--cameras`` synthetic cameras run
  the edge pipeline *during* serving, each shipping patches over its
  own FIFO uplink.  With ``--ingestion-window`` the engine's backlog
  feeds back to the cameras, which respond per ``--overload`` by
  dropping frames or degrading RoI quality; drop/degrade counts are
  reported at the end.
* ``file`` — like ``synthetic`` but frames come from a recorded stack
  (``--frames-path``, ``.npy``/``.npz`` or a directory of ``.npy``).

The whole pipeline is assembled from named factories —
``make_executor`` / ``make_clock`` / ``make_placement`` /
``make_source`` — driven by a :class:`~repro.core.config.ServeConfig`;
the CLI flags below are a direct projection of its fields.

``--async-device`` switches the executor to submit/complete mode
(:class:`~repro.core.engine.AsyncDeviceExecutor`): each fired invocation
is stitched and *dispatched* without blocking, the device works through
its queue while the engine keeps ingesting arrivals, and the engine
blocks only when ``--max-inflight`` handles are unresolved or the trace
drains.  ``--clock wall`` runs the engine on real time (timers fire at
wall instants, ``--wall-speed`` compresses the replay); the default
virtual clock replays the trace as fast as events can be processed.

``--workers N`` serves through a worker pool
(:class:`~repro.core.workers.WorkerPoolExecutor`): the local device set
is split into N independent mesh slices
(:func:`~repro.launch.mesh.make_worker_meshes`), each backing its own
async executor, and every fired invocation is routed to a worker by
``--placement``.  ``--online-latency`` wraps the profiled table in an
:class:`~repro.core.latency.OnlineLatencyTable` shared by the invokers
and the pool, folding observed per-worker completion times back into the
firing decision (EWMA), so batching tracks real device speed instead of
the offline profile.

Multi-device: the detector batch runs under a ``NamedSharding``
data-parallel layout — the stitched canvas batch is padded to the mesh's
"data"-axis size and split over it, so each device detects its slice of
the canvases.  On a 1-device world the mesh degenerates to 1x1 and every
step is identical to the unsharded path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --frames 40 --slo 1.0
  PYTHONPATH=src python -m repro.launch.serve --async-device --max-inflight 4
  PYTHONPATH=src python -m repro.launch.serve --workers 2 --online-latency
  PYTHONPATH=src python -m repro.launch.serve --source synthetic \
    --ingestion-window 32 --overload degrade
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --frames 16 --workers 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import param as param_lib
from repro.compat import shardingx
from repro.config import DetectorConfig
from repro.core.clock import make_clock
from repro.core.config import ServeConfig, make_classify
from repro.core.engine import (InvokerPool, ModelRuntime, ServingEngine,
                               make_executor, uniform_pool)
from repro.core.engine import shard_canvases  # noqa: F401  (public re-export)
from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import LatencyBank, OnlineLatencyTable, measure
from repro.core.models import make_model
from repro.core.parallel import ParallelShardedEngine
from repro.core.fleet import (FleetInvokerPool, FleetPlan, FleetCostModel,
                              ShardedEngine, fleet_uniform_pool,
                              make_planner)
from repro.core.workers import (WorkerPoolExecutor, device_worker_pool,
                                make_placement, share_frame_store,
                                weight_caches)
from repro.launch.mesh import make_serve_mesh, make_worker_meshes
from repro.models import detector as detector_lib
from repro.sharding import ShardingConfig
from repro.sources import RateProfile, make_source


def build_detector(canvas: int = 256, quantize: bool = False):
    cfg = DetectorConfig(name="serve-det", canvas=canvas, patch=32,
                         n_layers=2, d_model=64, n_heads=4, d_ff=128,
                         param_dtype="float32", compute_dtype="float32")
    rules = ShardingConfig.make().rules
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   detector_lib.param_specs(cfg))
    if quantize:
        # same weights, int8-resident: quantize the fp init through
        # models/quantize.py onto the quant spec tree
        from repro.models import quantize as quantize_lib

        cfg = dataclasses.replace(cfg, quant_weights=True)
        params = quantize_lib.quantize_params(
            detector_lib.param_specs(cfg), params)
    serve_fn = jax.jit(lambda p, x: detector_lib.serve(cfg, p, x, rules))
    # the same table the jit-internal logical constraints use: callers
    # must lay inputs out with these rules or force a reshard on entry
    return cfg, params, serve_fn, rules


def build_source(args, frame_sink, slos):
    """CLI -> source, through ``make_source``.  ``trace`` runs the same
    camera pipeline eagerly (no backpressure — the events pre-date the
    run) and replays the pre-shaped arrivals.  Multiple ``--slo`` values
    run one camera per class (distinct camera ids keep frame ids unique
    in the shared store) merged into one trace."""
    common = dict(n_frames=args.frames, canvas=args.canvas, slo=slos[0],
                  bandwidth_bps=args.bandwidth_mbps * 1e6,
                  overload=args.overload, frame_sink=frame_sink,
                  rate=RateProfile(fps=args.fps))
    if args.source == "file":
        return make_source("file", path=args.frames_path, **common)
    live = dict(scene=args.scene, n_cameras=args.cameras, **common)
    if args.source == "synthetic":
        return make_source("synthetic", **live)
    if len(slos) == 1:
        cam = make_source("synthetic", **live)
        return make_source("trace", arrivals=list(cam.events(None)))
    events = []
    for i, slo in enumerate(slos):
        per = dict(live, slo=slo, scene=args.scene + i, n_cameras=1,
                   camera_id=i)
        events.extend(make_source("synthetic", **per).events(None))
    events.sort(key=lambda a: a.t_arrive)
    return make_source("trace", arrivals=events)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--frames", type=int, default=40)
    p.add_argument("--slo", default="1.0",
                   help="SLO seconds; a comma list (e.g. 0.5,2.0) runs one "
                        "camera per class and shards the invoker per SLO")
    p.add_argument("--canvas", type=int, default=256)
    p.add_argument("--scene", type=int, default=0)
    p.add_argument("--fps", type=float, default=10.0)
    p.add_argument("--bandwidth-mbps", type=float, default=40.0,
                   help="uplink shaping for the virtual arrival clock")
    p.add_argument("--source", choices=("trace", "synthetic", "file"),
                   default="trace",
                   help="arrival source: trace replays a pre-generated "
                        "edge run; synthetic ingests live from --cameras "
                        "synthetic cameras; file streams --frames-path")
    p.add_argument("--cameras", type=int, default=1,
                   help="number of synthetic cameras (merged stream)")
    p.add_argument("--frames-path",
                   help="recorded frame stack for --source file "
                        "(.npy/.npz or a directory of .npy frames)")
    p.add_argument("--ingestion-window", type=int, default=None,
                   help="backlog bound, in patches, that live sources "
                        "throttle against (advisory; default: unbounded)")
    p.add_argument("--overload", choices=("drop", "degrade", "none"),
                   default="drop",
                   help="live-source response when the backlog fills the "
                        "ingestion window: drop frames, degrade RoI "
                        "quality (drops at 2x), or ignore")
    p.add_argument("--use-pallas-stitch", action="store_true",
                   help="assemble canvases with the Pallas kernel "
                        "(interpret mode on CPU)")
    p.add_argument("--fuse", action="store_true",
                   help="fused device hot path: stitch->patch-embed and "
                        "decode->gather run as single kernels, so the "
                        "canvas batch never materializes in HBM and "
                        "detector outputs skip the host round-trip "
                        "(single-worker mesh; the fused path does not "
                        "shard the canvas batch)")
    p.add_argument("--quantize", action="store_true",
                   help="serve int8-resident weights: registry models "
                        "resolve to their _int8 variants (with their own "
                        "latency profiles) and the built-in detector "
                        "builds quantized through models/quantize.py")
    p.add_argument("--async-device", action="store_true",
                   help="overlap device execution with arrival ingestion "
                        "(submit/complete executor over JAX async dispatch)")
    p.add_argument("--max-inflight", type=int, default=4,
                   help="bound on unresolved device invocations in "
                        "--async-device mode")
    p.add_argument("--clock", choices=("virtual", "wall"), default="virtual",
                   help="virtual: replay as fast as events process; "
                        "wall: timers fire at real wall instants")
    p.add_argument("--wall-speed", type=float, default=1.0,
                   help="engine seconds per wall second with --clock wall "
                        "(>1 compresses the replay)")
    p.add_argument("--workers", type=int, default=1,
                   help="device worker pool size: the local device set is "
                        "split into this many independent mesh slices, "
                        "each an overlapped (async) executor, and "
                        "concurrent invocations are routed across them")
    p.add_argument("--shards", type=int, default=None,
                   help="fleet sharding: partition cameras into this many "
                        "shard groups, each its own invoker pool + "
                        "executor over its own mesh slice, under a "
                        "two-level ShardedEngine (core.fleet); mutually "
                        "exclusive with --workers > 1")
    p.add_argument("--planner", choices=("cost", "equal"), default=None,
                   help="shard layout planner with --shards: cost "
                        "(default; rate-aware LPT grouping + proportional "
                        "workers when the source exposes camera rates) or "
                        "equal (naive contiguous split); sources without "
                        "rate feeds route camera_id %% shards")
    p.add_argument("--parallel", action="store_true",
                   help="run each shard's engine loop on its own thread "
                        "(ParallelShardedEngine) with a bounded arrival "
                        "queue per shard; requires --shards; without it "
                        "the sequential sharded path is unchanged")
    p.add_argument("--placement",
                   choices=("least", "round", "affinity", "model"),
                   default="least",
                   help="worker placement policy with --workers > 1: "
                        "least-outstanding (default), round-robin, "
                        "class-affinity (tightest SLO class gets worker 0 "
                        "once a second class appears), or model-affinity "
                        "(same-model batches co-locate so weights stay "
                        "resident)")
    p.add_argument("--model", default=None,
                   help="registry model to serve (repro.core.models; "
                        "default: the historical tiny built-in detector)")
    p.add_argument("--model-map", action="append", default=None,
                   metavar="CLASS=MODEL",
                   help="route an SLO class to a registry model, e.g. "
                        "--model-map 0.5=vit_s16 --model-map 2.0=tangram; "
                        "repeatable; classes not mapped fall back to "
                        "--model")
    p.add_argument("--online-latency", action="store_true",
                   help="fold observed per-worker completion times back "
                        "into the latency table (EWMA) so firing decisions "
                        "track real device speed; composes with any "
                        "executor mode")
    args = p.parse_args(argv)
    if args.workers < 1:
        p.error("--workers must be >= 1")
    if args.shards is not None and args.shards < 1:
        p.error("--shards must be >= 1")
    if args.shards is not None and args.workers > 1:
        p.error("--shards and --workers > 1 both carve the device set; "
                "pick one (per-shard worker pools: use the sim scheduler)")
    if args.parallel and args.shards is None:
        p.error("--parallel requires --shards")
    if args.cameras < 1:
        p.error("--cameras must be >= 1")
    if args.source == "file" and not args.frames_path:
        p.error("--source file requires --frames-path")
    try:
        slos = [float(s) for s in str(args.slo).split(",")]
    except ValueError:
        p.error(f"--slo must be a float or comma list, got {args.slo!r}")
    if len(slos) > 1 and args.source != "trace":
        p.error("multiple --slo classes need --source trace")
    model_map = None
    if args.model_map:
        try:
            model_map = dict(kv.split("=", 1) for kv in args.model_map)
        except ValueError:
            p.error("--model-map entries must look like CLASS=MODEL")

    # every pipeline choice below is a field of this one record
    config = ServeConfig(
        max_canvases=4,
        classify="slo" if (model_map or len(slos) > 1) else None,
        executor="async_device" if args.async_device or args.workers > 1
        else "device",
        use_pallas=args.use_pallas_stitch,
        fuse=args.fuse, quantize=args.quantize,
        max_inflight=args.max_inflight,
        clock=args.clock, wall_speed=args.wall_speed,
        n_workers=args.workers, placement=args.placement,
        online_latency=args.online_latency,
        source=args.source, ingestion_window=args.ingestion_window,
        model=args.model, model_map=model_map,
        shards=args.shards, planner=args.planner, parallel=args.parallel)

    m = n = args.canvas
    if config.quantize and config.multi_model:
        # --quantize reroutes every referenced registry model to its
        # _int8 variant (when one is registered): quantized weights,
        # economics, and latency profile, same routing keys
        from repro.core.models import model_names as _registry_names

        have = set(_registry_names())

        def _q(name):
            return (f"{name}_int8"
                    if name and f"{name}_int8" in have else name)

        config = config.replace(
            model=_q(config.model),
            model_map=({k: _q(v) for k, v in config.model_map.items()}
                       if config.model_map else None))
    if config.multi_model:
        # lazy registry builds: each referenced model jit-compiles its
        # (reduced) trunk at the CLI canvas, with per-name weight seeds
        specs = {name: make_model(name) for name in config.model_names()}
        builds = {name: spec.build(canvas=args.canvas)
                  for name, spec in specs.items()}
        default_model = config.model or sorted(builds)[0]
        cfg, params, serve_fn, rules = builds[default_model]
        print(f"models: {', '.join(sorted(builds))} "
              f"(default {default_model})")
    else:
        specs, builds, default_model = {}, {}, None
        cfg, params, serve_fn, rules = build_detector(
            args.canvas, quantize=config.quantize)

    def fused_kwargs(mcfg, pr, rl):
        """ModelRuntime fused-path fields (tokens_fn + patch-embed
        projection) for one built model; empty when fusion is off."""
        if not config.fuse:
            return {}
        ek, eb = detector_lib.embed_params(mcfg, pr)
        tok = jax.jit(lambda p, t, _c=mcfg, _r=rl:
                      detector_lib.forward_tokens(_c, p, t, _r))
        return dict(tokens_fn=tok, embed_kernel=ek, embed_bias=eb,
                    patch=mcfg.patch)
    n_slices = config.shards or config.n_workers
    if n_slices > 1:
        meshes = make_worker_meshes(n_slices)
    else:
        meshes = [make_serve_mesh()]
    mesh = meshes[0]
    axis_sizes = shardingx.mesh_axis_sizes(mesh)
    print(f"serve mesh: {len(meshes)} worker(s) x "
          f"data={axis_sizes.get('data', 1)} "
          f"model={axis_sizes.get('model', 1)} "
          f"({mesh.devices.size} devices each)")

    # offline profiling (the paper's 1000-iteration stage, scaled down)
    # under the same data-parallel layout execution will use; the sync
    # hook keeps jit's async dispatch inside the timed region
    def profile(fn, pr, rl):
        def run_batch(b):
            x = jnp.zeros((b, m, n, 3), jnp.float32)
            x, _ = shard_canvases(x, mesh, rl)
            return fn(pr, x)
        return measure(run_batch, batch_sizes=(1, 2, 4), iters=5, warmup=1,
                       sync=jax.block_until_ready)

    table = profile(serve_fn, params, rules)
    print("latency table:",
          {k: (round(v[0], 4), round(v[1], 4)) for k, v in table.table.items()})
    model_tables = {}
    for name, (_, pr, fn, rl) in builds.items():
        model_tables[name] = (table if name == default_model
                              else profile(fn, pr, rl))
    if config.online_latency:
        # one estimator instance, shared between the invoker pool (reads
        # t_slack) and the worker pool (feeds observations back); with
        # models it is a LatencyBank routing observations per model
        table = OnlineLatencyTable(table)
        model_tables = {name: (table if name == default_model
                               else OnlineLatencyTable(t))
                        for name, t in model_tables.items()}
    estimator = None
    if config.online_latency:
        estimator = (LatencyBank(model_tables) if config.multi_model
                     else table)

    def runtimes(mesh_i):
        """Per-model device runtimes on one worker's mesh slice."""
        return {name: ModelRuntime(fn, pr, m, n, mesh=mesh_i, rules=rl,
                                   **fused_kwargs(mcfg, pr, rl))
                for name, (mcfg, pr, fn, rl) in builds.items()}

    caches = None
    if config.multi_model and len(specs) > 1:
        # each worker holds the largest single model: swaps are real and
        # model-affinity placement is what avoids paying them repeatedly
        caches = weight_caches(
            config.n_workers,
            max(s.weight_bytes for s in specs.values()),
            {name: (s.weight_bytes, s.load_s) for name, s in specs.items()})

    t_start = time.time()
    shard_executors = None
    if config.shards:
        # one executor per shard over its own mesh slice; the frame
        # store is shared so any shard's completions can route evidence
        # for any camera's frames (cameras pin to shards, frames don't
        # need to)
        shard_executors = [
            make_executor(
                config.executor, serve_fn=serve_fn, params=params,
                canvas_m=m, canvas_n=n, use_pallas=config.use_pallas,
                fuse=config.fuse, mesh=meshes[i % len(meshes)],
                rules=rules, max_inflight=config.max_inflight,
                models=runtimes(meshes[i % len(meshes)]) if builds else None,
                **fused_kwargs(cfg, params, rules))
            for i in range(config.shards)]
        share_frame_store(shard_executors)
        executor = shard_executors[0]
    elif config.n_workers > 1:
        # a multi-worker pool overlaps by construction: each worker is an
        # async executor over its own mesh slice, sharing one frame store
        executor = device_worker_pool(
            config.n_workers,
            lambda i: make_executor(
                config.executor, serve_fn=serve_fn, params=params,
                canvas_m=m, canvas_n=n, use_pallas=config.use_pallas,
                fuse=config.fuse, mesh=meshes[i], rules=rules,
                max_inflight=config.max_inflight,
                models=runtimes(meshes[i]) if builds else None,
                **fused_kwargs(cfg, params, rules)),
            placement=make_placement(config.placement),
            estimator=estimator, weight_caches=caches)
    else:
        executor = make_executor(
            config.executor, serve_fn=serve_fn, params=params,
            canvas_m=m, canvas_n=n, use_pallas=config.use_pallas,
            fuse=config.fuse, mesh=mesh, rules=rules,
            max_inflight=config.max_inflight,
            models=runtimes(mesh) if builds else None,
            **fused_kwargs(cfg, params, rules))
        if config.online_latency or caches is not None:
            # a 1-worker pool only adds the estimator feedback loop and
            # weight-cache accounting: the wrapped executor keeps its
            # sync-vs-async semantics, so the flags never change
            # execution mode behind the user's back
            executor = WorkerPoolExecutor([executor], estimator=estimator,
                                          weight_caches=caches)

    source = build_source(args, frame_sink=executor.add_frame, slos=slos)

    def build_pool(fleet: bool = False):
        if config.multi_model:
            # per-class invokers: each SLO class fires against its
            # model's own latency table, so t_slack is per-model
            # (Eqn. 8 per tenant)
            def make_invoker(key):
                name = config.resolve_model(key) or default_model
                return SLOAwareInvoker(m, n, model_tables[name],
                                       max_canvases=config.max_canvases)

            pool_cls = FleetInvokerPool if fleet else InvokerPool
            return pool_cls(
                make_invoker,
                classify=make_classify(config.classify) or (lambda p: None),
                model_of=lambda key: (config.resolve_model(key)
                                      or default_model))
        fn = fleet_uniform_pool if fleet else uniform_pool
        return fn(m, n, table, max_canvases=config.max_canvases,
                  classify=make_classify(config.classify))

    if config.shards:
        window = (max(1, config.ingestion_window // config.shards)
                  if config.ingestion_window else None)
        if config.parallel and config.clock == "wall":
            # one wall timeline, one thread-private monotone view each
            parent_clock = make_clock("wall", speed=config.wall_speed)
            shard_clocks = [parent_clock.shard_view()
                            for _ in range(config.shards)]
        else:
            shard_clocks = [make_clock(config.clock,
                                       speed=config.wall_speed)
                            for _ in range(config.shards)]
        shard_engines = [
            ServingEngine(build_pool(fleet=True), shard_executors[s],
                          clock=shard_clocks[s],
                          ingestion_window=window)
            for s in range(config.shards)]
        if hasattr(source, "camera_rates"):
            planner = make_planner(
                config.planner or "cost",
                cost_model=FleetCostModel(latency=table),
                worker_budget=config.shards)
            plan = planner.plan(source.camera_rates(),
                                n_shards=config.shards)
        else:
            plan = FleetPlan(n_shards=config.shards)
        engine_cls = (ParallelShardedEngine if config.parallel
                      else ShardedEngine)
        engine = engine_cls(shard_engines, plan.shard_of, plan=plan)
    else:
        engine = ServingEngine(build_pool(), executor,
                               clock=make_clock(config.clock,
                                                speed=config.wall_speed),
                               ingestion_window=config.ingestion_window)
    outcomes = engine.serve(source)

    stats = source.stats()
    violated = sum(o.violated for o in outcomes)
    executors = shard_executors if shard_executors else [executor]

    def _total(attr: str) -> int:
        return sum(getattr(e, attr, 0) for e in executors)

    if config.shards:
        overlap = (f"{config.shards} shard(s), "
                   f"{config.planner or 'cost'} planner"
                   + (", parallel" if config.parallel else ""))
    elif config.n_workers > 1:
        overlap = (f"{config.n_workers} worker(s), {config.placement} "
                   f"placement, in-flight high water "
                   f"{engine.inflight_high_water}/"
                   f"{getattr(executor, 'max_inflight', '-')}")
    elif args.async_device:
        overlap = (f"async, in-flight high water "
                   f"{engine.inflight_high_water}/{config.max_inflight}")
    else:
        overlap = "sync"
    if config.online_latency:
        overlap += ", online latency"
    if config.fuse:
        overlap += ", fused"
    if config.quantize:
        overlap += ", int8"
    print(f"served {stats.patches_emitted} patches in "
          f"{_total('n_invocations')} invocations ({overlap}, "
          f"{config.clock} clock, {_total('n_sharded')} data-parallel over "
          f"data={axis_sizes.get('data', 1)}), "
          f"routed {_total('n_detections')} detections + "
          f"{_total('evidence_bytes') / 1e6:.2f} MB patch evidence back to "
          f"frames, {violated} SLO violations "
          f"({len(executor.frames)} frames still held, "
          f"{time.time()-t_start:.1f}s wall)")
    if config.shards:
        for row in engine.shard_stats():
            print(f"  shard {row['shard']}: {row['arrivals']} arrivals, "
                  f"{row['invocations']} invocations, "
                  f"{row['violations']} violations, backlog high water "
                  f"{row['backlog_high_water']}")
    print(f"source {stats.kind}: {stats.frames_total} frames, "
          f"{stats.frames_dropped} dropped, {stats.frames_degraded} "
          f"degraded, backlog high water {engine.backlog_high_water}"
          + (f"/{config.ingestion_window}"
             if config.ingestion_window else ""))
    if isinstance(executor, WorkerPoolExecutor):
        for ws in executor.worker_stats():
            drift = (f", drift {ws['drift']}x" if "drift" in ws else "")
            print(f"  worker {ws['worker']}: {ws['invocations']} "
                  f"invocations, {ws['patches']} patches, "
                  f"busy {ws['busy_s']:.3f}s{drift}")
    by_model = {}
    for o in outcomes:
        if o.model is not None:
            row = by_model.setdefault(o.model, [0, 0])
            row[0] += 1
            row[1] += int(o.violated)
    if by_model:
        cache_stats = (executor.model_cache_stats()
                       if hasattr(executor, "model_cache_stats") else {})
        for name in sorted(by_model):
            served, viol = by_model[name]
            extra = ""
            cs = cache_stats.get(name)
            if cs:
                extra = (f", weight hits {cs['weight_hits']}/"
                         f"{cs['weight_hits'] + cs['weight_misses']}")
            print(f"  model {name}: {served} patches, "
                  f"{viol} violations{extra}")


if __name__ == "__main__":
    main()
