"""Serving driver: the full Tangram pipeline against a real jit'd model.

Edge side per frame: GMM background subtraction -> RoI extraction ->
adaptive frame partitioning (Alg. 1).  Cloud side: the unified serving
engine (``core.engine``) drives the per-SLO-class invoker pool over
bandwidth-shaped arrivals and executes every fired invocation on the
device pipeline — batched stitch -> (data-parallel) detect -> inverse
unstitch -> per-frame routing.  Timers fire at their scheduled times
(not at the next arrival), and the executor's frame store is refcounted:
a frame is evicted the moment every patch cut from it has been routed.

Where arrivals come from is a ``--source`` choice (:mod:`repro.sources`):

* ``trace`` (default) — the edge pipeline runs up front and the
  pre-shaped arrivals replay through a
  :class:`~repro.sources.TraceSource`: the historical batch path.
* ``synthetic`` — live ingestion: ``--cameras`` synthetic cameras run
  the edge pipeline *during* serving, each shipping patches over its
  own FIFO uplink.  With ``--ingestion-window`` the engine's backlog
  feeds back to the cameras, which respond per ``--overload`` by
  dropping frames or degrading RoI quality; drop/degrade counts are
  reported at the end.
* ``file`` — like ``synthetic`` but frames come from a recorded stack
  (``--frames-path``, ``.npy``/``.npz`` or a directory of ``.npy``).

The whole pipeline is assembled from named factories —
``make_executor`` / ``make_clock`` / ``make_placement`` /
``make_source`` — driven by a :class:`~repro.core.config.ServeConfig`;
the CLI flags below are a direct projection of its fields.

``--async-device`` switches the executor to submit/complete mode
(:class:`~repro.core.engine.AsyncDeviceExecutor`): each fired invocation
is stitched and *dispatched* without blocking, the device works through
its queue while the engine keeps ingesting arrivals, and the engine
blocks only when ``--max-inflight`` handles are unresolved or the trace
drains.  ``--clock wall`` runs the engine on real time (timers fire at
wall instants, ``--wall-speed`` compresses the replay); the default
virtual clock replays the trace as fast as events can be processed.

``--workers N`` serves through a worker pool
(:class:`~repro.core.workers.WorkerPoolExecutor`): the local device set
is split into N independent mesh slices
(:func:`~repro.launch.mesh.make_worker_meshes`), each backing its own
async executor, and every fired invocation is routed to a worker by
``--placement``.  ``--online-latency`` wraps the profiled table in an
:class:`~repro.core.latency.OnlineLatencyTable` shared by the invokers
and the pool, folding observed per-worker completion times back into the
firing decision (EWMA), so batching tracks real device speed instead of
the offline profile.

Multi-device: the detector batch runs under a ``NamedSharding``
data-parallel layout — the stitched canvas batch is padded to the mesh's
"data"-axis size and split over it, so each device detects its slice of
the canvases.  On a 1-device world the mesh degenerates to 1x1 and every
step is identical to the unsharded path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --frames 40 --slo 1.0
  PYTHONPATH=src python -m repro.launch.serve --async-device --max-inflight 4
  PYTHONPATH=src python -m repro.launch.serve --workers 2 --online-latency
  PYTHONPATH=src python -m repro.launch.serve --source synthetic \
    --ingestion-window 32 --overload degrade
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --frames 16 --workers 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import param as param_lib
from repro.compat import shardingx
from repro.config import DetectorConfig
from repro.core.clock import make_clock
from repro.core.config import ServeConfig
from repro.core.engine import (ServingEngine, make_executor, uniform_pool)
from repro.core.engine import shard_canvases  # noqa: F401  (public re-export)
from repro.core.latency import OnlineLatencyTable, measure
from repro.core.workers import (WorkerPoolExecutor, device_worker_pool,
                                make_placement)
from repro.launch.mesh import make_serve_mesh, make_worker_meshes
from repro.models import detector as detector_lib
from repro.sharding import ShardingConfig
from repro.sources import RateProfile, make_source


def build_detector(canvas: int = 256):
    cfg = DetectorConfig(name="serve-det", canvas=canvas, patch=32,
                         n_layers=2, d_model=64, n_heads=4, d_ff=128,
                         param_dtype="float32", compute_dtype="float32")
    rules = ShardingConfig.make().rules
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   detector_lib.param_specs(cfg))
    serve_fn = jax.jit(lambda p, x: detector_lib.serve(cfg, p, x, rules))
    # the same table the jit-internal logical constraints use: callers
    # must lay inputs out with these rules or force a reshard on entry
    return cfg, params, serve_fn, rules


def build_source(args, frame_sink):
    """CLI -> source, through ``make_source``.  ``trace`` runs the same
    camera pipeline eagerly (no backpressure — the events pre-date the
    run) and replays the pre-shaped arrivals."""
    common = dict(n_frames=args.frames, canvas=args.canvas, slo=args.slo,
                  bandwidth_bps=args.bandwidth_mbps * 1e6,
                  overload=args.overload, frame_sink=frame_sink,
                  rate=RateProfile(fps=args.fps))
    if args.source == "file":
        return make_source("file", path=args.frames_path, **common)
    live = dict(scene=args.scene, n_cameras=args.cameras, **common)
    if args.source == "synthetic":
        return make_source("synthetic", **live)
    cam = make_source("synthetic", **live)
    return make_source("trace", arrivals=list(cam.events(None)))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--frames", type=int, default=40)
    p.add_argument("--slo", type=float, default=1.0)
    p.add_argument("--canvas", type=int, default=256)
    p.add_argument("--scene", type=int, default=0)
    p.add_argument("--fps", type=float, default=10.0)
    p.add_argument("--bandwidth-mbps", type=float, default=40.0,
                   help="uplink shaping for the virtual arrival clock")
    p.add_argument("--source", choices=("trace", "synthetic", "file"),
                   default="trace",
                   help="arrival source: trace replays a pre-generated "
                        "edge run; synthetic ingests live from --cameras "
                        "synthetic cameras; file streams --frames-path")
    p.add_argument("--cameras", type=int, default=1,
                   help="number of synthetic cameras (merged stream)")
    p.add_argument("--frames-path",
                   help="recorded frame stack for --source file "
                        "(.npy/.npz or a directory of .npy frames)")
    p.add_argument("--ingestion-window", type=int, default=None,
                   help="backlog bound, in patches, that live sources "
                        "throttle against (advisory; default: unbounded)")
    p.add_argument("--overload", choices=("drop", "degrade", "none"),
                   default="drop",
                   help="live-source response when the backlog fills the "
                        "ingestion window: drop frames, degrade RoI "
                        "quality (drops at 2x), or ignore")
    p.add_argument("--use-pallas-stitch", action="store_true",
                   help="assemble canvases with the Pallas kernel "
                        "(interpret mode on CPU)")
    p.add_argument("--async-device", action="store_true",
                   help="overlap device execution with arrival ingestion "
                        "(submit/complete executor over JAX async dispatch)")
    p.add_argument("--max-inflight", type=int, default=4,
                   help="bound on unresolved device invocations in "
                        "--async-device mode")
    p.add_argument("--clock", choices=("virtual", "wall"), default="virtual",
                   help="virtual: replay as fast as events process; "
                        "wall: timers fire at real wall instants")
    p.add_argument("--wall-speed", type=float, default=1.0,
                   help="engine seconds per wall second with --clock wall "
                        "(>1 compresses the replay)")
    p.add_argument("--workers", type=int, default=1,
                   help="device worker pool size: the local device set is "
                        "split into this many independent mesh slices, "
                        "each an overlapped (async) executor, and "
                        "concurrent invocations are routed across them")
    p.add_argument("--placement", choices=("least", "round", "affinity"),
                   default="least",
                   help="worker placement policy with --workers > 1: "
                        "least-outstanding (default), round-robin, or "
                        "class-affinity (tightest SLO class gets worker 0 "
                        "once a second class appears)")
    p.add_argument("--online-latency", action="store_true",
                   help="fold observed per-worker completion times back "
                        "into the latency table (EWMA) so firing decisions "
                        "track real device speed; composes with any "
                        "executor mode")
    args = p.parse_args(argv)
    if args.workers < 1:
        p.error("--workers must be >= 1")
    if args.cameras < 1:
        p.error("--cameras must be >= 1")
    if args.source == "file" and not args.frames_path:
        p.error("--source file requires --frames-path")

    # every pipeline choice below is a field of this one record
    config = ServeConfig(
        max_canvases=4,
        executor="async_device" if args.async_device or args.workers > 1
        else "device",
        use_pallas=args.use_pallas_stitch,
        max_inflight=args.max_inflight,
        clock=args.clock, wall_speed=args.wall_speed,
        n_workers=args.workers, placement=args.placement,
        online_latency=args.online_latency,
        source=args.source, ingestion_window=args.ingestion_window)

    cfg, params, serve_fn, rules = build_detector(args.canvas)
    m = n = args.canvas
    if config.n_workers > 1:
        meshes = make_worker_meshes(config.n_workers)
    else:
        meshes = [make_serve_mesh()]
    mesh = meshes[0]
    axis_sizes = shardingx.mesh_axis_sizes(mesh)
    print(f"serve mesh: {len(meshes)} worker(s) x "
          f"data={axis_sizes.get('data', 1)} "
          f"model={axis_sizes.get('model', 1)} "
          f"({mesh.devices.size} devices each)")

    # offline profiling (the paper's 1000-iteration stage, scaled down)
    # under the same data-parallel layout execution will use; the sync
    # hook keeps jit's async dispatch inside the timed region
    def run_batch(b):
        x = jnp.zeros((b, m, n, 3), jnp.float32)
        x, _ = shard_canvases(x, mesh, rules)
        return serve_fn(params, x)
    table = measure(run_batch, batch_sizes=(1, 2, 4), iters=5, warmup=1,
                    sync=jax.block_until_ready)
    print("latency table:",
          {k: (round(v[0], 4), round(v[1], 4)) for k, v in table.table.items()})
    if config.online_latency:
        # one estimator instance, shared between the invoker pool (reads
        # t_slack) and the worker pool (feeds observations back)
        table = OnlineLatencyTable(table)

    t_start = time.time()
    if config.n_workers > 1:
        # a multi-worker pool overlaps by construction: each worker is an
        # async executor over its own mesh slice, sharing one frame store
        executor = device_worker_pool(
            config.n_workers,
            lambda i: make_executor(
                config.executor, serve_fn=serve_fn, params=params,
                canvas_m=m, canvas_n=n, use_pallas=config.use_pallas,
                mesh=meshes[i], rules=rules,
                max_inflight=config.max_inflight),
            placement=make_placement(config.placement),
            estimator=table if config.online_latency else None)
    else:
        executor = make_executor(
            config.executor, serve_fn=serve_fn, params=params,
            canvas_m=m, canvas_n=n, use_pallas=config.use_pallas,
            mesh=mesh, rules=rules, max_inflight=config.max_inflight)
        if config.online_latency:
            # a 1-worker pool only adds the estimator feedback loop: the
            # wrapped executor keeps its sync-vs-async semantics, so the
            # flag never changes execution mode behind the user's back
            executor = WorkerPoolExecutor([executor], estimator=table)

    source = build_source(args, frame_sink=executor.add_frame)
    pool = uniform_pool(m, n, table, max_canvases=config.max_canvases)
    engine = ServingEngine(pool, executor,
                           clock=make_clock(config.clock,
                                            speed=config.wall_speed),
                           ingestion_window=config.ingestion_window)
    outcomes = engine.serve(source)

    stats = source.stats()
    violated = sum(o.violated for o in outcomes)
    if config.n_workers > 1:
        overlap = (f"{config.n_workers} worker(s), {config.placement} "
                   f"placement, in-flight high water "
                   f"{engine.inflight_high_water}/"
                   f"{getattr(executor, 'max_inflight', '-')}")
    elif args.async_device:
        overlap = (f"async, in-flight high water "
                   f"{engine.inflight_high_water}/{config.max_inflight}")
    else:
        overlap = "sync"
    if config.online_latency:
        overlap += ", online latency"
    print(f"served {stats.patches_emitted} patches in "
          f"{executor.n_invocations} invocations ({overlap}, "
          f"{config.clock} clock, {executor.n_sharded} data-parallel over "
          f"data={axis_sizes.get('data', 1)}), "
          f"routed {executor.n_detections} detections + "
          f"{executor.evidence_bytes / 1e6:.2f} MB patch evidence back to "
          f"frames, {violated} SLO violations "
          f"({len(executor.frames)} frames still held, "
          f"{time.time()-t_start:.1f}s wall)")
    print(f"source {stats.kind}: {stats.frames_total} frames, "
          f"{stats.frames_dropped} dropped, {stats.frames_degraded} "
          f"degraded, backlog high water {engine.backlog_high_water}"
          + (f"/{config.ingestion_window}"
             if config.ingestion_window else ""))
    if isinstance(executor, WorkerPoolExecutor):
        for ws in executor.worker_stats():
            drift = (f", drift {ws['drift']}x" if "drift" in ws else "")
            print(f"  worker {ws['worker']}: {ws['invocations']} "
                  f"invocations, {ws['patches']} patches, "
                  f"busy {ws['busy_s']:.3f}s{drift}")


if __name__ == "__main__":
    main()
