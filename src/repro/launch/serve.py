"""Serving driver: the full Tangram pipeline against a real jit'd model.

Edge side per frame: GMM background subtraction -> RoI extraction ->
adaptive frame partitioning (Alg. 1).  Cloud side: the unified serving
engine (``core.engine``) drives the per-SLO-class invoker pool over
bandwidth-shaped arrivals and executes every fired invocation on the
device pipeline — batched stitch -> (data-parallel) detect -> inverse
unstitch -> per-frame routing.  Timers fire at their scheduled times
(not at the next arrival), and the executor's frame store is refcounted:
a frame is evicted the moment every patch cut from it has been routed.

``--async-device`` switches the executor to submit/complete mode
(:class:`~repro.core.engine.AsyncDeviceExecutor`): each fired invocation
is stitched and *dispatched* without blocking, the device works through
its queue while the engine keeps ingesting arrivals, and the engine
blocks only when ``--max-inflight`` handles are unresolved or the trace
drains.  ``--clock wall`` runs the engine on real time (timers fire at
wall instants, ``--wall-speed`` compresses the replay); the default
virtual clock replays the trace as fast as events can be processed.

``--workers N`` serves through a worker pool
(:class:`~repro.core.workers.WorkerPoolExecutor`): the local device set
is split into N independent mesh slices
(:func:`~repro.launch.mesh.make_worker_meshes`), each backing its own
async executor, and every fired invocation is routed to a worker by
``--placement`` (least-outstanding default; ``round`` round-robin;
``affinity`` reserves worker 0 for the tightest SLO class).  Completions
harvest out of order across workers, so one slow batch no longer pins
finished work on other slices.  ``--online-latency`` wraps the profiled
table in an :class:`~repro.core.latency.OnlineLatencyTable` shared by
the invokers and the pool, folding observed per-worker completion times
back into the firing decision (EWMA), so batching tracks real device
speed instead of the offline profile.  The flag composes with any
executor mode — at ``--workers 1`` the chosen sync/async executor is
wrapped in a 1-worker pool that only adds the feedback loop, never a
change of execution semantics.

Multi-device: the detector batch runs under a ``NamedSharding``
data-parallel layout — the stitched canvas batch is padded to the mesh's
"data"-axis size and split over it, so each device detects its slice of
the canvases.  On a 1-device world the mesh degenerates to 1x1 and every
step is identical to the unsharded path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --frames 40 --slo 1.0
  PYTHONPATH=src python -m repro.launch.serve --async-device --max-inflight 4
  PYTHONPATH=src python -m repro.launch.serve --workers 2 --online-latency
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --frames 16 --workers 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import param as param_lib
from repro.compat import shardingx
from repro.config import DetectorConfig
from repro.core import gmm, partitioning, rois
from repro.core.clock import VirtualClock, WallClock
from repro.core.engine import (AsyncDeviceExecutor, DeviceExecutor,
                               ServingEngine, uniform_pool)
from repro.core.engine import shard_canvases  # noqa: F401  (public re-export)
from repro.core.latency import OnlineLatencyTable, measure
from repro.core.workers import (WorkerPoolExecutor, device_worker_pool,
                                make_placement)
from repro.data.synthetic import Scene, preset
from repro.data.video import shape_arrivals
from repro.launch.mesh import make_serve_mesh, make_worker_meshes
from repro.models import detector as detector_lib
from repro.sharding import ShardingConfig


def build_detector(canvas: int = 256):
    cfg = DetectorConfig(name="serve-det", canvas=canvas, patch=32,
                         n_layers=2, d_model=64, n_heads=4, d_ff=128,
                         param_dtype="float32", compute_dtype="float32")
    rules = ShardingConfig.make().rules
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   detector_lib.param_specs(cfg))
    serve_fn = jax.jit(lambda p, x: detector_lib.serve(cfg, p, x, rules))
    # the same table the jit-internal logical constraints use: callers
    # must lay inputs out with these rules or force a reshard on entry
    return cfg, params, serve_fn, rules


def generate_stream(scene: Scene, executor: DeviceExecutor, n_frames: int,
                    canvas: int, slo: float):
    """Edge pipeline: GMM -> RoIs -> Alg. 1 patches, frames registered in
    the executor's refcounted store.  Returns the patch stream in
    generation order."""
    state = gmm.init_state(scene.cfg.height, scene.cfg.width)
    stream = []
    for t, frame, gt in scene.frames(n_frames):
        state, fg = gmm.update_jit(state, jnp.asarray(frame))
        if t < 1.0:
            continue
        boxes, valid = rois.extract_rois_jit(jnp.asarray(fg))
        boxes_np = np.asarray(boxes)[np.asarray(valid)]
        patches = partitioning.partition_host(
            boxes_np, scene.cfg.width, scene.cfg.height, 4, 4,
            frame_id=scene.t, t_gen=t, slo=slo)
        # enclosing rects can exceed zones; clamp to the canvas tile
        patches = [partitioning.Patch(
            p.x0, p.y0, min(p.x1, p.x0 + canvas), min(p.y1, p.y0 + canvas),
            p.frame_id, p.camera_id, p.t_gen, p.slo) for p in patches]
        executor.add_frame(scene.t, scene.render_rgb(), len(patches))
        stream.extend(patches)
    return stream


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--frames", type=int, default=40)
    p.add_argument("--slo", type=float, default=1.0)
    p.add_argument("--canvas", type=int, default=256)
    p.add_argument("--scene", type=int, default=0)
    p.add_argument("--bandwidth-mbps", type=float, default=40.0,
                   help="uplink shaping for the virtual arrival clock")
    p.add_argument("--use-pallas-stitch", action="store_true",
                   help="assemble canvases with the Pallas kernel "
                        "(interpret mode on CPU)")
    p.add_argument("--async-device", action="store_true",
                   help="overlap device execution with arrival ingestion "
                        "(submit/complete executor over JAX async dispatch)")
    p.add_argument("--max-inflight", type=int, default=4,
                   help="bound on unresolved device invocations in "
                        "--async-device mode")
    p.add_argument("--clock", choices=("virtual", "wall"), default="virtual",
                   help="virtual: replay as fast as events process; "
                        "wall: timers fire at real wall instants")
    p.add_argument("--wall-speed", type=float, default=1.0,
                   help="engine seconds per wall second with --clock wall "
                        "(>1 compresses the replay)")
    p.add_argument("--workers", type=int, default=1,
                   help="device worker pool size: the local device set is "
                        "split into this many independent mesh slices, "
                        "each an overlapped (async) executor, and "
                        "concurrent invocations are routed across them")
    p.add_argument("--placement", choices=("least", "round", "affinity"),
                   default="least",
                   help="worker placement policy with --workers > 1: "
                        "least-outstanding (default), round-robin, or "
                        "class-affinity (tightest SLO class gets worker 0 "
                        "once a second class appears)")
    p.add_argument("--online-latency", action="store_true",
                   help="fold observed per-worker completion times back "
                        "into the latency table (EWMA) so firing decisions "
                        "track real device speed; composes with any "
                        "executor mode")
    args = p.parse_args(argv)
    if args.workers < 1:
        p.error("--workers must be >= 1")

    cfg, params, serve_fn, rules = build_detector(args.canvas)
    m = n = args.canvas
    if args.workers > 1:
        meshes = make_worker_meshes(args.workers)
    else:
        meshes = [make_serve_mesh()]
    mesh = meshes[0]
    axis_sizes = shardingx.mesh_axis_sizes(mesh)
    print(f"serve mesh: {len(meshes)} worker(s) x "
          f"data={axis_sizes.get('data', 1)} "
          f"model={axis_sizes.get('model', 1)} "
          f"({mesh.devices.size} devices each)")

    # offline profiling (the paper's 1000-iteration stage, scaled down)
    # under the same data-parallel layout execution will use; the sync
    # hook keeps jit's async dispatch inside the timed region
    def run_batch(b):
        x = jnp.zeros((b, m, n, 3), jnp.float32)
        x, _ = shard_canvases(x, mesh, rules)
        return serve_fn(params, x)
    table = measure(run_batch, batch_sizes=(1, 2, 4), iters=5, warmup=1,
                    sync=jax.block_until_ready)
    print("latency table:",
          {k: (round(v[0], 4), round(v[1], 4)) for k, v in table.table.items()})
    if args.online_latency:
        # one estimator instance, shared between the invoker pool (reads
        # t_slack) and the worker pool (feeds observations back)
        table = OnlineLatencyTable(table)

    t_start = time.time()
    if args.workers > 1:
        # a multi-worker pool overlaps by construction: each worker is an
        # async executor over its own mesh slice, sharing one frame store
        executor = device_worker_pool(
            args.workers,
            lambda i: AsyncDeviceExecutor(
                serve_fn, params, m, n,
                use_pallas=args.use_pallas_stitch,
                mesh=meshes[i], rules=rules,
                max_inflight=args.max_inflight),
            placement=make_placement(args.placement),
            estimator=table if args.online_latency else None)
    else:
        if args.async_device:
            executor = AsyncDeviceExecutor(serve_fn, params, m, n,
                                           use_pallas=args.use_pallas_stitch,
                                           mesh=mesh, rules=rules,
                                           max_inflight=args.max_inflight)
        else:
            executor = DeviceExecutor(serve_fn, params, m, n,
                                      use_pallas=args.use_pallas_stitch,
                                      mesh=mesh, rules=rules)
        if args.online_latency:
            # a 1-worker pool only adds the estimator feedback loop: the
            # wrapped executor keeps its sync-vs-async semantics, so the
            # flag never changes execution mode behind the user's back
            executor = WorkerPoolExecutor([executor], estimator=table)
    scene = Scene(preset(args.scene, width=2 * args.canvas,
                         height=args.canvas))
    stream = generate_stream(scene, executor, args.frames, args.canvas,
                             args.slo)

    pool = uniform_pool(m, n, table, max_canvases=4)
    clock = (WallClock(speed=args.wall_speed) if args.clock == "wall"
             else VirtualClock())
    engine = ServingEngine(pool, executor, clock=clock)
    outcomes = engine.run(shape_arrivals(stream, args.bandwidth_mbps * 1e6))

    violated = sum(o.violated for o in outcomes)
    if args.workers > 1:
        overlap = (f"{args.workers} worker(s), {args.placement} placement, "
                   f"in-flight high water {engine.inflight_high_water}/"
                   f"{getattr(executor, 'max_inflight', '-')}")
    elif args.async_device:
        overlap = (f"async, in-flight high water "
                   f"{engine.inflight_high_water}/{args.max_inflight}")
    else:
        overlap = "sync"
    if args.online_latency:
        overlap += ", online latency"
    print(f"served {len(stream)} patches in {executor.n_invocations} "
          f"invocations ({overlap}, {args.clock} clock, "
          f"{executor.n_sharded} data-parallel over "
          f"data={axis_sizes.get('data', 1)}), "
          f"routed {executor.n_detections} detections + "
          f"{executor.evidence_bytes / 1e6:.2f} MB patch evidence back to "
          f"frames, {violated} SLO violations "
          f"({len(executor.frames)} frames still held, "
          f"{time.time()-t_start:.1f}s wall)")
    if isinstance(executor, WorkerPoolExecutor):
        for ws in executor.worker_stats():
            drift = (f", drift {ws['drift']}x" if "drift" in ws else "")
            print(f"  worker {ws['worker']}: {ws['invocations']} "
                  f"invocations, {ws['patches']} patches, "
                  f"busy {ws['busy_s']:.3f}s{drift}")


if __name__ == "__main__":
    main()
