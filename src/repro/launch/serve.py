"""Serving driver: the full Tangram pipeline against a real jit'd model.

Edge side per frame: GMM background subtraction -> RoI extraction ->
adaptive frame partitioning (Alg. 1).  Cloud side: SLO-aware invoker
(Alg. 2) -> stitch kernel assembles canvases -> detector ``serve_step``
executes the batch.  On CPU this runs a reduced detector; the platform
billing and SLO accounting are the same objects the simulator uses.

Multi-device: the detector batch runs under a ``NamedSharding``
data-parallel layout — the stitched canvas batch is padded to the mesh's
"data"-axis size and split over it, so each device detects its slice of
the canvases (stitch -> sharded detect -> unstitch -> route, end to end).
On a 1-device world the mesh degenerates to 1x1 and every step is
identical to the unsharded path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --frames 40 --slo 1.0
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --frames 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import param as param_lib
from repro.compat import shardingx
from repro.config import DetectorConfig
from repro.core import gmm, partitioning, rois
from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import measure
from repro.data.synthetic import Scene, preset
from repro.kernels.stitch import ops as stitch_ops
from repro.launch.mesh import make_serve_mesh
from repro.models import detector as detector_lib
from repro.sharding import ShardingConfig, divisible_sharding


def build_detector(canvas: int = 256):
    cfg = DetectorConfig(name="serve-det", canvas=canvas, patch=32,
                         n_layers=2, d_model=64, n_heads=4, d_ff=128,
                         param_dtype="float32", compute_dtype="float32")
    rules = ShardingConfig.make().rules
    params = param_lib.init_params(jax.random.PRNGKey(0),
                                   detector_lib.param_specs(cfg))
    serve_fn = jax.jit(lambda p, x: detector_lib.serve(cfg, p, x, rules))
    # the same table the jit-internal logical constraints use: callers
    # must lay inputs out with these rules or force a reshard on entry
    return cfg, params, serve_fn, rules


def shard_canvases(canvases, mesh, rules):
    """Lay the canvas batch out data-parallel over the serve mesh.

    The batch is padded to a multiple of the "data"-axis size (records
    never reference pad rows, so the detector output for them is simply
    ignored), then device_put with the batch axis split over "data".
    Pow2-style padding also stabilises jit static shapes: every batch
    compiles to a multiple of the axis size.  Returns the sharded batch
    and whether the data axis actually split it (False on 1 device).
    """
    n_data = shardingx.mesh_axis_sizes(mesh).get("data", 1)
    pad = (-canvases.shape[0]) % n_data
    if pad:
        canvases = jnp.concatenate(
            [canvases,
             jnp.zeros((pad,) + canvases.shape[1:], canvases.dtype)])
    sh = divisible_sharding(mesh, canvases.shape,
                            ("batch", None, None, None), rules)
    return jax.device_put(canvases, sh), bool(sh.spec) and n_data > 1


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--frames", type=int, default=40)
    p.add_argument("--slo", type=float, default=1.0)
    p.add_argument("--canvas", type=int, default=256)
    p.add_argument("--scene", type=int, default=0)
    p.add_argument("--use-pallas-stitch", action="store_true",
                   help="assemble canvases with the Pallas kernel "
                        "(interpret mode on CPU)")
    args = p.parse_args(argv)

    cfg, params, serve_fn, rules = build_detector(args.canvas)
    m = n = args.canvas
    mesh = make_serve_mesh()
    axis_sizes = shardingx.mesh_axis_sizes(mesh)
    print(f"serve mesh: data={axis_sizes.get('data', 1)} "
          f"model={axis_sizes.get('model', 1)} "
          f"({mesh.devices.size} devices)")

    # offline profiling (the paper's 1000-iteration stage, scaled down)
    # under the same data-parallel layout execution will use; the sync
    # hook keeps jit's async dispatch inside the timed region
    def run_batch(b):
        x = jnp.zeros((b, m, n, 3), jnp.float32)
        x, _ = shard_canvases(x, mesh, rules)
        return serve_fn(params, x)
    table = measure(run_batch, batch_sizes=(1, 2, 4), iters=5, warmup=1,
                    sync=jax.block_until_ready)
    print("latency table:",
          {k: (round(v[0], 4), round(v[1], 4)) for k, v in table.table.items()})

    scene = Scene(preset(args.scene, width=2 * args.canvas,
                         height=args.canvas))
    state = gmm.init_state(scene.cfg.height, scene.cfg.width)
    invoker = SLOAwareInvoker(m, n, table, max_canvases=4)

    n_patches = n_invocations = n_detections = n_sharded = 0
    evidence_bytes = 0

    def run_invocation(inv):
        nonlocal n_invocations, n_detections, n_sharded, evidence_bytes
        n_invocations += 1
        _, _, per_frame, pixels, sharded = _execute(
            inv, frames_store, serve_fn, params, m, n,
            args.use_pallas_stitch, mesh=mesh, rules=rules)
        n_sharded += bool(sharded)
        n_detections += sum(len(v) for v in per_frame.values())
        evidence_bytes += sum(a.nbytes for v in pixels.values() for a in v)
    t_start = time.time()
    frames_store = {}
    for t, frame, gt in scene.frames(args.frames):
        state, fg = gmm.update_jit(state, jnp.asarray(frame))
        if t < 1.0:
            continue
        boxes, valid = rois.extract_rois_jit(jnp.asarray(fg))
        boxes_np = np.asarray(boxes)[np.asarray(valid)]
        patches = partitioning.partition_host(
            boxes_np, scene.cfg.width, scene.cfg.height, 4, 4,
            frame_id=scene.t, t_gen=t, slo=args.slo)
        # enclosing rects can exceed zones; clamp to the canvas tile
        patches = [partitioning.Patch(
            p.x0, p.y0, min(p.x1, p.x0 + n), min(p.y1, p.y0 + m),
            p.frame_id, p.camera_id, p.t_gen, p.slo) for p in patches]
        frames_store[scene.t] = scene.render_rgb()
        now = time.time() - t_start
        for patch in patches:
            n_patches += 1
            fired = invoker.on_patch(now, patch)
            fired += filter(None, [invoker.poll(now)])
            for inv in fired:
                run_invocation(inv)
    last = invoker.flush(time.time() - t_start)
    if last:
        run_invocation(last)
    print(f"served {n_patches} patches in {n_invocations} invocations "
          f"({n_sharded} data-parallel over data={axis_sizes.get('data', 1)}), "
          f"routed {n_detections} detections + "
          f"{evidence_bytes / 1e6:.2f} MB patch evidence back to frames "
          f"({time.time()-t_start:.1f}s wall)")


def _execute(inv, frames_store, serve_fn, params, m, n, use_pallas,
             mesh=None, rules=None):
    """One serverless invocation: the invoker's multi-canvas plan drives a
    single batched stitch, the data-parallel detector batch, and the
    inverse unstitch that routes per-patch outputs back to their source
    frames."""
    plan = inv.batch_plan()
    crops = []
    for patch in inv.patches:
        frame = frames_store.get(patch.frame_id)
        if frame is None:
            crops.append(np.zeros((patch.h, patch.w, 3), np.float32))
        else:
            crops.append(frame[patch.y0:patch.y1, patch.x0:patch.x1])
    slots = stitch_ops.pack_plan_host(crops, plan)
    records = jnp.asarray(plan.records)
    impl = "pallas_interpret" if use_pallas else "xla"
    canvases = stitch_ops.stitch_canvases(
        jnp.asarray(slots), records, m, n, impl=impl)
    sharded = False
    if mesh is not None:
        canvases, sharded = shard_canvases(canvases, mesh, rules)
    obj, boxes = serve_fn(params, canvases)
    # inverse gather, grouped by source frame alongside the routed
    # detections.  The box head has no pixel-space output, so the
    # canvases stand in for a per-pixel head (e.g. segmentation): the
    # gathered slots equal the input crops, and the value here is
    # exercising the unstitch path every invocation.  slot_capacity
    # (pow2-bucketed) keeps the jit static shapes stable across
    # invocations; rows past num_patches are never read.
    patch_out = stitch_ops.unstitch_patches(
        canvases, records, plan.slot_capacity, plan.hmax, plan.wmax,
        impl=impl)
    jax.block_until_ready((obj, patch_out))
    per_frame = stitch_ops.route_detections(plan, inv.patches,
                                            np.asarray(obj), np.asarray(boxes))
    evidence = np.asarray(patch_out)
    per_frame_pixels = {}
    for i, patch in enumerate(inv.patches):
        # copy: a view would pin the whole pow2-padded batch in memory
        per_frame_pixels.setdefault(patch.frame_id, []).append(
            np.ascontiguousarray(evidence[i, :patch.h, :patch.w]))
    return obj, boxes, per_frame, per_frame_pixels, sharded


if __name__ == "__main__":
    main()
