"""Collective-traffic + roofline-term extraction from compiled artifacts.

``cost_analysis()`` has no collective statistics, so we parse the
post-SPMD per-device HLO text and sum the output bytes of every
collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, sync or async-start form).  Shapes in the partitioned
module are per-device, so the sum is bytes-through-ICI per device — the
quantity the collective roofline term wants.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

from repro.config import HardwareConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[2048,1408]{1,0} all-gather(...)
#        ROOT %tuple ... f32[]  all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        bytes_by[kind] += n * _DTYPE_BYTES[dtype]
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


# ----------------------------------------------------------- while loops ----

_WHILE_TRIP_RE = re.compile(
    r'while\(.*?\).*?backend_config=.*?"known_trip_count":\{"n":"(\d+)"\}')


def scan_trip_counts(hlo_text: str):
    """Known trip counts of while loops (scan-over-layers multiplies the
    per-iteration collective bytes).  Best effort: XLA records
    known_trip_count in the while op's backend_config."""
    return [int(m.group(1)) for m in _WHILE_TRIP_RE.finditer(hlo_text)]


# ----------------------------------------------------------------- terms ----

@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    model_flops_global: float = 0.0
    chips: int = 1
    arg_bytes: int = 0
    temp_bytes: int = 0          # XLA-CPU temp (pessimistic, see notes)
    out_bytes: int = 0
    analytic_act_bytes: float = 0.0
    notes: str = ""

    @property
    def hbm_estimate(self) -> float:
        """args (exact: params+opt+cache+batch) + analytic activations."""
        return self.arg_bytes + self.analytic_act_bytes

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global): remat/dispatch waste detector."""
        hlo_global = self.flops_per_device * self.chips
        if hlo_global <= 0:
            return 0.0
        return self.model_flops_global / hlo_global

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute peak: t_compute / max(all terms),
        i.e. how close the cell sits to being compute-bound."""
        t_max = max(self.t_compute, self.t_memory, self.t_collective, 1e-30)
        return self.t_compute / t_max

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": f"{self.t_compute:.3e}",
            "t_memory_s": f"{self.t_memory:.3e}",
            "t_collective_s": f"{self.t_collective:.3e}",
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": f"{self.useful_flops_ratio:.2f}",
            "roofline_fraction": f"{self.roofline_fraction:.2f}",
            "hbm_bytes_per_dev": f"{self.arg_bytes + self.temp_bytes:.3e}",
            "notes": self.notes,
        }


def estimate_activation_bytes(cfg, shape, kind: str, data_size: int,
                              model_size: int, accum: int = 1,
                              act_seq: bool = False) -> float:
    """Coarse analytic per-device activation footprint on the TPU target
    (remat policy: per-layer dot outputs saved; flash attention — scores
    never materialize).  XLA-CPU ``memory_analysis`` temp numbers are not
    representative of the TPU executable (no fusion-aware buffer packing,
    naive attention transients), so the fits-in-HBM call uses this model;
    both numbers are reported.
    """
    from repro.config import (DetectorConfig, DiTConfig, EfficientNetConfig,
                              TransformerConfig, ViTConfig)
    B = max(shape.global_batch // (accum * data_size), 1)

    if isinstance(cfg, TransformerConfig):
        d = cfg.d_model
        if cfg.moe:
            ff_active = (cfg.moe.top_k + cfg.moe.n_shared) * \
                (cfg.moe.d_ff_expert or cfg.d_ff)
        else:
            ff_active = cfg.d_ff
        ff_dev = ff_active / (1 if cfg.moe else model_size)
        if kind == "train":
            tok = B * shape.seq_len
            seq_shards = model_size if act_seq else 1
            carry = tok * d * 2 / seq_shards            # layer-boundary x
            if getattr(cfg, "remat_policy", "dots") == "minimal":
                per_layer = carry
            else:
                heads_div = cfg.n_heads % model_size == 0
                attn = tok * 2 * (2 * d / (model_size if heads_div else 1)
                                  + 2 * cfg.n_kv_heads * cfg.head_dim /
                                  (model_size if cfg.n_kv_heads %
                                   model_size == 0 else 1))
                mlp = tok * 2 * 2 * ff_dev
                per_layer = carry + attn + mlp
            logits = B * 512 * cfg.vocab / model_size * 4  # loss chunk
            # attention transient is block-bounded on TPU (flash kernel
            # VMEM working set), not O(S^2)
            transient = 64 * 2**20
            return cfg.n_layers * per_layer + logits + transient
        if kind == "prefill":
            tok = B * shape.seq_len
            return 6 * tok * d * 2 + 64 * 2**20
        if kind == "decode":
            return 8 * B * d * 2 * cfg.n_layers
    if isinstance(cfg, (ViTConfig, DiTConfig, DetectorConfig)):
        d = cfg.d_model
        if isinstance(cfg, DiTConfig):
            tok = B * cfg.n_tokens(shape.img_res)
        elif isinstance(cfg, ViTConfig):
            side = (shape.img_res or cfg.img_res) // cfg.patch
            tok = B * (side * side + 2)
        else:
            tok = B * cfg.n_tokens
        ff_dev = getattr(cfg, "d_ff", 4 * d) / model_size
        saved = tok * 2 * (4 * d + 2 * ff_dev)
        n_live = cfg.n_layers if kind in ("train", "cls") else 2
        return n_live * saved + tok * tok // max(B, 1) * 4  # + scores
    if isinstance(cfg, EfficientNetConfig):
        r = shape.img_res or cfg.img_res
        # dominant early-stage feature maps, ~sum over stages of B*H*W*C
        total = 0.0
        res, c = r // 2, cfg.scaled_channels(cfg.stem_channels)
        for (e, ch, rep, st, k) in cfg.STAGES:
            res = res // st
            c = cfg.scaled_channels(ch)
            total += cfg.scaled_repeats(rep) * res * res * c * e * 2
        n_live = 1.0 if kind == "serve" else 1.0  # BN saves activations
        return B * total * n_live
    return 0.0


def model_flops(n_params: int, n_active: int, shape, kind: str,
                cfg=None) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (fwd-only), N = active params.

    D (tokens processed): LM = batch*seq (train/prefill) or batch (decode);
    vision/diffusion = batch * tokens; gen multiplies by sampler steps.
    """
    if kind in ("train", "cls"):
        mult = 6.0
    else:
        mult = 2.0
    if shape.seq_len:
        d = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    elif cfg is not None and callable(getattr(cfg, "n_tokens", None)):
        d = shape.global_batch * cfg.n_tokens(shape.img_res)   # DiT
    elif cfg is not None and hasattr(cfg, "patch") and shape.img_res:
        side = shape.img_res // cfg.patch                       # ViT/DeiT
        d = shape.global_batch * (side * side + 1)
    elif shape.img_res:
        d = shape.global_batch * (shape.img_res // 16) ** 2
    else:
        d = shape.global_batch
    steps = shape.steps if kind == "gen" else 1
    return mult * n_active * d * steps
