"""Training driver: data -> sharded train steps -> checkpoints, with
failure-drill support (elastic re-mesh + resume-from-latest).

On this CPU container it runs reduced configs end-to-end (examples/ and
integration tests); on a pod the same driver runs the full configs — the
mesh/ sharding / checkpoint logic is identical.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tangram-detector \
      --steps 50 --batch 4 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, param as param_lib
from repro import configs as cfg_registry
from repro.config import DetectorConfig, ShapeConfig, TransformerConfig
from repro.data import loader
from repro.sharding import ShardingConfig
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib
from repro.training.elastic import ElasticState, FailureEvent, FailureInjector


def reduced_config(model):
    """Shrink any arch config to a CPU-trainable size (same family)."""
    if isinstance(model, TransformerConfig):
        return dataclasses.replace(
            model, n_layers=2, d_model=128, n_heads=4, n_kv_heads=min(
                model.n_kv_heads, 4), d_ff=256, vocab=512, head_dim=32,
            param_dtype="float32", compute_dtype="float32", remat=False,
            moe=dataclasses.replace(model.moe, n_experts=4, top_k=min(
                model.moe.top_k, 2), d_ff_expert=64, group_size=64)
            if model.moe else None)
    if isinstance(model, DetectorConfig):
        return dataclasses.replace(model, canvas=256, patch=32, n_layers=2,
                                   d_model=64, n_heads=4, d_ff=128,
                                   param_dtype="float32",
                                   compute_dtype="float32")
    raise TypeError(f"reduced training not wired for {type(model)}")


def make_data(model, shape: ShapeConfig, seed: int = 0):
    if isinstance(model, TransformerConfig):
        return loader.lm_batches(model.vocab, shape.global_batch,
                                 shape.seq_len, seed=seed)
    if isinstance(model, DetectorConfig):
        return loader.detector_batches(model.canvas, shape.global_batch,
                                       seed=seed)
    raise TypeError(type(model))


def train(model, shape: ShapeConfig, *, steps: int, ckpt_dir: Optional[str],
          ckpt_every: int = 20, seed: int = 0,
          injector: Optional[FailureInjector] = None,
          opt_cfg: Optional[opt_lib.OptimizerConfig] = None,
          log_every: int = 10):
    """Single-host training loop with resume + failure drills."""
    rules = ShardingConfig.make().rules
    specs = api.param_specs(model)
    opt_cfg = opt_cfg or opt_lib.OptimizerConfig(
        lr=1e-3, warmup_steps=max(steps // 10, 1), total_steps=steps)

    params = param_lib.init_params(jax.random.PRNGKey(seed), specs)
    opt_state = opt_lib.init(params)
    start_step = 0
    if ckpt_dir:
        restored, at = ckpt_lib.restore_latest(ckpt_dir,
                                               {"p": params, "o": opt_state})
        if restored is not None:
            params, opt_state = restored["p"], restored["o"]
            start_step = at
            print(f"resumed from step {at}")

    loss_fn = api._loss_fn(model, rules)
    from repro.training.train_state import make_train_step
    step_fn = jax.jit(make_train_step(loss_fn, opt_cfg))

    data = make_data(model, shape, seed=seed)
    losses = []
    for step in range(start_step, steps):
        if injector:
            for ev in injector.poll(step):
                # failure drill: drop state, restore latest checkpoint
                print(f"[drill] {ev.kind} at step {step}: "
                      f"restoring latest checkpoint")
                restored, at = ckpt_lib.restore_latest(
                    ckpt_dir, {"p": params, "o": opt_state})
                assert restored is not None, "no checkpoint to recover from"
                params, opt_state = restored["p"], restored["o"]
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"step {step}: loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, step + 1, {"p": params, "o": opt_state})
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, steps, {"p": params, "o": opt_state})
    return params, losses


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tangram-detector",
                   choices=cfg_registry.ARCH_IDS)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--ckpt-dir")
    p.add_argument("--drill-step", type=int,
                   help="inject a failure drill at this step")
    args = p.parse_args(argv)

    spec = cfg_registry.get(args.arch)
    model = reduced_config(spec.model) if args.reduced else spec.model
    if isinstance(model, TransformerConfig):
        shape = ShapeConfig("train", "train", seq_len=args.seq,
                            global_batch=args.batch)
    else:
        shape = ShapeConfig("train", "train", img_res=model.canvas,
                            global_batch=args.batch)
    injector = None
    if args.drill_step:
        injector = FailureInjector(
            [FailureEvent(args.drill_step, "host", 0)])
    t0 = time.time()
    _, losses = train(model, shape, steps=args.steps,
                      ckpt_dir=args.ckpt_dir, injector=injector)
    print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
