"""Logical-axis sharding machinery (MaxText-style).

Models annotate parameters and activations with *logical* axis names
("batch", "embed", "mlp", ...).  A per-config rule table resolves logical
names to physical mesh axes ("pod", "data", "model").  This keeps model
code mesh-agnostic: the same model runs on the single-pod (data, model)
mesh, the multi-pod (pod, data, model) mesh, or a 1-device test mesh just
by swapping the rule table.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shardingx

# A logical axis resolves to: a mesh axis name, a tuple of mesh axis names
# (product sharding), or None (replicated).
MeshAxes = Union[str, Tuple[str, ...], None]
Rules = Mapping[str, MeshAxes]

# Default rule table for the production meshes.  "batch" shards over the
# pure-DP axes (pod, data); weight matrices shard their wide dimension over
# "model".  Logical axes absent from the table are replicated.
DEFAULT_RULES: Rules = {
    "batch": ("data", "pod"),
    "decode_batch": ("data", "pod"),
    "seq": None,
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": None,
    "head_dim": None,
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "capacity": None,
    "layers": None,
    "img_h": None,
    "img_w": None,
    "channels": "model",
    "in_channels": None,
    "patch": None,
    "kv_seq": None,
    "canvas": ("data", "pod"),
    "stack": None,
    "expert_group": ("data", "pod"),
}

# FSDP rule overlay: additionally shard the parameter "embed" (contraction)
# dimension over the data axis so optimizer state is fully sharded (ZeRO-3
# style).  Used for >=100B-param configs (mistral-large-123b).
FSDP_OVERLAY: Rules = {
    "embed": "data",
}

# Sequence-parallel overlay for long-context decode cells: the KV cache
# shards its sequence dimension over "model".
SEQUENCE_OVERLAY: Rules = {
    "kv_seq": "model",
}

# Activation sequence-sharding (Megatron-SP-style) for big train cells:
# layer-boundary activations shard "seq" over "model"; GSPMD inserts the
# all-gather at the attention boundary and the reduce-scatter after —
# 16x less saved-activation memory for ~one extra collective pair/layer.
ACT_SEQ_OVERLAY: Rules = {
    "seq": "model",
}


def merge_rules(*tables: Optional[Rules]) -> Rules:
    out: dict = {}
    for t in tables:
        if t:
            out.update(t)
    return out


def _mesh_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    rules: Rules,
    mesh: Optional[Mesh] = None,
) -> P:
    """Resolve a tuple of logical axis names to a PartitionSpec.

    Mesh axes that do not exist on the provided mesh are dropped (so the
    same rules work on a 1-device test mesh with no "model" axis).  A mesh
    axis may appear at most once in the spec; later duplicates are dropped.
    """
    available = set(_mesh_axis_names(mesh)) if mesh is not None else None
    used: set = set()
    parts = []
    for ax in logical_axes:
        if ax is None:
            parts.append(None)
            continue
        resolved = rules.get(ax, None)
        if resolved is None:
            parts.append(None)
            continue
        axes = (resolved,) if isinstance(resolved, str) else tuple(resolved)
        keep = []
        for a in axes:
            if available is not None and a not in available:
                continue
            if a in used:
                continue
            used.add(a)
            keep.append(a)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(tuple(keep))
    # Trim trailing Nones for tidier specs.
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named_sharding(
    mesh: Mesh, logical_axes: Sequence[Optional[str]], rules: Rules
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules, mesh))


def divisible_spec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Like ``logical_to_spec`` but drops mesh axes that do not divide the
    dim size evenly (required for jit input shardings).  For multi-axis
    rules like batch -> ("data", "pod") axes are kept greedily in order,
    skipping any axis whose inclusion would break divisibility.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    parts = []
    for dim, ax in zip(shape, logical_axes):
        if ax is None or rules.get(ax) is None:
            parts.append(None)
            continue
        resolved = rules[ax]
        axes = (resolved,) if isinstance(resolved, str) else tuple(resolved)
        keep, prod = [], 1
        for a in axes:
            if a not in sizes or a in used:
                continue
            if dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        for a in keep:
            used.add(a)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(tuple(keep))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def divisible_sharding(mesh, shape, logical_axes, rules) -> NamedSharding:
    return NamedSharding(mesh, divisible_spec(shape, logical_axes, rules, mesh))


def with_logical_constraint(x, logical_axes: Sequence[Optional[str]], rules: Rules):
    """Apply a sharding constraint from logical axes, if inside a mesh ctx.

    Outside a mesh context (unit tests on one device) this is a no-op.
    Mesh axes that do not divide the dim evenly are dropped: GSPMD
    technically supports uneven sharding via padding, but for e.g. 40
    heads on a 16-way axis it falls back to "involuntary full
    rematerialization" (replicate + reshard) which injects massive
    all-gathers — replicating outright is strictly better.
    """
    env_mesh = shardingx.get_abstract_mesh()
    if env_mesh is None:
        return x
    sizes = shardingx.mesh_axis_sizes(env_mesh)
    used: set = set()
    parts = []
    for dim, ax in zip(x.shape, logical_axes):
        resolved = rules.get(ax) if ax is not None else None
        if resolved is None:
            parts.append(None)
            continue
        axes = (resolved,) if isinstance(resolved, str) else tuple(resolved)
        keep, prod = [], 1
        for a in axes:
            if a not in sizes or a in used:
                continue
            if dim % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        used.update(keep)
        parts.append(None if not keep
                     else keep[0] if len(keep) == 1 else tuple(keep))
    return jax.lax.with_sharding_constraint(x, P(*parts))


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Bundle of rule tables selected per arch/shape cell."""

    rules: Rules

    @staticmethod
    def make(fsdp: bool = False, sequence_parallel: bool = False,
             act_seq: bool = False,
             extra: Optional[Rules] = None) -> "ShardingConfig":
        rules = merge_rules(
            DEFAULT_RULES,
            FSDP_OVERLAY if fsdp else None,
            SEQUENCE_OVERLAY if sequence_parallel else None,
            ACT_SEQ_OVERLAY if act_seq else None,
            extra,
        )
        return ShardingConfig(rules=rules)
