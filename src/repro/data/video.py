"""Transmission byte model + bandwidth-shaped patch arrival.

Compressed sizes follow a bits-per-pixel model (JPEG-crop-ish for patches,
intra-frame H.264-ish for full frames; masked frames compress the masked
background to almost nothing):

    patch bytes  = header + area * BPP_FG
    frame bytes  = header + W*H * BPP_FULL
    masked bytes = header + fg_area * BPP_FG + (W*H - fg_area) * BPP_BG

Constants are calibrated so a 3840x2160 frame is ~1.0 MB (0.125 B/px),
matching the paper's 13-34 Mbps @30fps band for 4K H.264.

Two shaping surfaces over the same FIFO-link model:

* :func:`shape_arrivals` — batch: shape a whole per-camera patch list at
  once (trace replay, benchmarks);
* :class:`Uplink` — streaming: one camera's link as an object, shaping
  patches as they are produced (the live sources in
  :mod:`repro.sources`).  ``shape_arrivals`` is implemented on top of it,
  so the two paths cannot drift apart.

:func:`load_frames` reads a recorded frame sequence (``.npy``/``.npz``
stack, or a directory of per-frame ``.npy`` files) for
``repro.sources.FileStreamSource``.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import List, Sequence, Union

import numpy as np

from repro.core.partitioning import Patch

BPP_FULL = 0.125      # bytes/pixel, full-frame intra coding
BPP_FG = 0.25         # bytes/pixel, high-quality RoI crops
BPP_BG_MASKED = 0.01  # bytes/pixel, masked (uniform) background
HEADER_BYTES = 256


def patch_bytes(p: Patch) -> float:
    return HEADER_BYTES + p.area * BPP_FG


def frame_bytes(width: int, height: int) -> float:
    return HEADER_BYTES + width * height * BPP_FULL


def masked_frame_bytes(width: int, height: int, fg_area: int) -> float:
    bg = width * height - fg_area
    return HEADER_BYTES + fg_area * BPP_FG + bg * BPP_BG_MASKED


@dataclasses.dataclass
class Arrival:
    t_arrive: float
    patch: Patch
    n_bytes: float


class Uplink:
    """One camera's FIFO uplink, shaping patches as they are produced.

    The streaming counterpart of :func:`shape_arrivals` (which is built
    on top of this class): arrival time = max(t_gen, link free) +
    bytes / bandwidth, patches serialised in send order.  Keeps running
    byte/transmission totals so live sources can account for bandwidth
    exactly like the batch path does.
    """

    def __init__(self, bandwidth_bps: float):
        if bandwidth_bps <= 0:
            raise ValueError(f"bandwidth must be positive, got "
                             f"{bandwidth_bps}")
        self.byte_rate = bandwidth_bps / 8.0
        self.link_free = 0.0
        self.bytes_sent = 0.0
        self.transmission_seconds = 0.0
        self.n_sent = 0

    def send(self, p: Patch) -> Arrival:
        b = patch_bytes(p)
        start = max(p.t_gen, self.link_free)
        t_arr = start + b / self.byte_rate
        self.link_free = t_arr
        self.bytes_sent += b
        self.transmission_seconds += t_arr - p.t_gen
        self.n_sent += 1
        return Arrival(t_arr, p, b)


def shape_arrivals(patches: Sequence[Patch], bandwidth_bps: float
                   ) -> List[Arrival]:
    """FIFO uplink: each camera serialises its patches over one link.

    ``patches`` must be in generation order for a single camera; arrival
    time = max(t_gen, link free) + bytes / bandwidth.
    """
    link = Uplink(bandwidth_bps)
    return [link.send(p) for p in patches]


def merge_arrivals(per_camera: Sequence[List[Arrival]]) -> List[Arrival]:
    out = [a for cam in per_camera for a in cam]
    out.sort(key=lambda a: a.t_arrive)
    return out


def load_frames(path: Union[str, pathlib.Path]) -> np.ndarray:
    """Read a recorded frame sequence into a (T, H, W) float32 stack.

    Accepts a ``.npy`` stack, an ``.npz`` archive (first array, or the
    one named ``frames``), or a directory of per-frame ``.npy`` files
    (lexicographic order).  RGB stacks (T, H, W, 3) are collapsed to
    luminance; integer dtypes are rescaled from [0, 255] to [0, 1].
    """
    path = pathlib.Path(path)
    if path.is_dir():
        files = sorted(path.glob("*.npy"))
        if not files:
            raise ValueError(f"no .npy frames in directory {path}")
        frames = np.stack([np.load(f) for f in files])
    elif path.suffix == ".npz":
        with np.load(path) as z:
            key = "frames" if "frames" in z.files else z.files[0]
            frames = z[key]
    else:
        frames = np.load(path)
    frames = np.asarray(frames)
    if frames.ndim == 2:
        frames = frames[None]
    if frames.ndim == 4:                      # RGB -> luminance
        frames = frames.mean(axis=-1)
    if frames.ndim != 3:
        raise ValueError(f"expected (T, H, W[, 3]) frames, got shape "
                         f"{frames.shape}")
    frames = frames.astype(np.float32)
    if frames.max(initial=0.0) > 1.5:         # 8-bit recording
        frames = frames / 255.0
    return frames
