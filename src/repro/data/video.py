"""Transmission byte model + bandwidth-shaped patch arrival.

Compressed sizes follow a bits-per-pixel model (JPEG-crop-ish for patches,
intra-frame H.264-ish for full frames; masked frames compress the masked
background to almost nothing):

    patch bytes  = header + area * BPP_FG
    frame bytes  = header + W*H * BPP_FULL
    masked bytes = header + fg_area * BPP_FG + (W*H - fg_area) * BPP_BG

Constants are calibrated so a 3840x2160 frame is ~1.0 MB (0.125 B/px),
matching the paper's 13-34 Mbps @30fps band for 4K H.264.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.partitioning import Patch

BPP_FULL = 0.125      # bytes/pixel, full-frame intra coding
BPP_FG = 0.25         # bytes/pixel, high-quality RoI crops
BPP_BG_MASKED = 0.01  # bytes/pixel, masked (uniform) background
HEADER_BYTES = 256


def patch_bytes(p: Patch) -> float:
    return HEADER_BYTES + p.area * BPP_FG


def frame_bytes(width: int, height: int) -> float:
    return HEADER_BYTES + width * height * BPP_FULL


def masked_frame_bytes(width: int, height: int, fg_area: int) -> float:
    bg = width * height - fg_area
    return HEADER_BYTES + fg_area * BPP_FG + bg * BPP_BG_MASKED


@dataclasses.dataclass
class Arrival:
    t_arrive: float
    patch: Patch
    n_bytes: float


def shape_arrivals(patches: Sequence[Patch], bandwidth_bps: float
                   ) -> List[Arrival]:
    """FIFO uplink: each camera serialises its patches over one link.

    ``patches`` must be in generation order for a single camera; arrival
    time = max(t_gen, link free) + bytes / bandwidth.
    """
    byte_rate = bandwidth_bps / 8.0
    link_free = 0.0
    out = []
    for p in patches:
        b = patch_bytes(p)
        start = max(p.t_gen, link_free)
        t_arr = start + b / byte_rate
        link_free = t_arr
        out.append(Arrival(t_arr, p, b))
    return out


def merge_arrivals(per_camera: Sequence[List[Arrival]]) -> List[Arrival]:
    out = [a for cam in per_camera for a in cam]
    out.sort(key=lambda a: a.t_arrive)
    return out
