"""Host-side training data loaders (detector training + LM synth data).

Deterministic, seeded, prefetch-free (CPU container); the interfaces match
what a tf.data/grain pipeline would expose on a real pod: an iterator of
ready-to-device batch dicts matching ``api.train_batch_specs``.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.core import partitioning, rois, stitching
from repro.core.gmm import GMMConfig, init_state, update
from repro.data.synthetic import Scene, SceneConfig, preset


def detector_batches(canvas: int, batch: int, max_boxes: int = 64,
                     seed: int = 0, scene_idx: int = 0,
                     n_batches: Optional[int] = None) -> Iterator[dict]:
    """Stitched-canvas detection batches from synthetic scenes.

    Runs the real edge pipeline (scene -> GT boxes -> Algorithm 1 ->
    stitching) and composites patch pixels onto canvases, yielding
    {canvases, boxes, valid} with boxes in canvas coordinates.
    """
    rng = np.random.default_rng(seed)
    scene = Scene(preset(scene_idx, width=canvas * 2, height=canvas,
                         fps=10.0))
    made = 0
    while n_batches is None or made < n_batches:
        canvases_px = np.zeros((batch, canvas, canvas, 3), np.float32)
        boxes_out = np.zeros((batch, max_boxes, 4), np.float32)
        valid_out = np.zeros((batch, max_boxes), bool)
        b = 0
        while b < batch:
            scene.step()
            frame = scene.render_rgb()
            gt = scene.boxes()
            patches = partitioning.partition_host(
                gt, scene.cfg.width, scene.cfg.height, 4, 4,
                frame_id=scene.t)
            if not patches:
                continue
            canvases = stitching.stitch(patches, canvas, canvas)
            for cv in canvases:
                if b >= batch:
                    break
                k = 0
                for pl in cv.placements:
                    p = patches[pl.patch_idx]
                    canvases_px[b, pl.y:pl.y + pl.h, pl.x:pl.x + pl.w] = \
                        frame[p.y0:p.y1, p.x0:p.x1]
                    # ground-truth boxes falling inside this patch,
                    # translated into canvas coordinates
                    for (x0, y0, x1, y1) in gt:
                        if k >= max_boxes:
                            break
                        if x0 >= p.x0 and y0 >= p.y0 and x1 <= p.x1 \
                                and y1 <= p.y1:
                            boxes_out[b, k] = (x0 - p.x0 + pl.x,
                                               y0 - p.y0 + pl.y,
                                               x1 - p.x0 + pl.x,
                                               y1 - p.y0 + pl.y)
                            valid_out[b, k] = True
                            k += 1
                b += 1
        yield {"canvases": canvases_px, "boxes": boxes_out,
               "valid": valid_out}
        made += 1


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
               n_batches: Optional[int] = None) -> Iterator[dict]:
    """Synthetic LM batches (Zipf-ish tokens with local structure)."""
    rng = np.random.default_rng(seed)
    made = 0
    while n_batches is None or made < n_batches:
        base = rng.zipf(1.3, size=(batch, seq)).clip(0, vocab - 1)
        tokens = base.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        yield {"tokens": tokens, "labels": labels}
        made += 1
