"""Synthetic PANDA-like scene generator.

The PANDA4K dataset is not redistributable in this container, so benchmarks
run on synthetic gigapixel-camera-style scenes calibrated to the paper's
Table I statistics: RoI proportion between ~2.6% and ~14.2% of the frame,
tens to hundreds of small moving objects (30-120 px at 4K scale), a static
background with texture, and irregular fluctuation of object counts
(Fig. 3).  Rendering is deterministic per (scene, frame) seed.

Scenes render at a configurable resolution; tests use 480x270, benchmarks
960x540 by default (4K / 4), with all object sizes scaled accordingly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

# (name, n_objects, mean object side in px at 4K, roi proportion target %)
# mirrors Table I's ten scenes
SCENE_PRESETS = [
    ("university_canteen", 25, 90, 5.45),
    ("oct_habour", 38, 90, 8.31),
    ("xili_crossroad", 55, 60, 5.91),
    ("primary_school", 24, 140, 14.16),
    ("basketball_court", 11, 120, 5.04),
    ("xinzhongguan", 90, 45, 5.23),
    ("university_campus", 25, 55, 2.59),
    ("xili_street_1", 48, 80, 9.63),
    ("xili_street_2", 30, 95, 8.75),
    ("huaqiangbei", 120, 50, 9.67),
]


@dataclasses.dataclass
class SceneConfig:
    name: str
    width: int = 960
    height: int = 540
    n_objects: int = 30
    obj_side: int = 24           # mean object side at render resolution
    fps: float = 10.0
    seed: int = 0
    speed: float = 3.0           # px / frame random walk scale
    burst_prob: float = 0.02     # irregular peaks (Fig. 3)
    n_clusters: int = 3          # crowds cluster (PANDA-like); most zones
    cluster_pull: float = 0.02   # stay background-only


ACTIVE_FRAC = 0.86          # stationary active fraction of the burst chain
_LOGNORM_AREA = 1.38        # E[side^2] inflation for sigma = 0.4


def preset(index: int, width: int = 960, height: int = 540,
           fps: float = 10.0) -> SceneConfig:
    """Calibrate mean object size so the scene hits its Table-I RoI
    proportion target at this resolution."""
    name, n_obj, _side4k, prop_pct = SCENE_PRESETS[index % len(SCENE_PRESETS)]
    target_area = prop_pct / 100.0 * width * height
    mean_area = target_area / (n_obj * ACTIVE_FRAC * _LOGNORM_AREA)
    side = max(4, int(mean_area ** 0.5))
    return SceneConfig(name=name, width=width, height=height,
                       n_objects=n_obj, obj_side=side, fps=fps, seed=index)


class Scene:
    """Moving-rectangle scene with textured static background."""

    def __init__(self, cfg: SceneConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        h, w = cfg.height, cfg.width
        # static textured background
        yy, xx = np.mgrid[0:h, 0:w]
        self.background = (
            0.35 + 0.15 * np.sin(xx / 37.0) * np.cos(yy / 23.0)
            + 0.05 * rng.standard_normal((h, w))
        ).astype(np.float32).clip(0.0, 1.0)

        n = cfg.n_objects
        self.centers = rng.uniform([w * .15, h * .15], [w * .85, h * .85],
                                   size=(cfg.n_clusters, 2)).astype(np.float32)
        assign = rng.integers(0, cfg.n_clusters, n)
        self.home = self.centers[assign]
        spread = min(w, h) / 8.0
        self.pos = (self.home + rng.normal(0, spread, (n, 2))
                    ).astype(np.float32).clip([0, 0], [w, h])
        self.vel = rng.normal(0, cfg.speed, size=(n, 2)).astype(np.float32)
        sides = rng.lognormal(np.log(cfg.obj_side), 0.4, size=(n, 2))
        self.size = np.clip(sides, 4, min(h, w) // 3).astype(np.float32)
        self.shade = rng.uniform(0.6, 1.0, size=n).astype(np.float32)
        self.active = np.ones(n, bool)
        self._rng = rng
        self.t = 0

    def step(self):
        cfg = self.cfg
        n = len(self.pos)
        self.vel += self._rng.normal(0, 0.5, size=(n, 2)).astype(np.float32)
        self.vel += cfg.cluster_pull * (self.home - self.pos)  # stay crowded
        self.vel = np.clip(self.vel, -3 * cfg.speed, 3 * cfg.speed)
        self.pos += self.vel
        # reflect at borders
        for d, limit in ((0, cfg.width), (1, cfg.height)):
            low = self.pos[:, d] < 0
            high = self.pos[:, d] > limit
            self.vel[low | high, d] *= -1
            self.pos[:, d] = np.clip(self.pos[:, d], 0, limit)
        # irregular bursts: asymmetric on/off chain with ~86% duty cycle
        r = self._rng.random(n)
        turn_off = self.active & (r < cfg.burst_prob)
        turn_on = ~self.active & (r < 6 * cfg.burst_prob)
        self.active = (self.active & ~turn_off) | turn_on
        if not self.active.any():
            self.active[0] = True
        self.t += 1

    def boxes(self) -> np.ndarray:
        """Ground-truth boxes (K, 4) xyxy of active objects."""
        w2 = self.size[:, 0] / 2
        h2 = self.size[:, 1] / 2
        b = np.stack([self.pos[:, 0] - w2, self.pos[:, 1] - h2,
                      self.pos[:, 0] + w2, self.pos[:, 1] + h2], axis=-1)
        b[:, 0::2] = b[:, 0::2].clip(0, self.cfg.width)
        b[:, 1::2] = b[:, 1::2].clip(0, self.cfg.height)
        b = b[self.active]
        keep = (b[:, 2] - b[:, 0] > 2) & (b[:, 3] - b[:, 1] > 2)
        return b[keep].astype(np.int32)

    def render(self) -> np.ndarray:
        """Grayscale frame (H, W) float32 with objects composited."""
        frame = self.background.copy()
        for i in np.nonzero(self.active)[0]:
            x0 = int(max(0, self.pos[i, 0] - self.size[i, 0] / 2))
            y0 = int(max(0, self.pos[i, 1] - self.size[i, 1] / 2))
            x1 = int(min(self.cfg.width, self.pos[i, 0] + self.size[i, 0] / 2))
            y1 = int(min(self.cfg.height, self.pos[i, 1] + self.size[i, 1] / 2))
            if x1 <= x0 or y1 <= y0:
                continue
            frame[y0:y1, x0:x1] = self.shade[i]
        return frame

    def render_rgb(self) -> np.ndarray:
        g = self.render()
        return np.stack([g, g * 0.9, g * 0.8], axis=-1)

    def frames(self, n: int):
        """Yield (t_seconds, frame, gt_boxes) for n frames."""
        for _ in range(n):
            self.step()
            yield self.t / self.cfg.fps, self.render(), self.boxes()

    def roi_proportion(self) -> float:
        b = self.boxes()
        if len(b) == 0:
            return 0.0
        area = ((b[:, 2] - b[:, 0]) * (b[:, 3] - b[:, 1])).sum()
        return float(area) / (self.cfg.width * self.cfg.height)
