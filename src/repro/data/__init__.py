"""Data pipeline: synthetic PANDA-like scenes, byte/bandwidth models,
training loaders."""
