"""Grouped-query attention with RoPE: train/prefill, and KV-cache decode.

The XLA einsum path below is the dry-run/compile path; the Pallas flash
kernel (``repro.kernels.attention``) is the TPU execution path and is
numerically validated against this module in tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.param import spec
from repro.sharding import with_logical_constraint

NEG_INF = -1e30


# ------------------------------------------------------------------ RoPE ----

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- specs ----

def _wspec(shape, axes, dtype, quant: bool, scale_axes_from: int = 1):
    """Weight spec; int8 + per-out-channel scale when quantized."""
    if quant:
        return {"q": spec(shape, axes, dtype=jnp.int8, init="zeros"),
                "scale": spec(shape[scale_axes_from:],
                              axes[scale_axes_from:], dtype=jnp.float32,
                              init="ones")}
    return spec(shape, axes, dtype=dtype, fan_in_axes=tuple(
        range(scale_axes_from)))


def weight(p, compute_dtype):
    """Materialize a (possibly int8-quantized) weight for compute."""
    if isinstance(p, dict) and "q" in p:
        w = p["q"].astype(compute_dtype)
        scale = p["scale"].astype(compute_dtype)
        return w * scale[(None,) * (w.ndim - scale.ndim)]
    return p.astype(compute_dtype)


def gqa_specs(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype, fused: bool = False, quant: bool = False):
    wo = _wspec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed"),
                dtype, quant, scale_axes_from=2)
    if fused:
        # single (d, H + 2*Kv, dh) projection: one MXU pass, one HBM read
        return {
            "wqkv": _wspec((d_model, n_heads + 2 * n_kv_heads, head_dim),
                           ("embed", "heads", "head_dim"), dtype, quant),
            "wo": wo,
        }
    return {
        "wq": _wspec((d_model, n_heads, head_dim),
                     ("embed", "heads", "head_dim"), dtype, quant),
        "wk": _wspec((d_model, n_kv_heads, head_dim),
                     ("embed", "kv_heads", "head_dim"), dtype, quant),
        "wv": _wspec((d_model, n_kv_heads, head_dim),
                     ("embed", "kv_heads", "head_dim"), dtype, quant),
        "wo": wo,
    }


# ------------------------------------------------------------- attention ----

def _qkv(params, x, n_kv_heads: int, compute_dtype):
    if "wqkv" in params:
        qkv = jnp.einsum("bsd,dhk->bshk", x,
                         weight(params["wqkv"], compute_dtype))
        n_heads = qkv.shape[2] - 2 * n_kv_heads
        return (qkv[:, :, :n_heads], qkv[:, :, n_heads:n_heads + n_kv_heads],
                qkv[:, :, n_heads + n_kv_heads:])
    q = jnp.einsum("bsd,dhk->bshk", x, weight(params["wq"], compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, weight(params["wk"], compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, weight(params["wv"], compute_dtype))
    return q, k, v


def _gqa_scores(q, k, n_kv_heads: int):
    """q: (B,Sq,H,D) -> grouped (B,Sq,Kv,G,D); scores (B,Kv,G,Sq,Skv) fp32."""
    B, Sq, H, D = q.shape
    G = H // n_kv_heads
    qg = q.reshape(B, Sq, n_kv_heads, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    return scores * (1.0 / jnp.sqrt(D).astype(jnp.float32))


def _gqa_out(probs, v, params, compute_dtype):
    """probs: (B,Kv,G,Sq,Skv); v: (B,Skv,Kv,D) -> (B,Sq,d_model)."""
    B, Kv, G, Sq, _ = probs.shape
    D = v.shape[-1]
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(compute_dtype), v)
    ctx = ctx.reshape(B, Sq, Kv * G, D)
    return jnp.einsum("bshk,hkd->bsd", ctx, weight(params["wo"], compute_dtype))


def attention(params, x, *, n_heads: int, n_kv_heads: int, rope_theta: float,
              compute_dtype, rules, positions: Optional[jnp.ndarray] = None,
              impl: str = "xla"):
    """Causal self-attention for train/prefill.  x: (B, S, d)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, n_kv_heads, compute_dtype)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = with_logical_constraint(q, ("batch", "seq", "heads", "head_dim"), rules)
    k = with_logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"), rules)

    if impl in ("flash", "flash_interpret"):
        from repro.kernels.attention import ops as flash_ops
        ctx = flash_ops.flash_attention(
            q, k, v, causal=True, interpret=(impl == "flash_interpret"))
        B_, Sq, H, D = ctx.shape
        out = jnp.einsum("bshk,hkd->bsd", ctx, weight(params["wo"], compute_dtype))
        return with_logical_constraint(out, ("batch", "seq", "embed"), rules)

    if impl == "chunked":
        ctx = _chunked_causal(q, k, v, n_kv_heads)
        out = jnp.einsum("bshk,hkd->bsd", ctx,
                         weight(params["wo"], compute_dtype))
        return with_logical_constraint(out, ("batch", "seq", "embed"), rules)

    scores = _gqa_scores(q, k, n_kv_heads)
    causal = (jnp.arange(S)[:, None] >= jnp.arange(S)[None, :])
    scores = jnp.where(causal[None, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, params, compute_dtype)
    return with_logical_constraint(out, ("batch", "seq", "embed"), rules)


def _chunk_size(s: int) -> int:
    """Tile size for the chunked stand-in: <= 8 chunks per axis, >= 2048."""
    c = max(2048, s // 8)
    while s % c:
        c += 1
    return min(c, s)


def _chunked_causal(q, k, v, n_kv_heads: int):
    """Online-softmax attention over KV chunks, unrolled python loops so
    the dry-run HLO carries exact per-chunk flop/traffic accounting.
    This is the pure-XLA stand-in for the Pallas flash kernel: same
    O(S) memory asymptotics (scores never materialize at S x S).
    q: (B,S,H,D); k,v: (B,S,Kv,D) -> ctx (B,S,H,D).  Causal."""
    B, S, H, D = q.shape
    G = H // n_kv_heads
    cq = ckv = _chunk_size(S)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    out = []
    for qs in range(0, S, cq):
        qg = q[:, qs:qs + cq].reshape(B, cq, n_kv_heads, G, D)
        m = jnp.full((B, n_kv_heads, G, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, n_kv_heads, G, cq), jnp.float32)
        acc = jnp.zeros((B, cq, n_kv_heads, G, D), jnp.float32)
        for ks in range(0, qs + cq, ckv):
            ke = min(ks + ckv, S)
            kc, vc = k[:, ks:ke], v[:, ks:ke]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc).astype(jnp.float32)
            s = s * scale
            rows = qs + jnp.arange(cq)[:, None]
            cols = ks + jnp.arange(ke - ks)[None, :]
            s = jnp.where((rows >= cols)[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bhgqk,bkhd->bqhgd", p.astype(q.dtype), vc).astype(jnp.float32)
            m = m_new
        ctx = acc / l.transpose(0, 3, 1, 2)[..., None]
        out.append(ctx.reshape(B, cq, H, D).astype(q.dtype))
    return jnp.concatenate(out, axis=1)


def encoder_attention(params, x, *, n_heads: int, compute_dtype, rules,
                      impl: str = "xla"):
    """Bidirectional MHA (no RoPE) for ViT/DiT encoders.  x: (B, S, d)."""
    q, k, v = _qkv(params, x, n_heads, compute_dtype)
    if impl in ("flash", "flash_interpret"):
        from repro.kernels.attention import ops as flash_ops
        ctx = flash_ops.flash_attention(
            q, k, v, causal=False, interpret=(impl == "flash_interpret"))
        return jnp.einsum("bshk,hkd->bsd", ctx,
                          weight(params["wo"], compute_dtype))
    scores = _gqa_scores(q, k, n_heads)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, params, compute_dtype)
    return with_logical_constraint(out, ("batch", "seq", "embed"), rules)


# ---------------------------------------------------------------- decode ----

def init_cache(batch: int, max_seq: int, n_kv_heads: int, head_dim: int,
               dtype, quant_kv: bool = False):
    shape = (batch, max_seq, n_kv_heads, head_dim)
    if quant_kv:
        sshape = (batch, max_seq, n_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(batch: int, max_seq: int, n_kv_heads: int, head_dim: int,
                dtype, quant_kv: bool = False):
    """ShapeDtypeStruct cache stand-ins for the dry-run."""
    shape = (batch, max_seq, n_kv_heads, head_dim)
    if quant_kv:
        sshape = (batch, max_seq, n_kv_heads)
        return {"k": jax.ShapeDtypeStruct(shape, jnp.int8),
                "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
                "v": jax.ShapeDtypeStruct(shape, jnp.int8),
                "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32)}
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


CACHE_AXES = ("decode_batch", "kv_seq", "kv_heads", "head_dim")
CACHE_SCALE_AXES = ("decode_batch", "kv_seq", "kv_heads")


def _quantize_kv(x):
    """x: (B, 1, Kv, D) -> (int8 values, (B, 1, Kv) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention(params, x, cache, pos, *, n_heads: int, n_kv_heads: int,
                     rope_theta: float, compute_dtype, rules,
                     impl: str = "xla", cache_update: str = "auto"):
    """One-token decode.  x: (B, 1, d); cache k/v: (B, Smax, Kv, D);
    pos: scalar int32 current position.  Returns (out, new_cache).

    Cost is O(Smax) per step — linear in context, not quadratic (the
    full-attention ``long_500k`` cells rely on this; see DESIGN.md §5).

    cache_update: "dus" (dynamic_update_slice), "masked" (one-hot blend),
    or "auto" — masked when the cache seq axis is sharded.  A dynamic
    slice update at a data-dependent position on a *sharded* axis makes
    GSPMD regather the whole cache (§Perf iteration 2.1); the masked
    blend is elementwise and sharding-oblivious.
    """
    B, one, _ = x.shape
    q, k_new, v_new = _qkv(params, x, n_kv_heads, compute_dtype)
    positions = jnp.full((B, 1), pos)
    q = apply_rope(q, positions, rope_theta)
    k_new = apply_rope(k_new, positions, rope_theta)

    quant_kv = "k_scale" in cache
    if cache_update == "auto":
        cache_update = "masked" if (rules.get("kv_seq") or quant_kv) else "dus"

    if quant_kv:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        sel = (jnp.arange(cache["k"].shape[1]) == pos)[None, :, None]
        k = jnp.where(sel[..., None], kq, cache["k"])
        v = jnp.where(sel[..., None], vq, cache["v"])
        k_scale = jnp.where(sel, ks, cache["k_scale"])
        v_scale = jnp.where(sel, vs, cache["v_scale"])
        k = with_logical_constraint(k, CACHE_AXES, rules)
        v = with_logical_constraint(v, CACHE_AXES, rules)
        new_cache = {"k": k, "k_scale": k_scale, "v": v, "v_scale": v_scale}
        # dequantize for attention (int8 stream, registers-dequant on TPU)
        k = k.astype(compute_dtype) * k_scale.astype(compute_dtype)[..., None]
        v = v.astype(compute_dtype) * v_scale.astype(compute_dtype)[..., None]
    elif cache_update == "masked":
        sel = (jnp.arange(cache["k"].shape[1]) == pos)[None, :, None, None]
        k = jnp.where(sel, k_new.astype(cache["k"].dtype), cache["k"])
        v = jnp.where(sel, v_new.astype(cache["v"].dtype), cache["v"])
        k = with_logical_constraint(k, CACHE_AXES, rules)
        v = with_logical_constraint(v, CACHE_AXES, rules)
        new_cache = {"k": k, "v": v}
    else:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        k = with_logical_constraint(k, CACHE_AXES, rules)
        v = with_logical_constraint(v, CACHE_AXES, rules)
        new_cache = {"k": k, "v": v}

    if impl in ("flash_decode", "flash_decode_interpret"):
        from repro.kernels.attention import ops as flash_ops
        ctx = flash_ops.flash_decode(
            q, k.astype(compute_dtype), v.astype(compute_dtype), pos,
            interpret=(impl == "flash_decode_interpret"))
        out = jnp.einsum("bshk,hkd->bsd", ctx,
                         weight(params["wo"], compute_dtype))
        return out, new_cache

    scores = _gqa_scores(q, k.astype(compute_dtype), n_kv_heads)  # (B,Kv,G,1,Smax)
    valid = (jnp.arange(k.shape[1]) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v.astype(compute_dtype), params, compute_dtype)
    return out, new_cache
