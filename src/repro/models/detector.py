"""Anchor-free single-stage detector on a ViT trunk — the Tangram "YOLOv8x".

The paper states Tangram is orthogonal to the DNN; we use a ViT backbone
over the 1024x1024 canvas (patch 32 -> 32x32 grid) with a per-cell head
predicting (objectness, cx, cy, w, h).  Targets are grid-assigned boxes
(FCOS-style center assignment).  This is the model the serverless function
executes on stitched canvases, and the model trained in
``examples/train_detector.py``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import DetectorConfig, ViTConfig, dtype_of
from repro.models import layers, vit
from repro.param import spec
from repro.sharding import with_logical_constraint


def _trunk_cfg(cfg: DetectorConfig) -> ViTConfig:
    return ViTConfig(
        name=f"{cfg.name}-trunk", img_res=cfg.canvas, patch=cfg.patch,
        n_layers=cfg.n_layers, d_model=cfg.d_model, n_heads=cfg.n_heads,
        d_ff=cfg.d_ff, n_classes=1, param_dtype=cfg.param_dtype,
        compute_dtype=cfg.compute_dtype, remat=cfg.remat,
        scan_layers=cfg.scan_layers,
        quant_weights=getattr(cfg, "quant_weights", False))


def param_specs(cfg: DetectorConfig):
    dtype = dtype_of(cfg.param_dtype)
    t = _trunk_cfg(cfg)
    trunk = vit.param_specs(t)
    # replace classification head with a detection head; drop cls machinery
    del trunk["head"], trunk["cls_token"]
    side = cfg.canvas // cfg.patch
    trunk["pos_embed"] = spec((1, side * side, cfg.d_model),
                              (None, "seq", "embed"), dtype=dtype, init="pos")
    return {
        "trunk": trunk,
        "det_head": layers.dense_specs(cfg.d_model, 5, in_axis="embed",
                                       out_axis=None, dtype=dtype, bias=True),
    }


def embed_params(cfg: DetectorConfig, params):
    """The patch-embed projection as plain (kernel, bias) arrays.

    The fused stitch->embed Pallas kernel applies this projection inside
    the stitch grid, so it needs the raw weights (always full precision —
    ``param_specs`` never quantizes the patch embed) cast to the compute
    dtype.
    """
    cdt = dtype_of(cfg.compute_dtype)
    pe = params["trunk"]["patch_embed"]
    return pe["kernel"].astype(cdt), pe["bias"].astype(cdt)


def forward_tokens(cfg: DetectorConfig, params, tokens, rules):
    """Embedded tokens (B, seq, d_model) -> (B, side, side, 5) raw head.

    The trunk minus the patch embed: entry point for the fused
    stitch->embed path, which produces the token batch on-device without
    materializing canvases in HBM.
    """
    cdt = dtype_of(cfg.compute_dtype)
    t = _trunk_cfg(cfg)
    tp = params["trunk"]
    x = tokens.astype(cdt) + tp["pos_embed"].astype(cdt)
    x = with_logical_constraint(x, ("canvas", "seq", "embed"), rules)
    x = vit._encoder(t, tp, x, rules, "xla")
    out = layers.dense(params["det_head"], x, cdt)
    side = cfg.canvas // cfg.patch
    return out.reshape(tokens.shape[0], side, side, 5)


def forward(cfg: DetectorConfig, params, canvases, rules):
    """canvases: (B, M, N, 3) -> (B, side, side, 5) raw head outputs."""
    cdt = dtype_of(cfg.compute_dtype)
    tp = params["trunk"]
    x = layers.dense(tp["patch_embed"], vit.patchify(canvases, cfg.patch), cdt)
    return forward_tokens(cfg, params, x, rules)


def decode_boxes(cfg: DetectorConfig, raw: jnp.ndarray,
                 obj_threshold: float = 0.5):
    """raw: (B, s, s, 5) -> (obj_prob, boxes_xyxy in canvas pixels)."""
    side = raw.shape[1]
    cell = cfg.canvas / side
    obj = jax.nn.sigmoid(raw[..., 0].astype(jnp.float32))
    gy, gx = jnp.meshgrid(jnp.arange(side), jnp.arange(side), indexing="ij")
    cx = (gx + jax.nn.sigmoid(raw[..., 1].astype(jnp.float32))) * cell
    cy = (gy + jax.nn.sigmoid(raw[..., 2].astype(jnp.float32))) * cell
    w = jnp.exp(jnp.clip(raw[..., 3].astype(jnp.float32), -6, 6)) * cell
    h = jnp.exp(jnp.clip(raw[..., 4].astype(jnp.float32), -6, 6)) * cell
    boxes = jnp.stack([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2], -1)
    return obj, boxes


def targets_from_boxes(cfg: DetectorConfig, boxes: jnp.ndarray,
                       valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Grid-assign ground-truth boxes (B, K, 4) xyxy + valid mask (B, K).

    Returns (obj_target (B,s,s), box_target (B,s,s,4) = [dx, dy, logw, logh]).
    Later boxes overwrite earlier ones on cell collision (rare for person-
    scale objects on a 32px grid).
    """
    side = cfg.canvas // cfg.patch
    cell = cfg.canvas / side
    B, K, _ = boxes.shape
    cx = (boxes[..., 0] + boxes[..., 2]) / 2
    cy = (boxes[..., 1] + boxes[..., 3]) / 2
    w = jnp.maximum(boxes[..., 2] - boxes[..., 0], 1.0)
    h = jnp.maximum(boxes[..., 3] - boxes[..., 1], 1.0)
    gx = jnp.clip((cx / cell).astype(jnp.int32), 0, side - 1)
    gy = jnp.clip((cy / cell).astype(jnp.int32), 0, side - 1)

    obj_t = jnp.zeros((B, side, side), jnp.float32)
    box_t = jnp.zeros((B, side, side, 4), jnp.float32)
    bidx = jnp.arange(B)[:, None].repeat(K, 1)
    vals = jnp.stack([cx / cell - gx, cy / cell - gy,
                      jnp.log(w / cell), jnp.log(h / cell)], -1)
    obj_t = obj_t.at[bidx, gy, gx].max(valid.astype(jnp.float32))
    box_t = box_t.at[bidx, gy, gx].set(
        vals * valid[..., None].astype(jnp.float32))
    return obj_t, box_t


def detection_loss(cfg: DetectorConfig, params, batch, rules):
    """batch: {canvases (B,M,N,3), boxes (B,K,4), valid (B,K)} -> scalar."""
    raw = forward(cfg, params, batch["canvases"], rules).astype(jnp.float32)
    obj_t, box_t = targets_from_boxes(cfg, batch["boxes"], batch["valid"])
    # focal-ish BCE on objectness
    obj_logit = raw[..., 0]
    p = jax.nn.sigmoid(obj_logit)
    bce = -(obj_t * jax.nn.log_sigmoid(obj_logit) +
            (1 - obj_t) * jax.nn.log_sigmoid(-obj_logit))
    focal = bce * jnp.where(obj_t > 0, (1 - p) ** 2, p ** 2)
    obj_loss = jnp.mean(focal)
    # L1 on box params at positive cells
    pred = jnp.concatenate([jax.nn.sigmoid(raw[..., 1:3]),
                            raw[..., 3:5]], -1)
    l1 = jnp.sum(jnp.abs(pred - box_t), -1) * obj_t
    box_loss = jnp.sum(l1) / jnp.maximum(jnp.sum(obj_t), 1.0)
    return obj_loss + box_loss


def serve(cfg: DetectorConfig, params, canvases, rules):
    """The serverless function body: canvases -> (obj, boxes)."""
    raw = forward(cfg, params, canvases, rules)
    return decode_boxes(cfg, raw)
