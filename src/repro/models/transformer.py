"""Decoder-only LM (dense or MoE) with scan-over-layers and remat.

Exposes:
  param_specs(cfg)                      -> ParamSpec tree
  forward(cfg, params, tokens, rules)   -> final hidden states (B,S,d)
  lm_loss(cfg, params, batch, rules)    -> scalar loss (chunked vocab xent)
  prefill(cfg, params, tokens, rules)   -> (logits_last, cache)
  decode_step(cfg, params, tokens, cache, pos, rules) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import TransformerConfig, dtype_of
from repro.models import attention as attn
from repro.models import layers, moe
from repro.param import spec
from repro.sharding import with_logical_constraint

LOSS_CHUNK = 512


# ----------------------------------------------------------------- specs ----

def _layer_specs(cfg: TransformerConfig, dtype):
    quant = getattr(cfg, "quant_weights", False)
    p = {
        "ln_attn": layers.rmsnorm_specs(cfg.d_model, dtype),
        "attn": attn.gqa_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype,
                               fused=getattr(cfg, "fused_qkv", False),
                               quant=quant),
        "ln_mlp": layers.rmsnorm_specs(cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe.moe_specs(cfg.d_model, cfg.moe, dtype, quant=quant)
    else:
        p["mlp"] = layers.swiglu_specs(cfg.d_model, cfg.d_ff, dtype,
                                       quant=quant)
    return p


def _stack_layer_specs(layer_tree, n_layers: int):
    """Prepend a stacked "layers" dimension to every leaf spec."""
    def stack(s):
        return spec((n_layers,) + s.shape, ("layers",) + s.axes, dtype=s.dtype,
                    init=s.init, scale=s.scale,
                    fan_in_axes=tuple(a + 1 for a in s.fan_in_axes))
    from repro.param import tree_map_specs
    return tree_map_specs(stack, layer_tree)


def param_specs(cfg: TransformerConfig):
    dtype = dtype_of(cfg.param_dtype)
    layer = _layer_specs(cfg, dtype)
    p = {
        "embed": layers.embed_specs(cfg.vocab, cfg.d_model, dtype),
        "layers": _stack_layer_specs(layer, cfg.n_layers) if cfg.scan_layers
        else {f"layer_{i}": _layer_specs(cfg, dtype) for i in range(cfg.n_layers)},
        "ln_f": layers.rmsnorm_specs(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_specs(
            cfg.d_model, cfg.vocab, in_axis="embed", out_axis="vocab",
            dtype=dtype, quant=getattr(cfg, "quant_weights", False))
    return p


# --------------------------------------------------------------- forward ----

def _layer_body(cfg: TransformerConfig, rules, lp, x, positions, impl):
    cdt = dtype_of(cfg.compute_dtype)
    h = layers.rmsnorm(lp["ln_attn"], x, cfg.norm_eps, cdt)
    h = attn.attention(lp["attn"], h, n_heads=cfg.n_heads,
                       n_kv_heads=cfg.n_kv_heads, rope_theta=cfg.rope_theta,
                       compute_dtype=cdt, rules=rules, positions=positions,
                       impl=impl)
    x = x + h
    h = layers.rmsnorm(lp["ln_mlp"], x, cfg.norm_eps, cdt)
    if cfg.moe is not None:
        h, aux = moe.moe_block(lp["moe"], h, cfg.moe, compute_dtype=cdt,
                               rules=rules)
    else:
        h = layers.swiglu(lp["mlp"], h, cdt)
        aux = jnp.zeros((), jnp.float32)
    return x + h, aux


def forward(cfg: TransformerConfig, params, tokens, rules, *,
            positions: Optional[jnp.ndarray] = None, impl: str = "xla"):
    """tokens: (B, S) int32 -> hidden (B, S, d)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = layers.embed_lookup(params["embed"], tokens, cdt)
    x = with_logical_constraint(x, ("batch", "seq", "embed"), rules)

    def body(lp, x):
        return _layer_body(cfg, rules, lp, x, positions, impl)
    if cfg.remat:
        policy = (None if getattr(cfg, "remat_policy", "dots") == "minimal"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)

    if cfg.scan_layers:
        def scan_fn(carry, lp):
            x, aux_tot = carry
            x, aux = body(lp, x)
            return (x, aux_tot + aux), None
        (x, aux_total), _ = jax.lax.scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            x, aux = body(params["layers"][f"layer_{i}"], x)
            aux_total = aux_total + aux

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps, cdt)
    return x, aux_total


def _logits_fn(cfg: TransformerConfig, params, cdt):
    if cfg.tie_embeddings:
        return lambda h: layers.embed_logits(params["embed"], h, cdt)
    return lambda h: layers.dense(params["lm_head"], h, cdt)


def lm_loss(cfg: TransformerConfig, params, batch, rules, *,
            aux_weight: float = 0.01, impl: str = "xla",
            unroll_loss: bool = False):
    """batch: {tokens: (B,S), labels: (B,S)} -> scalar fp32 loss."""
    cdt = dtype_of(cfg.compute_dtype)
    h, aux = forward(cfg, params, batch["tokens"], rules, impl=impl)
    nll = layers.chunked_softmax_xent(
        _logits_fn(cfg, params, cdt), h, batch["labels"], cfg.vocab,
        LOSS_CHUNK, cdt, unroll=unroll_loss)
    return nll + aux_weight * aux


# ---------------------------------------------------------------- decode ----

def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               abstract: bool = False):
    dtype = dtype_of(cfg.compute_dtype)
    quant_kv = getattr(cfg, "quant_kv", False)
    make = attn.cache_specs if abstract else attn.init_cache
    one = make(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, dtype,
               quant_kv=quant_kv)
    if cfg.scan_layers:
        def stack(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((cfg.n_layers,) + leaf.shape,
                                            leaf.dtype)
            return jnp.broadcast_to(leaf[None], (cfg.n_layers,) + leaf.shape)
        return jax.tree_util.tree_map(stack, one)
    return {f"layer_{i}": make(batch, max_seq, cfg.n_kv_heads, cfg.head_dim,
                               dtype, quant_kv=quant_kv)
            for i in range(cfg.n_layers)}


def cache_axes(cfg: TransformerConfig):
    one = {"k": attn.CACHE_AXES, "v": attn.CACHE_AXES}
    if getattr(cfg, "quant_kv", False):
        one["k_scale"] = attn.CACHE_SCALE_AXES
        one["v_scale"] = attn.CACHE_SCALE_AXES
    if cfg.scan_layers:
        return {key: ("layers",) + axes for key, axes in one.items()}
    return {f"layer_{i}": dict(one) for i in range(cfg.n_layers)}


def decode_step(cfg: TransformerConfig, params, tokens, cache, pos, rules, *,
                impl: str = "xla"):
    """tokens: (B, 1) -> (logits (B,1,V), new_cache).  pos: scalar int32."""
    cdt = dtype_of(cfg.compute_dtype)
    x = layers.embed_lookup(params["embed"], tokens, cdt)
    x = with_logical_constraint(x, ("decode_batch", None, "embed"), rules)

    def body(lp, lc, x):
        h = layers.rmsnorm(lp["ln_attn"], x, cfg.norm_eps, cdt)
        h, new_lc = attn.decode_attention(
            lp["attn"], h, lc, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, rope_theta=cfg.rope_theta,
            compute_dtype=cdt, rules=rules, impl=impl,
            cache_update=getattr(cfg, "cache_update", "auto"))
        x = x + h
        h = layers.rmsnorm(lp["ln_mlp"], x, cfg.norm_eps, cdt)
        if cfg.moe is not None:
            h, _ = moe.moe_block(lp["moe"], h, cfg.moe, compute_dtype=cdt,
                                 rules=rules)
        else:
            h = layers.swiglu(lp["mlp"], h, cdt)
        return x + h, new_lc

    if cfg.scan_layers:
        def scan_fn(x, layer_in):
            lp, lc = layer_in
            x, new_lc = body(lp, lc, x)
            return x, new_lc
        x, new_cache = jax.lax.scan(scan_fn, x, (params["layers"], cache))
    else:
        new_cache = {}
        for i in range(cfg.n_layers):
            x, new_cache[f"layer_{i}"] = body(
                params["layers"][f"layer_{i}"], cache[f"layer_{i}"], x)

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps, cdt)
    logits = _logits_fn(cfg, params, cdt)(x)
    return logits, new_cache


def prefill(cfg: TransformerConfig, params, tokens, rules, *, impl: str = "xla"):
    """Full-sequence prefill: returns last-position logits and hidden states.

    The prefill dry-run cell measures the forward pass at (B=32, S=32k);
    cache construction from prefill activations is exercised in tests with
    small configs (the compiled artifact is dominated by the forward).
    """
    cdt = dtype_of(cfg.compute_dtype)
    h, _ = forward(cfg, params, tokens, rules, impl=impl)
    logits = _logits_fn(cfg, params, cdt)(h[:, -1:, :])
    return logits, h
