"""Post-training weight quantization: fp checkpoint -> int8-resident tree.

``quantize_params(quant_specs, fp_params)`` walks the quantized ParamSpec
tree (built with ``quant_weights=True``) alongside a trained fp tree and
emits int8 weights + per-out-channel scales.  Reduction axes are derived
from the spec's logical axis names: every kernel axis whose name is absent
from the scale spec is a fan-in axis and gets max-reduced.

Used by the serving path (§Perf iteration 2.3: int8-resident decode) and
tested for numerics in tests/test_quantize.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.param import ParamSpec


def _quantize_kernel(kernel, q_spec: ParamSpec, s_spec: ParamSpec):
    k32 = jnp.asarray(kernel, jnp.float32)
    scale_names = set(a for a in s_spec.axes if a is not None)
    reduce_axes = tuple(i for i, a in enumerate(q_spec.axes)
                        if a not in scale_names)
    scale = jnp.max(jnp.abs(k32), axis=reduce_axes) / 127.0 + 1e-12
    expand = list(k32.shape)
    for i, a in enumerate(q_spec.axes):
        if a not in scale_names:
            expand[i] = 1
    q = jnp.clip(jnp.round(k32 / scale.reshape(expand)), -127, 127)
    return q.astype(jnp.int8), scale


def quantize_params(quant_specs, fp_params):
    """Map an fp param tree onto the structure of ``quant_specs``."""
    def walk(spec_node, fp_node):
        if isinstance(spec_node, ParamSpec):
            return jnp.asarray(fp_node, spec_node.dtype)
        if isinstance(spec_node, dict):
            if "q" in spec_node and "scale" in spec_node \
                    and isinstance(spec_node["q"], ParamSpec):
                q, s = _quantize_kernel(fp_node, spec_node["q"],
                                        spec_node["scale"])
                return {"q": q, "scale": s}
            if "kernel_q" in spec_node:
                q, s = _quantize_kernel(fp_node["kernel"],
                                        spec_node["kernel_q"],
                                        spec_node["kernel_scale"])
                out = {"kernel_q": q, "kernel_scale": s}
                if "bias" in spec_node:
                    out["bias"] = jnp.asarray(fp_node["bias"],
                                              spec_node["bias"].dtype)
                return out
            return {k: walk(v, fp_node[k]) for k, v in spec_node.items()}
        raise TypeError(type(spec_node))
    return walk(quant_specs, fp_params)
