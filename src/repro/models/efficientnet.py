"""EfficientNet (MBConv + SE) with compound scaling — b7: w2.0 d3.1 r600.

Convolutions use NHWC / HWIO layouts.  BatchNorm runs in sync-BN style:
batch statistics are computed with jnp.mean over the (sharded) batch axis,
so XLA inserts the cross-replica all-reduce automatically.  Running stats
are kept as parameters for the serve path.

Sharding: conv weights are replicated (66M params — DP-dominant regime,
see DESIGN.md); the classifier head shards over "model".
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.config import EfficientNetConfig, dtype_of
from repro.param import spec, tree_map_specs, count_params as _count
from repro.sharding import with_logical_constraint


def block_args(cfg: EfficientNetConfig) -> List[dict]:
    """Expand the B0 stage template with compound scaling."""
    blocks = []
    in_c = cfg.scaled_channels(cfg.stem_channels)
    for (expand, c, repeats, stride, k) in cfg.STAGES:
        out_c = cfg.scaled_channels(c)
        for i in range(cfg.scaled_repeats(repeats)):
            blocks.append(dict(
                in_c=in_c, out_c=out_c, expand=expand,
                stride=stride if i == 0 else 1, kernel=k))
            in_c = out_c
    return blocks


# ----------------------------------------------------------------- specs ----

def _conv_specs(k: int, in_c: int, out_c: int, dtype, groups: int = 1):
    return {"kernel": spec((k, k, in_c // groups, out_c),
                           (None, None, "in_channels", None), dtype=dtype,
                           fan_in_axes=(0, 1, 2))}


def _bn_specs(c: int, dtype):
    return {
        "scale": spec((c,), (None,), dtype=dtype, init="ones"),
        "bias": spec((c,), (None,), dtype=dtype, init="zeros"),
        "mean": spec((c,), (None,), dtype=jnp.float32, init="zeros"),
        "var": spec((c,), (None,), dtype=jnp.float32, init="ones"),
    }


def _block_specs(b: dict, cfg: EfficientNetConfig, dtype):
    mid = b["in_c"] * b["expand"]
    se_c = max(1, int(b["in_c"] * 0.25))
    p = {}
    if b["expand"] != 1:
        p["expand_conv"] = _conv_specs(1, b["in_c"], mid, dtype)
        p["expand_bn"] = _bn_specs(mid, dtype)
    p["dw_conv"] = {"kernel": spec((b["kernel"], b["kernel"], 1, mid),
                                   (None, None, None, None), dtype=dtype,
                                   fan_in_axes=(0, 1))}
    p["dw_bn"] = _bn_specs(mid, dtype)
    p["se_reduce"] = _conv_specs(1, mid, se_c, dtype)
    p["se_expand"] = _conv_specs(1, se_c, mid, dtype)
    p["project_conv"] = _conv_specs(1, mid, b["out_c"], dtype)
    p["project_bn"] = _bn_specs(b["out_c"], dtype)
    return p


def param_specs(cfg: EfficientNetConfig):
    dtype = dtype_of(cfg.param_dtype)
    stem_c = cfg.scaled_channels(cfg.stem_channels)
    head_c = cfg.scaled_channels(cfg.head_channels)
    blocks = block_args(cfg)
    return {
        "stem_conv": _conv_specs(3, 3, stem_c, dtype),
        "stem_bn": _bn_specs(stem_c, dtype),
        "blocks": {f"block_{i}": _block_specs(b, cfg, dtype)
                   for i, b in enumerate(blocks)},
        "head_conv": _conv_specs(1, blocks[-1]["out_c"], head_c, dtype),
        "head_bn": _bn_specs(head_c, dtype),
        "classifier": {
            "kernel": spec((head_c, cfg.n_classes), ("embed", "vocab"),
                           dtype=dtype, fan_in_axes=(0,)),
            "bias": spec((cfg.n_classes,), ("vocab",), dtype=dtype,
                         init="zeros"),
        },
    }


def count_params(cfg: EfficientNetConfig) -> int:
    return _count(param_specs(cfg))


# ------------------------------------------------------------------ ops -----

def _conv(p, x, stride: int, cdt, groups: int = 1):
    return jax.lax.conv_general_dilated(
        x.astype(cdt), p["kernel"].astype(cdt),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _bn(p, x, train: bool, cdt, eps: float = 1e-3):
    x32 = x.astype(jnp.float32)
    if train:
        mean = jnp.mean(x32, axis=(0, 1, 2))
        var = jnp.var(x32, axis=(0, 1, 2))
    else:
        mean, var = p["mean"], p["var"]
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(cdt)


def _mbconv(p, b: dict, x, train: bool, cdt):
    mid = b["in_c"] * b["expand"]
    inp = x
    if b["expand"] != 1:
        x = jax.nn.swish(_bn(p["expand_bn"], _conv(p["expand_conv"], x, 1, cdt),
                             train, cdt))
    x = jax.nn.swish(_bn(p["dw_bn"],
                         _conv(p["dw_conv"], x, b["stride"], cdt, groups=mid),
                         train, cdt))
    # squeeze-excite
    se = jnp.mean(x, axis=(1, 2), keepdims=True)
    se = jax.nn.swish(_conv(p["se_reduce"], se, 1, cdt))
    se = jax.nn.sigmoid(_conv(p["se_expand"], se, 1, cdt))
    x = x * se
    x = _bn(p["project_bn"], _conv(p["project_conv"], x, 1, cdt), train, cdt)
    if b["stride"] == 1 and b["in_c"] == b["out_c"]:
        x = x + inp
    return x


def forward(cfg: EfficientNetConfig, params, images, rules, *,
            train: bool = False):
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = images.astype(cdt)
    x = with_logical_constraint(x, ("batch", "img_h", "img_w", None), rules)
    x = jax.nn.swish(_bn(params["stem_bn"],
                         _conv(params["stem_conv"], x, 2, cdt), train, cdt))
    for i, b in enumerate(block_args(cfg)):
        x = _mbconv(params["blocks"][f"block_{i}"], b, x, train, cdt)
    x = jax.nn.swish(_bn(params["head_bn"],
                         _conv(params["head_conv"], x, 1, cdt), train, cdt))
    x = jnp.mean(x, axis=(1, 2))                       # global average pool
    logits = jnp.dot(x, params["classifier"]["kernel"].astype(cdt)) \
        + params["classifier"]["bias"].astype(cdt)
    return logits


def cls_loss(cfg: EfficientNetConfig, params, batch, rules):
    logits = forward(cfg, params, batch["images"], rules, train=True)
    lg = logits.astype(jnp.float32)
    labels = jnp.clip(batch["labels"], 0, cfg.n_classes - 1)
    return jnp.mean(jax.nn.logsumexp(lg, -1) -
                    jnp.take_along_axis(lg, labels[:, None], 1,
                                        mode="clip")[:, 0])


def serve(cfg: EfficientNetConfig, params, images, rules):
    return forward(cfg, params, images, rules, train=False)
