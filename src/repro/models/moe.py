"""Mixture-of-Experts block: GShard-style grouped top-k dispatch.

Tokens are reshaped into groups of ``group_size``; per group a capacity-
bounded one-hot dispatch tensor routes tokens to experts via einsums that
XLA SPMD partitions cleanly (experts on the "model" mesh axis = expert
parallelism, groups on the "data" axes).  Top-k routing builds the dispatch
mask with k unrolled argmax rounds (k <= 8 everywhere in the pool).

Shared experts (DeepSeekMoE) are a dense SwiGLU over all tokens, added to
the routed output.  Capacity overflow drops tokens (standard GShard
behaviour); ``capacity_factor`` and ``group_size`` are the knobs, and the
dispatch-einsum FLOP overhead is part of the §Perf iteration space.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.models import layers
from repro.param import spec
from repro.sharding import with_logical_constraint


def _espec(shape, axes, dtype, quant: bool):
    if quant:
        return {"q": spec(shape, axes, dtype=jnp.int8, init="zeros"),
                "scale": spec((shape[0], shape[2]), (axes[0], axes[2]),
                              dtype=jnp.float32, init="ones")}
    return spec(shape, axes, dtype=dtype, fan_in_axes=(1,))


def _eweight(p, compute_dtype):
    if isinstance(p, dict) and "q" in p:
        return p["q"].astype(compute_dtype) \
            * p["scale"].astype(compute_dtype)[:, None, :]
    return p.astype(compute_dtype)


def moe_specs(d_model: int, cfg: MoEConfig, dtype, quant: bool = False):
    ff = cfg.d_ff_expert or d_model * 4
    p = {
        "router": spec((d_model, cfg.n_experts), ("embed", "expert"),
                       dtype=jnp.float32, fan_in_axes=(0,)),
        "wg": _espec((cfg.n_experts, d_model, ff),
                     ("expert", "embed", "expert_mlp"), dtype, quant),
        "wu": _espec((cfg.n_experts, d_model, ff),
                     ("expert", "embed", "expert_mlp"), dtype, quant),
        "wd": _espec((cfg.n_experts, ff, d_model),
                     ("expert", "expert_mlp", "embed"), dtype, quant),
    }
    if cfg.n_shared:
        p["shared"] = layers.swiglu_specs(d_model, cfg.n_shared * ff, dtype,
                                          quant=quant)
    return p


def capacity(group_size: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(c, 1)


def _top_k_dispatch(gates: jnp.ndarray, cfg: MoEConfig, cap: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """gates: (G, S, E) softmax router probs.

    Returns (dispatch, combine, aux_loss):
      dispatch: (G, S, E, C) 0/1 routing tensor
      combine:  (G, S, E, C) gate-weighted routing tensor
      aux_loss: load-balancing loss (scalar, fp32)
    """
    G, S, E = gates.shape
    remaining = gates
    counts = jnp.zeros((G, E), jnp.float32)
    dispatch = jnp.zeros((G, S, E, cap), jnp.float32)
    gate_sum = jnp.zeros((G, S), jnp.float32)
    combine = jnp.zeros((G, S, E, cap), jnp.float32)

    for _ in range(cfg.top_k):
        idx = jnp.argmax(remaining, axis=-1)                     # (G,S)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # (G,S,E)
        gate_i = jnp.sum(remaining * onehot, axis=-1)            # (G,S)
        remaining = remaining * (1.0 - onehot)
        # position of each token within its chosen expert's buffer
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]
        counts = counts + jnp.sum(onehot, axis=1)
        pos_i = jnp.sum(pos * onehot, axis=-1)                   # (G,S)
        keep = (pos_i < cap).astype(jnp.float32)                 # capacity drop
        slot = jax.nn.one_hot(pos_i.astype(jnp.int32), cap, dtype=jnp.float32)
        d_i = onehot[..., None] * slot[:, :, None, :] * keep[..., None, None]
        dispatch = dispatch + d_i
        combine = combine + gate_i[..., None, None] * d_i
        gate_sum = gate_sum + gate_i * keep

    # normalize combine weights over the kept top-k gates
    combine = combine / jnp.maximum(gate_sum, 1e-9)[..., None, None]

    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jnp.sum(dispatch, axis=-1), axis=1)                      # (G,E) f_e
    frac_probs = jnp.mean(gates, axis=1)                         # (G,E) p_e
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return dispatch, combine, aux


def moe_block(params, x, cfg: MoEConfig, *, compute_dtype, rules):
    """x: (B, S, d) -> (out (B,S,d), aux_loss)."""
    B, S, d = x.shape
    tokens = B * S
    gs = min(cfg.group_size, tokens)
    n_groups = tokens // gs
    assert tokens % gs == 0, (tokens, gs)
    cap = capacity(gs, cfg)

    xt = x.reshape(n_groups, gs, d)
    xt = with_logical_constraint(xt, ("expert_group", None, "embed"), rules)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    dispatch, combine, aux = _top_k_dispatch(gates, cfg, cap)
    dispatch = dispatch.astype(compute_dtype)
    combine = combine.astype(compute_dtype)

    # dispatch: (G,S,E,C) x (G,S,d) -> (G,E,C,d)
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xt.astype(compute_dtype))
    expert_in = with_logical_constraint(
        expert_in, ("expert_group", "expert", "capacity", "embed"), rules)

    wg = _eweight(params["wg"], compute_dtype)
    wu = _eweight(params["wu"], compute_dtype)
    wd = _eweight(params["wd"], compute_dtype)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, wg)) \
        * jnp.einsum("gecd,edf->gecf", expert_in, wu)
    expert_out = jnp.einsum("gecf,efd->gecd", h, wd)
    expert_out = with_logical_constraint(
        expert_out, ("expert_group", "expert", "capacity", "embed"), rules)

    # combine: (G,S,E,C) x (G,E,C,d) -> (G,S,d)
    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    out = out.reshape(B, S, d)

    if cfg.n_shared:
        out = out + layers.swiglu(params["shared"], x, compute_dtype)
    return out, aux
