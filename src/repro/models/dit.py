"""Diffusion Transformer (DiT) with adaLN-zero conditioning.

Operates on a VAE latent grid (img_res/8, 4 channels) patchified with
``cfg.patch`` (DiT-*/2 => patch=2), exactly the compute shape of the paper
(arXiv:2212.09748).  No VAE is included — the framework treats latents as
inputs (generation examples use a synthetic latent prior).

train step: DDPM epsilon-prediction MSE at given timesteps.
gen step:   DDIM sampler, ``steps`` model forwards via lax.scan.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import DiTConfig, dtype_of
from repro.models import attention as attn
from repro.models import layers
from repro.param import spec, tree_map_specs
from repro.sharding import with_logical_constraint

T_MAX = 1000  # diffusion timestep range


def _layer_specs(cfg: DiTConfig, dtype):
    d = cfg.d_model
    return {
        "attn": attn.gqa_specs(d, cfg.n_heads, cfg.n_heads,
                               d // cfg.n_heads, dtype),
        "mlp": layers.gelu_mlp_specs(d, cfg.d_ff, dtype),
        # adaLN-zero: 6*d modulation from conditioning, zero-init
        "ada": layers.dense_specs(d, 6 * d, in_axis="embed", out_axis=None,
                                  dtype=dtype, bias=True, zero_init=True),
    }


def _stack(layer_tree, n_layers: int):
    def f(s):
        return spec((n_layers,) + s.shape, ("layers",) + s.axes, dtype=s.dtype,
                    init=s.init, scale=s.scale,
                    fan_in_axes=tuple(a + 1 for a in s.fan_in_axes))
    return tree_map_specs(f, layer_tree)


def param_specs(cfg: DiTConfig):
    dtype = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    patch_dim = cfg.latent_channels * cfg.patch * cfg.patch
    return {
        "patch_embed": layers.dense_specs(patch_dim, d, in_axis="patch",
                                          out_axis="embed", dtype=dtype,
                                          bias=True),
        "t_mlp1": layers.dense_specs(cfg.timestep_dim, d, in_axis=None,
                                     out_axis="embed", dtype=dtype, bias=True),
        "t_mlp2": layers.dense_specs(d, d, in_axis="embed", out_axis=None,
                                     dtype=dtype, bias=True),
        "label_embed": spec((cfg.n_classes + 1, d), ("vocab", "embed"),
                            dtype=dtype, init="embed"),  # +1 = CFG null class
        "layers": _stack(_layer_specs(cfg, dtype), cfg.n_layers)
        if cfg.scan_layers else
        {f"layer_{i}": _layer_specs(cfg, dtype) for i in range(cfg.n_layers)},
        "final_ada": layers.dense_specs(d, 2 * d, in_axis="embed",
                                        out_axis=None, dtype=dtype, bias=True,
                                        zero_init=True),
        "final_proj": layers.dense_specs(d, patch_dim, in_axis="embed",
                                         out_axis="patch", dtype=dtype,
                                         bias=True, zero_init=True),
    }


# ------------------------------------------------------------ embeddings ----

def timestep_embedding(t: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Sinusoidal timestep embedding. t: (B,) -> (B, dim) fp32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def patchify_latent(z: jnp.ndarray, patch: int) -> jnp.ndarray:
    B, H, W, C = z.shape
    h, w = H // patch, W // patch
    x = z.reshape(B, h, patch, w, patch, C).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, h * w, patch * patch * C)


def unpatchify_latent(x: jnp.ndarray, patch: int, side: int,
                      channels: int) -> jnp.ndarray:
    B = x.shape[0]
    x = x.reshape(B, side, side, patch, patch, channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, side * patch, side * patch, channels)


# ----------------------------------------------------------------- model ----

def forward(cfg: DiTConfig, params, latents, t, labels, rules, *,
            impl: str = "xla"):
    """latents: (B, Hl, Wl, C); t: (B,); labels: (B,) -> eps_hat same shape."""
    cdt = dtype_of(cfg.compute_dtype)
    B, Hl, Wl, C = latents.shape
    side = Hl // cfg.patch

    x = layers.dense(params["patch_embed"],
                     patchify_latent(latents.astype(cdt), cfg.patch), cdt)
    x = with_logical_constraint(x, ("batch", "seq", "embed"), rules)

    temb = timestep_embedding(t, cfg.timestep_dim)
    cond = layers.dense(params["t_mlp2"],
                        jax.nn.silu(layers.dense(params["t_mlp1"], temb.astype(cdt),
                                                 cdt)), cdt)
    cond = cond + jnp.take(params["label_embed"], labels, axis=0,
                           mode="clip").astype(cdt)
    cond = jax.nn.silu(cond)                                    # (B, d)

    def body(lp, x):
        mod = layers.dense(lp["ada"], cond, cdt)                # (B, 6d)
        s1, sc1, g1, s2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        h = layers.modulated_layernorm(x, s1, sc1, cfg.norm_eps, cdt)
        h = attn.encoder_attention(lp["attn"], h, n_heads=cfg.n_heads,
                                   compute_dtype=cdt, rules=rules, impl=impl)
        x = x + g1[:, None, :] * h
        h = layers.modulated_layernorm(x, s2, sc2, cfg.norm_eps, cdt)
        h = layers.gelu_mlp(lp["mlp"], h, cdt)
        return x + g2[:, None, :] * h

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda x, lp: (body(lp, x), None), x,
                            params["layers"])
    else:
        for i in range(cfg.n_layers):
            x = body(params["layers"][f"layer_{i}"], x)

    mod = layers.dense(params["final_ada"], cond, cdt)
    sf, scf = jnp.split(mod, 2, axis=-1)
    x = layers.modulated_layernorm(x, sf, scf, cfg.norm_eps, cdt)
    x = layers.dense(params["final_proj"], x, cdt)
    return unpatchify_latent(x, cfg.patch, side, cfg.latent_channels)


# -------------------------------------------------------------- schedule ----

def linear_alphas(n_steps: int = T_MAX) -> jnp.ndarray:
    betas = jnp.linspace(1e-4, 0.02, n_steps, dtype=jnp.float32)
    return jnp.cumprod(1.0 - betas)


def diffusion_loss(cfg: DiTConfig, params, batch, rules, *, impl: str = "xla"):
    """batch: {latents (B,H,W,C) clean, t (B,) int32, noise (B,H,W,C),
    labels (B,)} -> scalar MSE.  Noise/t provided as inputs so the step is
    a pure function (the data pipeline owns randomness)."""
    alphas = linear_alphas()
    a = alphas[batch["t"]][:, None, None, None]
    x0 = batch["latents"].astype(jnp.float32)
    eps = batch["noise"].astype(jnp.float32)
    xt = jnp.sqrt(a) * x0 + jnp.sqrt(1.0 - a) * eps
    eps_hat = forward(cfg, params, xt, batch["t"], batch["labels"], rules,
                      impl=impl).astype(jnp.float32)
    return jnp.mean(jnp.square(eps_hat - eps))


def ddim_sample(cfg: DiTConfig, params, noise, labels, rules, *,
                n_steps: int, impl: str = "xla"):
    """DDIM sampler: ``n_steps`` model forwards via lax.scan.

    noise: (B, Hl, Wl, C) initial gaussian latents -> denoised latents.
    """
    alphas = linear_alphas()
    ts = jnp.linspace(T_MAX - 1, 0, n_steps).astype(jnp.int32)

    def step(x, i):
        t = ts[i]
        t_prev = jnp.where(i + 1 < n_steps, ts[jnp.minimum(i + 1, n_steps - 1)], 0)
        a_t = alphas[t]
        a_p = jnp.where(i + 1 < n_steps, alphas[t_prev], 1.0)
        tb = jnp.full((x.shape[0],), t, jnp.int32)
        eps = forward(cfg, params, x, tb, labels, rules, impl=impl
                      ).astype(jnp.float32)
        x0 = (x - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
        x = jnp.sqrt(a_p) * x0 + jnp.sqrt(1.0 - a_p) * eps
        return x, None

    x, _ = jax.lax.scan(step, noise.astype(jnp.float32), jnp.arange(n_steps))
    return x
