"""Shared primitive layers: dense, norms, embeddings.

Functional style: ``*_specs`` builds ParamSpec subtrees, ``apply`` functions
take the materialized (or abstract, under tracing) param subtree.
Norm statistics always accumulate in float32 regardless of compute dtype.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.param import spec


# ---------------------------------------------------------------- dense ----

def dense_specs(d_in: int, d_out: int, *, in_axis: Optional[str],
                out_axis: Optional[str], dtype, bias: bool = False,
                init_scale: float = 1.0, zero_init: bool = False,
                quant: bool = False):
    if quant:
        # int8 weight + per-output-channel fp scale (serving residency)
        p = {
            "kernel_q": spec((d_in, d_out), (in_axis, out_axis),
                             dtype=jnp.int8, init="zeros"),
            "kernel_scale": spec((d_out,), (out_axis,), dtype=jnp.float32,
                                 init="ones"),
        }
    else:
        p = {
            "kernel": spec((d_in, d_out), (in_axis, out_axis), dtype=dtype,
                           init="zeros" if zero_init else "normal",
                           scale=init_scale, fan_in_axes=(0,)),
        }
    if bias:
        p["bias"] = spec((d_out,), (out_axis,), dtype=dtype, init="zeros")
    return p


def dense(params, x, compute_dtype):
    if "kernel_q" in params:
        w = params["kernel_q"].astype(compute_dtype) \
            * params["kernel_scale"].astype(compute_dtype)[None, :]
        y = jnp.dot(x.astype(compute_dtype), w)
    else:
        y = jnp.dot(x.astype(compute_dtype),
                    params["kernel"].astype(compute_dtype))
    if "bias" in params:
        y = y + params["bias"].astype(compute_dtype)
    return y


def quantize_dense(kernel) -> dict:
    """bf16/f32 kernel -> {kernel_q, kernel_scale} (per-out-channel)."""
    k32 = jnp.asarray(kernel, jnp.float32)
    scale = jnp.max(jnp.abs(k32), axis=0) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(k32 / scale[None, :]), -127, 127).astype(jnp.int8)
    return {"kernel_q": q, "kernel_scale": scale}


# ---------------------------------------------------------------- norms ----

def rmsnorm_specs(d: int, dtype, axis: Optional[str] = "embed"):
    return {"scale": spec((d,), (axis,), dtype=dtype, init="ones")}


def rmsnorm(params, x, eps: float, compute_dtype):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(compute_dtype)


def layernorm_specs(d: int, dtype, axis: Optional[str] = "embed",
                    elementwise: bool = True):
    if not elementwise:
        return {}
    return {
        "scale": spec((d,), (axis,), dtype=dtype, init="ones"),
        "bias": spec((d,), (axis,), dtype=dtype, init="zeros"),
    }


def layernorm(params, x, eps: float, compute_dtype):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    if "scale" in params:
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(compute_dtype)


def modulated_layernorm(x, shift, scale, eps: float, compute_dtype):
    """adaLN: parameter-free LN modulated by conditioning (DiT)."""
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale.astype(jnp.float32)[:, None, :]) \
        + shift.astype(jnp.float32)[:, None, :]
    return y.astype(compute_dtype)


# ----------------------------------------------------------- embeddings ----

def embed_specs(vocab: int, d: int, dtype):
    return {"embedding": spec((vocab, d), ("vocab", "embed"), dtype=dtype,
                              init="embed")}


def embed_lookup(params, ids, compute_dtype):
    table = params["embedding"]
    # one-hot free gather; XLA shards the gather over the vocab axis.
    # mode="clip": out-of-range ids clamp (jnp.take's default "fill"
    # poisons the batch with NaNs — wrong failure mode for serving).
    return jnp.take(table, ids, axis=0, mode="clip").astype(compute_dtype)


def embed_logits(params, x, compute_dtype):
    """Tied read-out: x @ E^T."""
    table = params["embedding"].astype(compute_dtype)
    return jnp.dot(x.astype(compute_dtype), table.T)


# ------------------------------------------------------------------ misc ----

def swiglu_specs(d: int, d_ff: int, dtype, in_axis="embed", out_axis="mlp",
                 quant: bool = False):
    return {
        "gate": dense_specs(d, d_ff, in_axis=in_axis, out_axis=out_axis,
                            dtype=dtype, quant=quant),
        "up": dense_specs(d, d_ff, in_axis=in_axis, out_axis=out_axis,
                          dtype=dtype, quant=quant),
        "down": dense_specs(d_ff, d, in_axis=out_axis, out_axis=in_axis,
                            dtype=dtype, quant=quant),
    }


def swiglu(params, x, compute_dtype):
    g = jax.nn.silu(dense(params["gate"], x, compute_dtype))
    u = dense(params["up"], x, compute_dtype)
    return dense(params["down"], g * u, compute_dtype)


def gelu_mlp_specs(d: int, d_ff: int, dtype, in_axis="embed", out_axis="mlp",
                   quant: bool = False):
    return {
        "fc1": dense_specs(d, d_ff, in_axis=in_axis, out_axis=out_axis,
                           dtype=dtype, bias=True, quant=quant),
        "fc2": dense_specs(d_ff, d, in_axis=out_axis, out_axis=in_axis,
                           dtype=dtype, bias=True, quant=quant),
    }


def gelu_mlp(params, x, compute_dtype):
    h = jax.nn.gelu(dense(params["fc1"], x, compute_dtype), approximate=True)
    return dense(params["fc2"], h, compute_dtype)


def chunked_softmax_xent(logits_fn, x, labels, vocab: int, chunk: int,
                         compute_dtype, unroll: bool = False):
    """Cross-entropy over the sequence in chunks to bound logits memory.

    ``logits_fn(h_chunk) -> (B, c, V)``; x: (B, S, d); labels: (B, S).
    Returns mean nll over all tokens (float32).  ``unroll`` replaces the
    scan with a python loop (dry-run: exact HLO flop accounting).
    """
    B, S, _ = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    assert S % chunk == 0, (S, chunk)

    def body(carry, inputs):
        xc, yc = inputs                     # (B, c, d), (B, c)
        logits = logits_fn(xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1,
                                   mode="clip")[..., 0]
        return carry + jnp.sum(logz - gold), None

    if unroll:
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            total, _ = body(total, (x[:, i * chunk:(i + 1) * chunk],
                                    labels[:, i * chunk:(i + 1) * chunk]))
        return total / (B * S)

    xs = x.reshape(B, n_chunks, chunk, -1).swapaxes(0, 1)
    ys = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ys))
    return total / (B * S)
