"""ViT / DeiT encoder classifiers.

Patch embedding is part of the model (vision pool semantics).  DeiT adds a
distillation token and a second head; at serve time the two head outputs
are averaged (deit inference rule).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ViTConfig, dtype_of
from repro.models import attention as attn
from repro.models import layers
from repro.param import spec, tree_map_specs
from repro.sharding import with_logical_constraint


def _layer_specs(cfg: ViTConfig, dtype):
    quant = getattr(cfg, "quant_weights", False)
    return {
        "ln1": layers.layernorm_specs(cfg.d_model, dtype),
        "attn": attn.gqa_specs(cfg.d_model, cfg.n_heads, cfg.n_heads,
                               cfg.d_model // cfg.n_heads, dtype,
                               fused=getattr(cfg, "fused_qkv", False),
                               quant=quant),
        "ln2": layers.layernorm_specs(cfg.d_model, dtype),
        "mlp": layers.gelu_mlp_specs(cfg.d_model, cfg.d_ff, dtype,
                                     quant=quant),
    }


def _stack(layer_tree, n_layers: int):
    def f(s):
        return spec((n_layers,) + s.shape, ("layers",) + s.axes, dtype=s.dtype,
                    init=s.init, scale=s.scale,
                    fan_in_axes=tuple(a + 1 for a in s.fan_in_axes))
    return tree_map_specs(f, layer_tree)


def param_specs(cfg: ViTConfig):
    dtype = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    patch_dim = cfg.in_channels * cfg.patch * cfg.patch
    n_extra = 1 + (1 if cfg.distill_token else 0)
    if getattr(cfg, "patch_embed", "reshape") == "conv":
        pe = {"kernel": spec((cfg.patch, cfg.patch, cfg.in_channels, d),
                             (None, None, "in_channels", "embed"),
                             dtype=dtype, fan_in_axes=(0, 1, 2)),
              "bias": spec((d,), ("embed",), dtype=dtype, init="zeros")}
    else:
        pe = layers.dense_specs(patch_dim, d, in_axis="patch",
                                out_axis="embed", dtype=dtype, bias=True)
    p = {
        "patch_embed": pe,
        "cls_token": spec((1, 1, d), (None, None, "embed"), dtype=dtype,
                          init="pos"),
        "pos_embed": spec((1, cfg.n_tokens, d), (None, "seq", "embed"),
                          dtype=dtype, init="pos"),
        "layers": _stack(_layer_specs(cfg, dtype), cfg.n_layers)
        if cfg.scan_layers else
        {f"layer_{i}": _layer_specs(cfg, dtype) for i in range(cfg.n_layers)},
        "ln_f": layers.layernorm_specs(d, dtype),
        "head": layers.dense_specs(d, cfg.n_classes, in_axis="embed",
                                   out_axis="vocab", dtype=dtype, bias=True),
    }
    if cfg.distill_token:
        p["dist_token"] = spec((1, 1, d), (None, None, "embed"), dtype=dtype,
                               init="pos")
        p["head_dist"] = layers.dense_specs(d, cfg.n_classes, in_axis="embed",
                                            out_axis="vocab", dtype=dtype,
                                            bias=True)
    return p


def patchify(images: jnp.ndarray, patch: int) -> jnp.ndarray:
    """(B, H, W, C) -> (B, h*w, patch*patch*C)."""
    B, H, W, C = images.shape
    h, w = H // patch, W // patch
    x = images.reshape(B, h, patch, w, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, h * w, patch * patch * C)


def _encoder(cfg: ViTConfig, params, x, rules, impl):
    cdt = dtype_of(cfg.compute_dtype)

    def body(lp, x):
        h = layers.layernorm(lp["ln1"], x, cfg.norm_eps, cdt)
        h = attn.encoder_attention(lp["attn"], h, n_heads=cfg.n_heads,
                                   compute_dtype=cdt, rules=rules, impl=impl)
        x = x + h
        h = layers.layernorm(lp["ln2"], x, cfg.norm_eps, cdt)
        return x + layers.gelu_mlp(lp["mlp"], h, cdt)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.scan_layers:
        def scan_fn(x, lp):
            return body(lp, x), None
        x, _ = jax.lax.scan(scan_fn, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            x = body(params["layers"][f"layer_{i}"], x)
    return layers.layernorm(params["ln_f"], x, cfg.norm_eps, cdt)


def forward(cfg: ViTConfig, params, images, rules, *, impl: str = "xla",
            img_res: Optional[int] = None):
    """images: (B, H, W, C) -> logits (B, n_classes).

    When ``img_res`` differs from ``cfg.img_res`` (cls_384 finetune cell)
    the position embedding is bilinearly resized, as in the ViT paper.
    """
    cdt = dtype_of(cfg.compute_dtype)
    B = images.shape[0]
    if getattr(cfg, "patch_embed", "reshape") == "conv":
        pe = params["patch_embed"]
        x = jax.lax.conv_general_dilated(
            images.astype(cdt), pe["kernel"].astype(cdt),
            window_strides=(cfg.patch, cfg.patch), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = x.reshape(B, -1, x.shape[-1]) + pe["bias"].astype(cdt)
    else:
        x = layers.dense(params["patch_embed"], patchify(images, cfg.patch),
                         cdt)

    n_extra = 1 + (1 if cfg.distill_token else 0)
    pos = params["pos_embed"].astype(cdt)
    n_patches = x.shape[1]
    grid_pos = pos[:, n_extra:, :]
    if n_patches != grid_pos.shape[1]:
        side_old = int(round(grid_pos.shape[1] ** 0.5))
        side_new = int(round(n_patches ** 0.5))
        g = grid_pos.reshape(1, side_old, side_old, -1)
        g = jax.image.resize(g, (1, side_new, side_new, g.shape[-1]), "bilinear")
        grid_pos = g.reshape(1, side_new * side_new, -1)
    x = x + grid_pos

    toks = [jnp.broadcast_to(params["cls_token"].astype(cdt) +
                             pos[:, :1, :], (B, 1, x.shape[-1]))]
    if cfg.distill_token:
        toks.append(jnp.broadcast_to(params["dist_token"].astype(cdt) +
                                     pos[:, 1:2, :], (B, 1, x.shape[-1])))
    x = jnp.concatenate(toks + [x], axis=1)
    x = with_logical_constraint(x, ("batch", "seq", "embed"), rules)

    x = _encoder(cfg, params, x, rules, impl)
    logits = layers.dense(params["head"], x[:, 0, :], cdt)
    if cfg.distill_token:
        logits_d = layers.dense(params["head_dist"], x[:, 1, :], cdt)
        return (logits + logits_d) / 2.0, (logits, logits_d)
    return logits, None


def cls_loss(cfg: ViTConfig, params, batch, rules, *, impl: str = "xla"):
    """batch: {images: (B,H,W,C), labels: (B,)} -> scalar fp32."""
    logits, heads = forward(cfg, params, batch["images"], rules, impl=impl)
    labels = jnp.clip(batch["labels"], 0, cfg.n_classes - 1)

    def xent(lg):
        lg = lg.astype(jnp.float32)
        return jnp.mean(jax.nn.logsumexp(lg, -1) -
                        jnp.take_along_axis(lg, labels[:, None], 1,
                                            mode="clip")[:, 0])

    if heads is not None:           # DeiT: average of cls and distill losses
        return 0.5 * (xent(heads[0]) + xent(heads[1]))
    return xent(logits)


def serve(cfg: ViTConfig, params, images, rules, *, impl: str = "xla"):
    logits, _ = forward(cfg, params, images, rules, impl=impl)
    return logits
