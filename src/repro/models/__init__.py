"""Model zoo: pure-JAX model definitions with logical-axis sharding."""
