"""Train step factory: loss -> grads -> optimizer, with optional microbatch
gradient accumulation (scan over microbatches, fp32 grad accumulator)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt


def make_train_step(loss_fn: Callable, opt_cfg: opt.OptimizerConfig,
                    accum_steps: int = 1, grad_pspecs=None):
    """loss_fn(params, batch) -> scalar.

    Returns step(params, opt_state, batch) -> (params, opt_state, metrics).
    With ``accum_steps > 1`` the leading batch axis of every array in
    ``batch`` is split into microbatches and gradients accumulated in fp32
    before one optimizer application (the standard memory/throughput knob).

    ``grad_pspecs``: optional PartitionSpec tree matching the params.
    Constraining per-microbatch grads to the (FSDP-sharded) param specs
    turns the per-microbatch grad all-reduce into a reduce-scatter and
    accumulates sharded shards — ZeRO-2 gradient partitioning
    (§Perf iteration 1.2).
    """
    grad_fn = jax.value_and_grad(loss_fn)

    def constrain(grads):
        if grad_pspecs is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_pspecs)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
            grads = constrain(grads)
        else:
            def reshape(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])
            micro = jax.tree_util.tree_map(reshape, batch)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = grad_fn(params, mb)
                grads = constrain(grads)
                grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
                return (loss_acc + loss, grads), None

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_grads), micro)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)

        params, opt_state, metrics = opt.update(opt_cfg, grads, opt_state,
                                                params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step
