"""Elastic scaling + failure handling for pod-scale training.

Real pods lose chips; the contract here is:

1. detect failure (heartbeat timeout — simulated by ``FailureInjector``),
2. drop the affected data-parallel replica rows, rebuild a smaller mesh
   (``shrink_mesh``), keeping the model axis intact,
3. restore the latest committed checkpoint with the new shardings
   (``checkpoint.restore_latest`` takes the new sharding tree),
4. rescale the global batch (tokens-per-replica kept constant) and resume.

Straggler mitigation at the step level reuses the paper's own idea: the
SLO-aware invoker's mu+3sigma slack is exactly a straggler hedge — the
serving platform additionally supports backup dispatch
(``serverless.platform.Platform(backup_after_sigma=...)``).
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.compat import shardingx


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str              # "chip" | "host" | "straggler"
    data_row: int          # which data-parallel row is affected
    slow_factor: float = 1.0


class FailureInjector:
    """Deterministic failure schedule for integration tests / drills."""

    def __init__(self, events: Sequence[FailureEvent]):
        self.events = sorted(events, key=lambda e: e.step)

    def poll(self, step: int) -> List[FailureEvent]:
        fired = [e for e in self.events if e.step == step]
        self.events = [e for e in self.events if e.step != step]
        return fired


def shrink_mesh(mesh: jax.sharding.Mesh, failed_data_rows: Sequence[int]
                ) -> jax.sharding.Mesh:
    """Rebuild the mesh without the failed data-parallel rows.

    Device array layout is (data, model) or (pod, data, model); we drop
    rows along the *data* axis so every surviving replica keeps a full
    model shard group.  Raises if no rows survive.
    """
    names = tuple(mesh.axis_names)
    data_idx = names.index("data")
    devs = np.asarray(mesh.devices)
    keep = [i for i in range(devs.shape[data_idx])
            if i not in set(failed_data_rows)]
    if not keep:
        raise RuntimeError("all data-parallel rows failed")
    devs = np.take(devs, keep, axis=data_idx)
    return shardingx.mesh_from_devices(devs, names)


def rescale_batch(global_batch: int, old_rows: int, new_rows: int) -> int:
    """Keep per-replica batch constant across a shrink (elastic batch)."""
    per_row = global_batch // old_rows
    return per_row * new_rows


@dataclasses.dataclass
class ElasticState:
    mesh: jax.sharding.Mesh
    global_batch: int
    generation: int = 0

    def on_failure(self, failed_rows: Sequence[int]) -> "ElasticState":
        names = tuple(self.mesh.axis_names)
        old_rows = np.asarray(self.mesh.devices).shape[names.index("data")]
        mesh = shrink_mesh(self.mesh, failed_rows)
        new_rows = np.asarray(mesh.devices).shape[names.index("data")]
        return ElasticState(
            mesh=mesh,
            global_batch=rescale_batch(self.global_batch, old_rows, new_rows),
            generation=self.generation + 1)
