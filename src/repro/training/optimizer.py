"""AdamW with cosine schedule, warmup, and global-norm clipping (pure JAX).

Moments are kept in float32 regardless of param dtype; the update is cast
back to the param dtype.  Optimizer state shards exactly like the params
(same PartitionSpec tree), which with the FSDP rule overlay gives fully
sharded (ZeRO-style) optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params) -> dict:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(sds, abstract_params),
        "v": jax.tree_util.tree_map(sds, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: OptimizerConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh, vh = m / b1c, v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
