"""Fault-tolerant checkpointing: atomic commit, resume-from-latest, keep-k.

Layout::

    <dir>/step_000100.tmp/     (being written)
    <dir>/step_000100/         (committed: atomic rename after manifest)
        manifest.json          {step, leaf paths, shapes, dtypes}
        leaf_00000.npy ...

On restore, arrays are ``jax.device_put`` with the target sharding, so a
checkpoint written on one mesh restores onto another (elastic re-mesh
restart path).  On real multi-host pods the .npy writes become tensorstore
shards; the commit protocol (tmpdir + fsync'd manifest + rename) is the
load-bearing part and is identical.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        stored_dtype = str(arr.dtype)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint8, np.bool_, np.int8, np.float16):
            arr = arr.astype(np.float32)   # bf16 etc: store widened
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": stored_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit

    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int):
    steps = sorted(committed_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def committed_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like_tree,
            shardings: Optional[Any] = None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the matching sharding (elastic re-mesh restore)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), "tree structure mismatch"
    out = []
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        assert tuple(arr.shape) == tuple(ref.shape), (
            f"leaf {i}: ckpt {arr.shape} vs model {ref.shape}")
        jarr = jax.numpy.asarray(arr).astype(ref.dtype)
        out.append(jax.device_put(jarr, shd) if shd is not None else jarr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(directory: str, like_tree, shardings=None):
    step = latest_step(directory)
    if step is None:
        return None, None
    return restore(directory, step, like_tree, shardings), step
