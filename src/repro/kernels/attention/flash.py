"""Pallas TPU flash attention: GQA, causal, packed-segment masking; and a
chunked-KV flash-decode kernel for the long-context serve cells.

Layout/tiling rationale (TPU v5e):
  * grid (B, H, Q_blocks, KV_blocks); KV innermost so the online-softmax
    accumulators (m, l, acc) live in VMEM scratch across the KV sweep and
    the output block is written once at the final KV step.
  * block_q x block_kv default 512x512: the two matmuls per step are
    (512, D) @ (D, 512) and (512, 512) @ (512, D) — MXU-aligned for
    D in {64, 128}; VMEM per step = q + k + v + acc + probs
    ~ 512*128*4 * 4 + 512*512*4 B ~ 2.1 MiB.
  * causal cells skip fully-masked KV blocks via a cheap early-out mask
    (the grid is still dense; Mosaic hoists the skipped compute), and the
    diagonal block applies the triangular mask.
  * GQA folds the group into the head grid axis: q head h reads kv head
    h // group via the k/v index_maps — no repeated KV in HBM.
  * segment ids (Tangram sequence packing) ride as an extra (B, S) input
    blocked along q and kv; masking is block-diagonal equality.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, qseg_ref, kseg_ref,
                  o_ref, m_scr, l_scr, acc_scr,
                  *, causal: bool, block_q: int, block_kv: int,
                  n_kv_blocks: int, sm_scale: float, use_segments: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_first = qi * block_q
    k_first = ki * block_kv

    def _step():
        q = q_ref[0, :, 0, :]                        # (block_q, D)
        k = k_ref[0, :, 0, :]                        # (block_kv, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale

        if causal:
            rows = q_first + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            cols = k_first + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        if use_segments:
            qs = qseg_ref[0, :]                      # (block_q,)
            ks = kseg_ref[0, :]                      # (block_kv,)
            s = jnp.where(qs[:, None] == ks[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # early-out: skip KV blocks strictly above the diagonal
        pl.when(k_first <= q_first + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        # fully-masked rows (possible with segments) produce l = 0
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    segment_ids: Optional[jnp.ndarray] = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Skv, Kv, D); H % Kv == 0.

    Returns the attention context (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    n_kv_blocks = skv // block_kv
    grid = (b, h, sq // block_q, n_kv_blocks)
    sm_scale = 1.0 / (d ** 0.5)

    use_segments = segment_ids is not None
    if segment_ids is None:
        segment_ids = jnp.zeros((b, sq), jnp.int32)
        kv_segment_ids = jnp.zeros((b, skv), jnp.int32)
    else:
        kv_segment_ids = segment_ids

    kernel = functools.partial(
        _flash_kernel, causal=causal, block_q=block_q, block_kv=block_kv,
        n_kv_blocks=n_kv_blocks, sm_scale=sm_scale,
        use_segments=use_segments)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_q), lambda bi, hi, qi, ki: (bi, qi)),
            pl.BlockSpec((1, block_kv), lambda bi, hi, qi, ki: (bi, ki)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, segment_ids, kv_segment_ids)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, block_kv: int, n_kv_blocks: int, sm_scale: float,
                   groups: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    k_first = ki * block_kv

    @pl.when(k_first <= pos)
    def _step():
        q = q_ref[0, 0]                              # (H, D) all heads
        k = k_ref[0, :, 0, :]                        # (block_kv, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (H, block_kv)
        cols = k_first + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_decode(q, k, v, pos, *, block_kv: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """One-token decode against a KV cache, chunked over KV blocks.

    q: (B, 1, H, D); k, v: (B, Smax, Kv, D); pos: scalar int32.
    Streams the cache HBM->VMEM in block_kv chunks (O(Smax) bytes, the
    long_500k bottleneck) and skips blocks beyond ``pos``.

    Grid is (B, KV_blocks) with all H heads of one batch element resident:
    per-step VMEM = H*D + 2*block_kv*D floats — for H=96, D=128,
    block_kv=512: ~0.6 MiB.  GQA is handled by processing each kv head's
    query group per batch step (fold below keeps one kernel for all G).
    """
    b, one, h, d = q.shape
    smax, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_kv = min(block_kv, smax)
    assert smax % block_kv == 0
    n_kv_blocks = smax // block_kv
    sm_scale = 1.0 / (d ** 0.5)

    # fold kv heads into the batch axis so each kernel instance sees one
    # kv head and its G query heads: q (B*Kv, 1, G, D), k/v (B*Kv, S, 1, D)
    qf = q.reshape(b, 1, kvh, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b * kvh, 1, g, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, smax, 1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, smax, 1, d)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    kernel = functools.partial(
        _decode_kernel, block_kv=block_kv, n_kv_blocks=n_kv_blocks,
        sm_scale=sm_scale, groups=g)

    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, n_kv_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, ki: (bi, 0, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, d), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, d), lambda bi, ki: (bi, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, ki: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, 1, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return out.reshape(b, kvh, 1, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b, 1, h, d)
