"""Pure-jnp oracle for the flash attention kernels (GQA + segments)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q, k, v, *, causal: bool,
                  segment_ids: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Skv, Kv, D) with H % Kv == 0.

    segment_ids: optional (B, S) int32 — packed-sequence block-diagonal
    masking (Tangram sequence packing): positions in different segments
    never attend to each other.  Assumes Sq == Skv when given.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    skv = k.shape[1]
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if segment_ids is not None:
        seg = (segment_ids[:, :, None] == segment_ids[:, None, :])
        scores = jnp.where(seg[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(q.dtype), v)
    return ctx.reshape(b, sq, h, d)


def decode_reference(q, k, v, pos) -> jnp.ndarray:
    """q: (B, 1, H, D); k, v: (B, Smax, Kv, D); attend to positions <= pos."""
    b, _, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    valid = (jnp.arange(k.shape[1]) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(q.dtype), v)
    return ctx.reshape(b, 1, h, d)
