"""Jit'd public entries for flash attention / flash decode."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.attention import flash as _flash
from repro.kernels.attention import ref as _ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, segment_ids=None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False, impl: str = "pallas"):
    if impl == "xla":
        return _ref.mha_reference(q, k, v, causal=causal,
                                  segment_ids=segment_ids)
    return _flash.flash_attention(q, k, v, causal=causal,
                                  segment_ids=segment_ids, block_q=block_q,
                                  block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret", "impl"))
def flash_decode(q, k, v, pos, *, block_kv: int = 512,
                 interpret: bool = False, impl: str = "pallas"):
    if impl == "xla":
        return _ref.decode_reference(q, k, v, pos)
    return _flash.flash_decode(q, k, v, pos, block_kv=block_kv,
                               interpret=interpret)
