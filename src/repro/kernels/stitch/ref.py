"""Pure-jnp oracles for the canvas stitch / unstitch kernels.

Device-side canvas assembly: patches live in padded slots
``patch_pixels (P, Hmax, Wmax, C)`` with per-placement records
``records (B, K, 6) int32 = (valid, slot, x, y, w, h)`` — B canvases, at
most K placements per canvas.  Output: ``canvases (B, M, N, C)`` with each
patch's valid (h, w) region copied to (y, x); untouched pixels are zero.
Placements are guaranteed non-overlapping by the packer (property-tested),
so blend order is irrelevant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stitch_reference(patch_pixels: jnp.ndarray, records: jnp.ndarray,
                     m: int, n: int) -> jnp.ndarray:
    p_, hmax, wmax, c = patch_pixels.shape
    b, k, _ = records.shape
    out = jnp.zeros((b, m, n, c), patch_pixels.dtype)

    rows = jnp.arange(hmax)
    cols = jnp.arange(wmax)

    for bi in range(b):
        for ki in range(k):
            valid, slot, x, y, w, h = (records[bi, ki, i] for i in range(6))
            img = jax.lax.dynamic_index_in_dim(patch_pixels, slot, axis=0,
                                               keepdims=False)
            # clamp the Hmax x Wmax window inside the canvas; shift the
            # valid-region mask by the clamp offset
            ys = jnp.clip(y, 0, m - hmax)
            xs = jnp.clip(x, 0, n - wmax)
            dy = y - ys
            dx = x - xs
            mask = ((rows[:, None] >= dy) & (rows[:, None] < dy + h)
                    & (cols[None, :] >= dx) & (cols[None, :] < dx + w)
                    & (valid > 0))
            window = jax.lax.dynamic_slice(out[bi], (ys, xs, 0),
                                           (hmax, wmax, c))
            # the patch's (h, w) region starts at its slot origin (0, 0);
            # shift it to (dy, dx) inside the window
            shifted = jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)
            blended = jnp.where(mask[..., None], shifted, window)
            out = out.at[bi].set(
                jax.lax.dynamic_update_slice(out[bi], blended, (ys, xs, 0)))
    return out


def unstitch_reference(canvases: jnp.ndarray, records: jnp.ndarray,
                       num_patches: int, hmax: int, wmax: int) -> jnp.ndarray:
    """Inverse oracle: gather each placement's (h, w) region from its
    canvas back into a zero-padded (num_patches, hmax, wmax, C) slot array.
    Invalid records leave the output untouched."""
    b, m, n, c = canvases.shape
    _, k, _ = records.shape
    out = jnp.zeros((num_patches, hmax, wmax, c), canvases.dtype)
    if num_patches == 0:
        return out

    rows = jnp.arange(hmax)
    cols = jnp.arange(wmax)

    for bi in range(b):
        for ki in range(k):
            valid, slot, x, y, w, h = (records[bi, ki, i] for i in range(6))
            ys = jnp.clip(y, 0, m - hmax)
            xs = jnp.clip(x, 0, n - wmax)
            window = jax.lax.dynamic_slice(canvases[bi], (ys, xs, 0),
                                           (hmax, wmax, c))
            shifted = jnp.roll(jnp.roll(window, -(y - ys), axis=0),
                               -(x - xs), axis=1)
            mask = ((rows[:, None] < h) & (cols[None, :] < w) & (valid > 0))
            patch = jnp.where(mask[..., None], shifted,
                              jnp.zeros_like(shifted))
            prev = jax.lax.dynamic_index_in_dim(out, slot, axis=0,
                                                keepdims=False)
            upd = jnp.where(valid > 0, patch, prev)
            out = jax.lax.dynamic_update_slice(
                out, upd[None], (slot, 0, 0, 0))
    return out


def stitch_embed_reference(patch_pixels: jnp.ndarray, records: jnp.ndarray,
                           kernel: jnp.ndarray, bias: jnp.ndarray,
                           m: int, n: int, patch: int) -> jnp.ndarray:
    """Oracle for the fused stitch->patch-embed kernel: stitch, patchify
    (same layout as ``vit.patchify``), project.  Returns (B, seq, d)."""
    canvases = stitch_reference(patch_pixels, records, m, n)
    b, _, _, c = canvases.shape
    side_m, side_n = m // patch, n // patch
    x = canvases.reshape(b, side_m, patch, side_n, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, side_m * side_n, patch * patch * c)
    y = jnp.dot(x.astype(kernel.dtype), kernel,
                preferred_element_type=jnp.float32)
    return (y + bias.astype(jnp.float32)).astype(kernel.dtype)


def unstitch_decode_reference(raw: jnp.ndarray, records: jnp.ndarray,
                              patch: int, num_patches: int) -> jnp.ndarray:
    """Oracle for the fused decode+gather kernel.

    raw: (B, side_m, side_n, 5) raw head outputs.  Decodes objectness and
    boxes per grid cell (``detector.decode_boxes`` math with cell size =
    ``patch``), assigns each cell to the placement containing its decoded
    center, and scatters (obj, box clipped to the placement in
    placement-local xyxy pixels) to the placement's slot grid; non-hit
    cells are zero.  Returns (num_patches, side_m, side_n, 5) float32.
    """
    b, side_m, side_n, _ = raw.shape
    _, k, _ = records.shape
    out = jnp.zeros((num_patches, side_m, side_n, 5), jnp.float32)
    if num_patches == 0:
        return out

    cell = float(patch)
    gy, gx = jnp.meshgrid(jnp.arange(side_m), jnp.arange(side_n),
                          indexing="ij")
    for bi in range(b):
        r = raw[bi].astype(jnp.float32)
        obj = jax.nn.sigmoid(r[..., 0])
        cx = (gx + jax.nn.sigmoid(r[..., 1])) * cell
        cy = (gy + jax.nn.sigmoid(r[..., 2])) * cell
        bw = jnp.exp(jnp.clip(r[..., 3], -6, 6)) * cell
        bh = jnp.exp(jnp.clip(r[..., 4], -6, 6)) * cell
        for ki in range(k):
            valid, slot, x, y, w, h = (records[bi, ki, i] for i in range(6))
            x0, y0 = x.astype(jnp.float32), y.astype(jnp.float32)
            wf, hf = w.astype(jnp.float32), h.astype(jnp.float32)
            hit = ((valid > 0)
                   & (cx >= x0) & (cx < x0 + wf)
                   & (cy >= y0) & (cy < y0 + hf))
            dec = jnp.stack([
                obj,
                jnp.clip(cx - bw / 2, x0, x0 + wf) - x0,
                jnp.clip(cy - bh / 2, y0, y0 + hf) - y0,
                jnp.clip(cx + bw / 2, x0, x0 + wf) - x0,
                jnp.clip(cy + bh / 2, y0, y0 + hf) - y0,
            ], axis=-1)
            val = jnp.where(hit[..., None], dec, jnp.zeros_like(dec))
            prev = jax.lax.dynamic_index_in_dim(out, slot, axis=0,
                                                keepdims=False)
            upd = jnp.where(valid > 0, val, prev)
            out = jax.lax.dynamic_update_slice(
                out, upd[None], (slot, 0, 0, 0))
    return out
