"""Pure-jnp oracles for the canvas stitch / unstitch kernels.

Device-side canvas assembly: patches live in padded slots
``patch_pixels (P, Hmax, Wmax, C)`` with per-placement records
``records (B, K, 6) int32 = (valid, slot, x, y, w, h)`` — B canvases, at
most K placements per canvas.  Output: ``canvases (B, M, N, C)`` with each
patch's valid (h, w) region copied to (y, x); untouched pixels are zero.
Placements are guaranteed non-overlapping by the packer (property-tested),
so blend order is irrelevant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stitch_reference(patch_pixels: jnp.ndarray, records: jnp.ndarray,
                     m: int, n: int) -> jnp.ndarray:
    p_, hmax, wmax, c = patch_pixels.shape
    b, k, _ = records.shape
    out = jnp.zeros((b, m, n, c), patch_pixels.dtype)

    rows = jnp.arange(hmax)
    cols = jnp.arange(wmax)

    for bi in range(b):
        for ki in range(k):
            valid, slot, x, y, w, h = (records[bi, ki, i] for i in range(6))
            img = jax.lax.dynamic_index_in_dim(patch_pixels, slot, axis=0,
                                               keepdims=False)
            # clamp the Hmax x Wmax window inside the canvas; shift the
            # valid-region mask by the clamp offset
            ys = jnp.clip(y, 0, m - hmax)
            xs = jnp.clip(x, 0, n - wmax)
            dy = y - ys
            dx = x - xs
            mask = ((rows[:, None] >= dy) & (rows[:, None] < dy + h)
                    & (cols[None, :] >= dx) & (cols[None, :] < dx + w)
                    & (valid > 0))
            window = jax.lax.dynamic_slice(out[bi], (ys, xs, 0),
                                           (hmax, wmax, c))
            # the patch's (h, w) region starts at its slot origin (0, 0);
            # shift it to (dy, dx) inside the window
            shifted = jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)
            blended = jnp.where(mask[..., None], shifted, window)
            out = out.at[bi].set(
                jax.lax.dynamic_update_slice(out[bi], blended, (ys, xs, 0)))
    return out


def unstitch_reference(canvases: jnp.ndarray, records: jnp.ndarray,
                       num_patches: int, hmax: int, wmax: int) -> jnp.ndarray:
    """Inverse oracle: gather each placement's (h, w) region from its
    canvas back into a zero-padded (num_patches, hmax, wmax, C) slot array.
    Invalid records leave the output untouched."""
    b, m, n, c = canvases.shape
    _, k, _ = records.shape
    out = jnp.zeros((num_patches, hmax, wmax, c), canvases.dtype)
    if num_patches == 0:
        return out

    rows = jnp.arange(hmax)
    cols = jnp.arange(wmax)

    for bi in range(b):
        for ki in range(k):
            valid, slot, x, y, w, h = (records[bi, ki, i] for i in range(6))
            ys = jnp.clip(y, 0, m - hmax)
            xs = jnp.clip(x, 0, n - wmax)
            window = jax.lax.dynamic_slice(canvases[bi], (ys, xs, 0),
                                           (hmax, wmax, c))
            shifted = jnp.roll(jnp.roll(window, -(y - ys), axis=0),
                               -(x - xs), axis=1)
            mask = ((rows[:, None] < h) & (cols[None, :] < w) & (valid > 0))
            patch = jnp.where(mask[..., None], shifted,
                              jnp.zeros_like(shifted))
            prev = jax.lax.dynamic_index_in_dim(out, slot, axis=0,
                                                keepdims=False)
            upd = jnp.where(valid > 0, patch, prev)
            out = jax.lax.dynamic_update_slice(
                out, upd[None], (slot, 0, 0, 0))
    return out
