"""Jit'd public entries for canvas stitch/unstitch + host-side packing.

The device side is batched end-to-end: ``stitch_canvases`` assembles a
whole multi-canvas batch in one call, ``unstitch_patches`` gathers every
placement back out, and ``route_detections`` maps canvas-space detector
outputs to per-frame boxes via the same :class:`BatchPlan` records.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.partitioning import Patch
from repro.core.stitching import BatchPlan
from repro.kernels.stitch.fused_embed import (stitch_embed_pallas,
                                              unstitch_decode_pallas)
from repro.kernels.stitch.ref import (stitch_embed_reference,
                                      stitch_reference,
                                      unstitch_decode_reference,
                                      unstitch_reference)
from repro.kernels.stitch.stitch import stitch_pallas, unstitch_pallas


@functools.partial(jax.jit, static_argnames=("m", "n", "impl"))
def stitch_canvases(patch_pixels, records, m: int, n: int,
                    impl: str = "xla"):
    """Assemble a batch of canvases from padded patch slots.

    impl: "xla" (reference), "pallas" (TPU kernel),
          "pallas_interpret" (kernel body on CPU, for tests).
    """
    if impl == "xla":
        return stitch_reference(patch_pixels, records, m, n)
    return stitch_pallas(patch_pixels, records, m, n,
                         interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit,
                   static_argnames=("num_patches", "hmax", "wmax", "impl"))
def unstitch_patches(canvases, records, num_patches: int, hmax: int,
                     wmax: int, impl: str = "xla"):
    """Inverse of :func:`stitch_canvases`: canvases -> padded patch slots."""
    if impl == "xla":
        return unstitch_reference(canvases, records, num_patches, hmax, wmax)
    return unstitch_pallas(canvases, records, num_patches, hmax, wmax,
                           interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit,
                   static_argnames=("m", "n", "patch", "block_rows", "impl"))
def stitch_embed(patch_pixels, records, kernel, bias, m: int, n: int,
                 patch: int, block_rows: int = None, impl: str = "xla"):
    """Fused stitch -> patchify -> patch-embed: slots to (B, seq, d)
    tokens without materializing the canvas batch in HBM.

    impl: "xla" (reference), "pallas" (TPU kernel),
          "pallas_interpret" (kernel body on CPU, for tests).
    """
    if impl == "xla":
        return stitch_embed_reference(patch_pixels, records, kernel, bias,
                                      m, n, patch)
    return stitch_embed_pallas(patch_pixels, records, kernel, bias, m, n,
                               patch, block_rows=block_rows,
                               interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit,
                   static_argnames=("patch", "num_patches", "impl"))
def unstitch_decode(raw, records, patch: int, num_patches: int,
                    impl: str = "xla"):
    """Fused head decode + placement gather: raw (B, s, s, 5) head outputs
    to per-slot (num_patches, s, s, 5) decoded grids, no host round-trip
    through canvas-space (obj, boxes)."""
    if impl == "xla":
        return unstitch_decode_reference(raw, records, patch, num_patches)
    return unstitch_decode_pallas(raw, records, patch, num_patches,
                                  interpret=(impl == "pallas_interpret"))


def pack_plan_host(frame_pixels: Sequence[np.ndarray],
                   plan: BatchPlan) -> np.ndarray:
    """Host prep: copy patch crops into the plan's padded slot array.

    frame_pixels[i] is the (h, w, C) crop for queue patch i.  Returns
    patch_pixels (slot_capacity, hmax, wmax, C) float32, zero-padded —
    the pow2-bucketed capacity keeps jit shapes stable across invocations.
    """
    c = frame_pixels[0].shape[-1] if frame_pixels else 3
    slots = np.zeros((plan.slot_capacity, plan.hmax, plan.wmax, c),
                     np.float32)
    for i, px in enumerate(frame_pixels):
        h, w = px.shape[:2]
        assert h <= plan.hmax and w <= plan.wmax, (h, w, plan.hmax, plan.wmax)
        slots[i, :h, :w] = px
    return slots


def route_detections(plan: BatchPlan, patches: Sequence[Patch],
                     obj: np.ndarray, boxes: np.ndarray,
                     obj_threshold: float = 0.5
                     ) -> Dict[int, List[Tuple[float, Tuple[float, ...]]]]:
    """Route canvas-space detector outputs back to their source frames.

    obj: (B, s, s) objectness, boxes: (B, s, s, 4) xyxy in canvas pixels.
    A detection belongs to the placement whose rectangle contains its
    decoded box center (cell centers would drop detections in placements
    narrower than one detector cell); its box is clipped to the placement
    and translated from canvas space to the patch's frame coordinates.
    Returns {frame_id: [(score, box_xyxy), ...]}.
    """
    obj = np.asarray(obj, np.float32)
    boxes = np.asarray(boxes, np.float32)
    b = obj.shape[0]
    bcx = (boxes[..., 0] + boxes[..., 2]) / 2     # (B, s, s) box centers
    bcy = (boxes[..., 1] + boxes[..., 3]) / 2

    out: Dict[int, List[Tuple[float, Tuple[float, ...]]]] = {}
    for bi, patch_idx, x, y, w, h in plan.placements():
        if bi >= b:
            continue
        patch = patches[patch_idx]
        hit = ((obj[bi] >= obj_threshold)
               & (bcx[bi] >= x) & (bcx[bi] < x + w)
               & (bcy[bi] >= y) & (bcy[bi] < y + h))
        if not hit.any():
            continue
        dx = patch.x0 - x
        dy = patch.y0 - y
        dests = out.setdefault(patch.frame_id, [])
        for score, bx in zip(obj[bi][hit], boxes[bi][hit]):
            # clip to the placement rect: pixels past it belong to a
            # neighboring placement (possibly another frame entirely)
            x0 = min(max(float(bx[0]), x), x + w)
            y0 = min(max(float(bx[1]), y), y + h)
            x1 = min(max(float(bx[2]), x), x + w)
            y1 = min(max(float(bx[3]), y), y + h)
            dests.append((float(score),
                          (x0 + dx, y0 + dy, x1 + dx, y1 + dy)))
    return out


def route_fused(plan: BatchPlan, patches: Sequence[Patch],
                fused: np.ndarray, obj_threshold: float = 0.5
                ) -> Dict[int, List[Tuple[float, Tuple[float, ...]]]]:
    """Route :func:`unstitch_decode` outputs back to their source frames.

    fused: (num_patches, s, s, 5) per-slot decoded grids.  The kernel
    already did the per-placement assignment, clipping, and translation
    to placement-local pixels, so routing reduces to thresholding each
    slot's grid and adding the patch's frame origin.  Emits detections in
    the same per-frame order as :func:`route_detections`.
    """
    fused = np.asarray(fused, np.float32)
    out: Dict[int, List[Tuple[float, Tuple[float, ...]]]] = {}
    for _, patch_idx, x, y, w, h in plan.placements():
        if patch_idx >= fused.shape[0]:
            continue
        grid = fused[patch_idx]
        hit = grid[..., 0] >= obj_threshold
        if not hit.any():
            continue
        patch = patches[patch_idx]
        dx = float(patch.x0)
        dy = float(patch.y0)
        dests = out.setdefault(patch.frame_id, [])
        for row in grid[hit]:
            dests.append((float(row[0]),
                          (float(row[1]) + dx, float(row[2]) + dy,
                           float(row[3]) + dx, float(row[4]) + dy)))
    return out
