"""Jit'd public entry for canvas stitching + host-side record packing."""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioning import Patch
from repro.core.stitching import Canvas
from repro.kernels.stitch.ref import stitch_reference
from repro.kernels.stitch.stitch import stitch_pallas


@functools.partial(jax.jit, static_argnames=("m", "n", "impl"))
def stitch_canvases(patch_pixels, records, m: int, n: int,
                    impl: str = "xla"):
    """Assemble canvases from padded patch slots.

    impl: "xla" (reference), "pallas" (TPU kernel),
          "pallas_interpret" (kernel body on CPU, for tests).
    """
    if impl == "xla":
        return stitch_reference(patch_pixels, records, m, n)
    return stitch_pallas(patch_pixels, records, m, n,
                         interpret=(impl == "pallas_interpret"))


def pack_host(frame_pixels: Sequence[np.ndarray],
              patches: Sequence[Patch], canvases: Sequence[Canvas],
              hmax: int, wmax: int, max_per_canvas: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Host prep: patch crops -> padded slots + placement records.

    frame_pixels[i] is the (h, w, C) crop for patches[i].  Returns
    (patch_pixels (P, hmax, wmax, C), records (B, K, 6) int32).
    """
    c = frame_pixels[0].shape[-1] if frame_pixels else 3
    p = max(len(patches), 1)
    slots = np.zeros((p, hmax, wmax, c), np.float32)
    for i, px in enumerate(frame_pixels):
        h, w = px.shape[:2]
        assert h <= hmax and w <= wmax, (h, w, hmax, wmax)
        slots[i, :h, :w] = px
    records = np.zeros((max(len(canvases), 1), max_per_canvas, 6), np.int32)
    for bi, canvas in enumerate(canvases):
        assert len(canvas.placements) <= max_per_canvas, "raise K"
        for ki, pl_ in enumerate(canvas.placements):
            records[bi, ki] = (1, pl_.patch_idx, pl_.x, pl_.y, pl_.w, pl_.h)
    return slots, records
