"""Pallas TPU kernel: scatter packed patches into canvases.

TPU adaptation of Tangram's host-side cv2 canvas assembly (DESIGN.md §2):
instead of compositing on the host and DMA'ing finished canvases, the
function instance DMAs compact patch slots HBM->VMEM and assembles the
canvas entirely in VMEM, one pass, no host round-trip.

Grid: (B canvases, K placement slots).  The output BlockSpec maps every k
step of a canvas to the same (M, N, C) block, so the canvas stays resident
in VMEM across its K placement steps (accumulation pattern); the patch
input streams one (Hmax, Wmax, C) slot per step.  Records ride in SMEM via
scalar prefetch and drive the dynamic in-VMEM stores.

VMEM budget (defaults): canvas 1024x1024x3 bf16 = 6.0 MiB + one patch slot
512x512x3 bf16 = 1.5 MiB << 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stitch_kernel(records_ref,          # SMEM (B, K, 6) int32
                   patch_ref,            # VMEM (1, Hmax, Wmax, C)
                   out_ref,              # VMEM (1, M, N, C)
                   *, m: int, n: int, hmax: int, wmax: int):
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = records_ref[b, k, 0]
    slot_x = records_ref[b, k, 2]
    slot_y = records_ref[b, k, 3]
    w = records_ref[b, k, 4]
    h = records_ref[b, k, 5]

    @pl.when(valid > 0)
    def _place():
        img = patch_ref[0]                            # (Hmax, Wmax, C)
        ys = jnp.clip(slot_y, 0, m - hmax)
        xs = jnp.clip(slot_x, 0, n - wmax)
        dy = slot_y - ys
        dx = slot_x - xs
        rows = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 1)
        mask = ((rows >= dy) & (rows < dy + h)
                & (cols >= dx) & (cols < dx + w))
        shifted = jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)
        window = pl.load(out_ref, (0, pl.dslice(ys, hmax),
                                   pl.dslice(xs, wmax), slice(None)))
        blended = jnp.where(mask[..., None], shifted, window)
        pl.store(out_ref, (0, pl.dslice(ys, hmax), pl.dslice(xs, wmax),
                           slice(None)), blended)


def stitch_pallas(patch_pixels: jnp.ndarray, records: jnp.ndarray,
                  m: int, n: int, *, interpret: bool = False) -> jnp.ndarray:
    """patch_pixels: (P, Hmax, Wmax, C); records: (B, K, 6) int32
    (valid, slot, x, y, w, h) -> canvases (B, M, N, C)."""
    p_, hmax, wmax, c = patch_pixels.shape
    b, k, _ = records.shape
    assert hmax <= m and wmax <= n, "patch slot larger than canvas"

    kernel = functools.partial(_stitch_kernel, m=m, n=n, hmax=hmax, wmax=wmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            # one patch slot per (b, k) step, selected by the record's slot id
            pl.BlockSpec((1, hmax, wmax, c),
                         lambda bi, ki, recs: (recs[bi, ki, 1], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n, c),
                               lambda bi, ki, recs: (bi, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, m, n, c), patch_pixels.dtype),
        interpret=interpret,
    )(records, patch_pixels)
