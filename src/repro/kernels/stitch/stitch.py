"""Pallas TPU kernels: batched canvas stitch (scatter) and unstitch (gather).

TPU adaptation of Tangram's host-side cv2 canvas assembly (DESIGN.md §2):
instead of compositing on the host and DMA'ing finished canvases, the
function instance DMAs compact patch slots HBM->VMEM and assembles a whole
*batch* of canvases in one kernel launch, no host round-trip.  The inverse
kernel gathers each placement's pixels back out of the canvases so
per-patch detector outputs can be routed to their source frames.

Grid: (B canvases, K placement slots) — the leading grid dimension batches
over canvases, so one launch stitches an entire multi-canvas packing plan.
The canvas BlockSpec maps every k step of a canvas to the same (M, N, C)
block, so the canvas stays resident in VMEM across its K placement steps
(accumulation pattern); the patch input streams one (Hmax, Wmax, C) slot
per step, selected by the record's slot id via scalar prefetch.  Records
ride in SMEM and drive the dynamic in-VMEM loads/stores (``pl.ds`` — never
raw integer indices, which the state-discharge pass rejects).

Unstitch inverts the mapping: the canvas block is the streamed input and
the patch slot is the output block, scattered to ``records[b, k, 1]``.
Invalid records are parked on a dummy slot appended past the real patches
so they can never clobber live output; the dummy is sliced off on return.

VMEM budget (defaults): canvas 1024x1024x3 bf16 = 6.0 MiB + one patch slot
512x512x3 bf16 = 1.5 MiB << 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stitch_kernel(records_ref,          # SMEM (B, K, 6) int32
                   patch_ref,            # VMEM (1, Hmax, Wmax, C)
                   out_ref,              # VMEM (1, M, N, C)
                   *, m: int, n: int, hmax: int, wmax: int):
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = records_ref[b, k, 0]
    slot_x = records_ref[b, k, 2]
    slot_y = records_ref[b, k, 3]
    w = records_ref[b, k, 4]
    h = records_ref[b, k, 5]

    @pl.when(valid > 0)
    def _place():
        img = patch_ref[0]                            # (Hmax, Wmax, C)
        # clamp the Hmax x Wmax window inside the canvas; shift the patch
        # by the clamp offset so its (h, w) region still lands at (y, x)
        ys = jnp.clip(slot_y, 0, m - hmax)
        xs = jnp.clip(slot_x, 0, n - wmax)
        dy = slot_y - ys
        dx = slot_x - xs
        rows = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 1)
        mask = ((rows >= dy) & (rows < dy + h)
                & (cols >= dx) & (cols < dx + w))
        shifted = jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)
        window = out_ref[0, pl.ds(ys, hmax), pl.ds(xs, wmax), :]
        out_ref[0, pl.ds(ys, hmax), pl.ds(xs, wmax), :] = (
            jnp.where(mask[..., None], shifted, window))


def stitch_pallas(patch_pixels: jnp.ndarray, records: jnp.ndarray,
                  m: int, n: int, *, interpret: bool = False) -> jnp.ndarray:
    """patch_pixels: (P, Hmax, Wmax, C); records: (B, K, 6) int32
    (valid, slot, x, y, w, h) -> canvases (B, M, N, C)."""
    p_, hmax, wmax, c = patch_pixels.shape
    b, k, _ = records.shape
    assert hmax <= m and wmax <= n, "patch slot larger than canvas"
    if b == 0 or k == 0 or p_ == 0:
        # empty packing: a zero canvas batch, no degenerate kernel launch
        return jnp.zeros((b, m, n, c), patch_pixels.dtype)

    kernel = functools.partial(_stitch_kernel, m=m, n=n, hmax=hmax, wmax=wmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            # one patch slot per (b, k) step, selected by the record's slot id
            pl.BlockSpec((1, hmax, wmax, c),
                         lambda bi, ki, recs: (recs[bi, ki, 1], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n, c),
                               lambda bi, ki, recs: (bi, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, m, n, c), patch_pixels.dtype),
        interpret=interpret,
    )(records, patch_pixels)


def _unstitch_kernel(records_ref,        # SMEM (B, K, 6) int32
                     canvas_ref,         # VMEM (1, M, N, C)
                     out_ref,            # VMEM (1, Hmax, Wmax, C)
                     *, m: int, n: int, hmax: int, wmax: int):
    b = pl.program_id(0)
    k = pl.program_id(1)

    valid = records_ref[b, k, 0]
    slot_x = records_ref[b, k, 2]
    slot_y = records_ref[b, k, 3]
    w = records_ref[b, k, 4]
    h = records_ref[b, k, 5]

    ys = jnp.clip(slot_y, 0, m - hmax)
    xs = jnp.clip(slot_x, 0, n - wmax)
    dy = slot_y - ys
    dx = slot_x - xs
    window = canvas_ref[0, pl.ds(ys, hmax), pl.ds(xs, wmax), :]
    # the placement starts at (dy, dx) inside the clamped window; shift it
    # back to the slot origin and zero everything outside the (h, w) region
    shifted = jnp.roll(jnp.roll(window, -dy, axis=0), -dx, axis=1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 1)
    mask = (rows < h) & (cols < w) & (valid > 0)
    out_ref[0] = jnp.where(mask[..., None], shifted,
                           jnp.zeros_like(shifted))


def unstitch_pallas(canvases: jnp.ndarray, records: jnp.ndarray,
                    num_patches: int, hmax: int, wmax: int,
                    *, interpret: bool = False) -> jnp.ndarray:
    """Inverse of :func:`stitch_pallas`: gather each placement back out.

    canvases: (B, M, N, C); records: (B, K, 6) int32 as in stitch.
    Returns patch slots (num_patches, hmax, wmax, C) with each slot's
    (h, w) region copied from its placement and the padding zeroed.
    Slots not referenced by any valid record are undefined — the packer
    places every queued patch exactly once, so this never happens for
    real plans.
    """
    b, m, n, c = canvases.shape
    _, k, _ = records.shape
    assert hmax <= m and wmax <= n, "patch slot larger than canvas"
    if num_patches == 0 or b == 0 or k == 0:
        return jnp.zeros((num_patches, hmax, wmax, c), canvases.dtype)

    kernel = functools.partial(_unstitch_kernel, m=m, n=n,
                               hmax=hmax, wmax=wmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, m, n, c),
                         lambda bi, ki, recs: (bi, 0, 0, 0)),
        ],
        # scatter each placement to its slot; invalid records park on the
        # dummy slot at index num_patches so they cannot clobber live data
        out_specs=pl.BlockSpec(
            (1, hmax, wmax, c),
            lambda bi, ki, recs: (jnp.where(recs[bi, ki, 0] > 0,
                                            recs[bi, ki, 1], num_patches),
                                  0, 0, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_patches + 1, hmax, wmax, c),
                                       canvases.dtype),
        interpret=interpret,
    )(records, canvases)
    return out[:num_patches]
