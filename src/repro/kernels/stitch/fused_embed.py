"""Fused Pallas TPU kernels for the device hot path (ROADMAP item 3).

Two fusions kill the HBM round-trips that bracket the detector:

``stitch_embed_pallas`` — stitch -> patchify -> patch-embed in one launch.
Each canvas is assembled in a VMEM scratch buffer while the patch-slot
stream is double-buffered HBM->VMEM with ``pltpu.make_async_copy`` (two
DMA buffers + two semaphores; slot k+1 is in flight while slot k is
composited).  The assembled canvas never leaves VMEM: it is patchified in
row chunks and multiplied by the patch-embed projection in place, so the
kernel emits the (B, seq, d_model) token batch directly and the
(B, M, N, C) canvas batch never materializes in HBM.

``unstitch_decode_pallas`` — head decode + placement gather in one launch.
The detector's raw (B, side, side, 5) head outputs are decoded in-kernel
(sigmoid objectness, cell-relative centers, exp box sizes — the same math
as ``detector.decode_boxes``) and each placement's hits are scattered
straight to its patch slot.  A decoded center always lies inside its own
grid cell (both offsets are sigmoids), so masking on center-in-placement
over the full grid is exact and the canvas-space (obj, boxes) tensors are
never materialized or round-tripped through the host.

Boxes are stored clipped to the placement rectangle and translated to
placement-local pixels; ``ops.route_fused`` only adds each patch's frame
origin.  Invalid records park on the dummy slot past the real patches,
exactly like ``unstitch_pallas``.

The K placement steps are unrolled in Python (K is the plan's pow2-
bucketed slots-per-canvas, small and static), which keeps the "prefetch
slot k+1" control flow out of traced conditionals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: default patch-rows per embed matmul chunk (overridable per-call; the
#: hillclimb cell "kernel_blocks" searches this)
DEFAULT_BLOCK_ROWS = 4


def _stitch_embed_kernel(records_ref,        # SMEM (B, K, 6) int32
                         slots_hbm,          # ANY  (P, Hmax, Wmax, C)
                         wk_ref,             # VMEM (patch*patch*C, d)
                         bias_ref,           # VMEM (1, d)
                         out_ref,            # VMEM (1, seq, d)
                         *, m: int, n: int, patch: int, k_steps: int,
                         hmax: int, wmax: int, c: int, block_rows: int,
                         slot_dtype):
    b = pl.program_id(0)

    def scoped(canvas, scratch, sem):
        def copy(k, buf):
            return pltpu.make_async_copy(
                slots_hbm.at[pl.ds(records_ref[b, k, 1], 1)],
                scratch.at[buf], sem.at[buf])

        canvas[...] = jnp.zeros_like(canvas)
        copy(0, 0).start()
        for k in range(k_steps):
            buf = k % 2
            if k + 1 < k_steps:
                copy(k + 1, (k + 1) % 2).start()
            copy(k, buf).wait()

            valid = records_ref[b, k, 0]
            slot_x = records_ref[b, k, 2]
            slot_y = records_ref[b, k, 3]
            w = records_ref[b, k, 4]
            h = records_ref[b, k, 5]
            img = scratch[buf, 0]                     # (Hmax, Wmax, C)
            # clamp+roll placement, same as _stitch_kernel; the store is
            # unconditional with validity folded into the mask so the
            # unrolled loop carries no traced control flow
            ys = jnp.clip(slot_y, 0, m - hmax)
            xs = jnp.clip(slot_x, 0, n - wmax)
            dy = slot_y - ys
            dx = slot_x - xs
            rows = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (hmax, wmax), 1)
            mask = ((rows >= dy) & (rows < dy + h)
                    & (cols >= dx) & (cols < dx + w) & (valid > 0))
            shifted = jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)
            window = canvas[pl.ds(ys, hmax), pl.ds(xs, wmax), :]
            canvas[pl.ds(ys, hmax), pl.ds(xs, wmax), :] = (
                jnp.where(mask[..., None], shifted, window))

        # embed phase: patchify the resident canvas in row chunks and
        # project each chunk on the MXU (same layout as vit.patchify)
        side_m, side_n = m // patch, n // patch
        for r0 in range(0, side_m, block_rows):
            br = min(block_rows, side_m - r0)
            px = canvas[pl.ds(r0 * patch, br * patch), :, :]
            x = px.reshape(br, patch, side_n, patch, c)
            x = x.transpose(0, 2, 1, 3, 4).reshape(br * side_n,
                                                   patch * patch * c)
            y = jnp.dot(x.astype(wk_ref.dtype), wk_ref[...],
                        preferred_element_type=jnp.float32)
            y = y + bias_ref[0].astype(jnp.float32)
            out_ref[0, pl.ds(r0 * side_n, br * side_n), :] = (
                y.astype(out_ref.dtype))

    pl.run_scoped(
        scoped,
        canvas=pltpu.VMEM((m, n, c), slot_dtype),
        scratch=pltpu.VMEM((2, 1, hmax, wmax, c), slot_dtype),
        sem=pltpu.SemaphoreType.DMA((2,)))


def stitch_embed_pallas(patch_pixels: jnp.ndarray, records: jnp.ndarray,
                        kernel: jnp.ndarray, bias: jnp.ndarray,
                        m: int, n: int, patch: int,
                        *, block_rows: int | None = None,
                        interpret: bool = False) -> jnp.ndarray:
    """Fused stitch -> patchify -> patch-embed.

    patch_pixels: (P, Hmax, Wmax, C); records: (B, K, 6) int32
    (valid, slot, x, y, w, h); kernel: (patch*patch*C, d); bias: (d,).
    Returns embedded tokens (B, seq, d) with seq = (m//patch)*(n//patch),
    numerically equivalent to
    ``dense(patch_embed, patchify(stitch(...), patch))``.
    """
    p_, hmax, wmax, c = patch_pixels.shape
    b, k, _ = records.shape
    d = kernel.shape[-1]
    assert hmax <= m and wmax <= n, "patch slot larger than canvas"
    assert m % patch == 0 and n % patch == 0, (m, n, patch)
    assert kernel.shape[0] == patch * patch * c, (kernel.shape, patch, c)
    side_m, side_n = m // patch, n // patch
    seq = side_m * side_n
    if b == 0 or k == 0 or p_ == 0:
        # empty packing: the embed of an all-zero canvas is just the bias
        return jnp.broadcast_to(bias.astype(kernel.dtype), (b, seq, d))

    block_rows = min(block_rows or DEFAULT_BLOCK_ROWS, side_m)
    body = functools.partial(
        _stitch_embed_kernel, m=m, n=n, patch=patch, k_steps=k,
        hmax=hmax, wmax=wmax, c=c, block_rows=block_rows,
        slot_dtype=patch_pixels.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            # the slot array stays in HBM; the kernel DMAs slots itself
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((patch * patch * c, d), lambda bi, recs: (0, 0)),
            pl.BlockSpec((1, d), lambda bi, recs: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seq, d), lambda bi, recs: (bi, 0, 0)),
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, seq, d), kernel.dtype),
        interpret=interpret,
    )(records, patch_pixels, kernel, bias.reshape(1, d))


def _unstitch_decode_kernel(records_ref,     # SMEM (B, K, 6) int32
                            raw_ref,         # VMEM (1, side_m, side_n, 5)
                            out_ref,         # VMEM (1, side_m, side_n, 5)
                            *, patch: int, side_m: int, side_n: int):
    b = pl.program_id(0)
    k = pl.program_id(1)

    valid = records_ref[b, k, 0]
    x0 = records_ref[b, k, 2].astype(jnp.float32)
    y0 = records_ref[b, k, 3].astype(jnp.float32)
    w = records_ref[b, k, 4].astype(jnp.float32)
    h = records_ref[b, k, 5].astype(jnp.float32)

    raw = raw_ref[0].astype(jnp.float32)              # (side_m, side_n, 5)
    cell = float(patch)
    obj = jax.nn.sigmoid(raw[..., 0])
    gy = jax.lax.broadcasted_iota(jnp.int32, (side_m, side_n), 0)
    gx = jax.lax.broadcasted_iota(jnp.int32, (side_m, side_n), 1)
    cx = (gx.astype(jnp.float32) + jax.nn.sigmoid(raw[..., 1])) * cell
    cy = (gy.astype(jnp.float32) + jax.nn.sigmoid(raw[..., 2])) * cell
    bw = jnp.exp(jnp.clip(raw[..., 3], -6, 6)) * cell
    bh = jnp.exp(jnp.clip(raw[..., 4], -6, 6)) * cell

    # center-in-placement assignment over the full grid (sigmoid offsets
    # keep every center inside its own cell, so no cell outside the
    # placement can hit), then clip to the rect and shift to
    # placement-local pixels — the same math route_detections applies
    # on the host to decode_boxes outputs
    hit = ((valid > 0)
           & (cx >= x0) & (cx < x0 + w)
           & (cy >= y0) & (cy < y0 + h))
    bx0 = jnp.clip(cx - bw / 2, x0, x0 + w) - x0
    by0 = jnp.clip(cy - bh / 2, y0, y0 + h) - y0
    bx1 = jnp.clip(cx + bw / 2, x0, x0 + w) - x0
    by1 = jnp.clip(cy + bh / 2, y0, y0 + h) - y0
    dec = jnp.stack([obj, bx0, by0, bx1, by1], axis=-1)
    out_ref[0] = jnp.where(hit[..., None], dec, jnp.zeros_like(dec))


def unstitch_decode_pallas(raw: jnp.ndarray, records: jnp.ndarray,
                           patch: int, num_patches: int,
                           *, interpret: bool = False) -> jnp.ndarray:
    """Fused head decode + placement gather.

    raw: (B, side_m, side_n, 5) raw head outputs; records as in stitch.
    Returns (num_patches, side_m, side_n, 5) float32 per-slot grids:
    channel 0 is objectness probability at cells whose decoded center
    falls inside the slot's placement (0 elsewhere), channels 1:5 the
    decoded box clipped to the placement in placement-local xyxy pixels.
    Slots not referenced by any valid record are undefined, exactly as in
    :func:`unstitch_pallas` — the packer places every queued patch once.
    """
    b, side_m, side_n, ch = raw.shape
    _, k, _ = records.shape
    assert ch == 5, raw.shape
    if num_patches == 0 or b == 0 or k == 0:
        return jnp.zeros((num_patches, side_m, side_n, ch), jnp.float32)

    body = functools.partial(_unstitch_decode_kernel, patch=patch,
                             side_m=side_m, side_n=side_n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, side_m, side_n, ch),
                         lambda bi, ki, recs: (bi, 0, 0, 0)),
        ],
        # invalid records park on the dummy slot, as in unstitch_pallas
        out_specs=pl.BlockSpec(
            (1, side_m, side_n, ch),
            lambda bi, ki, recs: (jnp.where(recs[bi, ki, 0] > 0,
                                            recs[bi, ki, 1], num_patches),
                                  0, 0, 0)),
    )
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_patches + 1, side_m, side_n, ch),
                                       jnp.float32),
        interpret=interpret,
    )(records, raw)
    return out[:num_patches]
