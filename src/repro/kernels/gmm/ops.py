"""Jit'd public entry for the GMM background-model update."""
from __future__ import annotations

import functools

import jax

from repro.core.gmm import GMMConfig
from repro.kernels.gmm.gmm import gmm_update_pallas
from repro.kernels.gmm.ref import gmm_update_reference


@functools.partial(jax.jit, static_argnames=("cfg", "impl", "block_h",
                                             "block_w"))
def gmm_update(state, frame, cfg: GMMConfig = GMMConfig(),
               impl: str = "xla", block_h: int = 8, block_w: int = 512):
    """impl: xla | pallas | pallas_interpret."""
    if impl == "xla":
        return gmm_update_reference(state, frame, cfg)
    return gmm_update_pallas(state, frame, cfg, block_h=block_h,
                             block_w=block_w,
                             interpret=(impl == "pallas_interpret"))
