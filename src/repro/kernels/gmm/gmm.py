"""Pallas TPU kernel: Stauffer-Grimson GMM background update.

TPU adaptation of cv2 cuda::BackgroundSubtractorMOG2 (DESIGN.md §2): the
update is purely per-pixel, so the kernel streams (block_h, block_w) pixel
tiles HBM->VMEM with the K mixture components unrolled in registers
(K = 3).  The background-selection uses the sort-free rank formulation so
the kernel math is identical to ``repro.core.gmm.update``.

Default tiling: (8, 512) tiles x K=3 components x 3 state arrays
= 8*512*3*3*4 B = 147 KiB in VMEM — deep pipelining headroom.
Every lane op is elementwise, so the VPU (8x128) is fully utilized;
arithmetic intensity is low (one frame read, 3 state arrays r/w), making
this kernel HBM-bound — the roofline term the §Perf log tracks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gmm import GMMConfig


def _gmm_kernel(w_ref, mu_ref, var_ref, x_ref,
                w_out, mu_out, var_out, fg_out, *, cfg: GMMConfig):
    w = w_ref[...]
    mu = mu_ref[...]
    var = var_ref[...]
    x = x_ref[...][..., None]
    lr = cfg.learning_rate
    k = cfg.n_components

    dist2 = jnp.square(x - mu)
    matched = dist2 < (cfg.match_sigmas ** 2) * var
    any_match = jnp.any(matched, axis=-1)

    fitness = w / jnp.sqrt(var)
    fit_masked = jnp.where(matched, fitness, -jnp.inf)
    best = jnp.argmax(fit_masked, axis=-1)
    onehot = jax.nn.one_hot(best, k) * any_match[..., None]

    w_new = (1 - lr) * w + lr * onehot
    mu_new = jnp.where(onehot > 0, (1 - lr) * mu + lr * x, mu)
    var_new = jnp.where(onehot > 0,
                        jnp.maximum((1 - lr) * var + lr * dist2, cfg.min_var),
                        var)

    weakest = jnp.argmin(w, axis=-1)
    replace = jax.nn.one_hot(weakest, k) * (~any_match)[..., None]
    w_new = jnp.where(replace > 0, lr, w_new)
    mu_new = jnp.where(replace > 0, x, mu_new)
    var_new = jnp.where(replace > 0, cfg.init_var, var_new)
    w_new = w_new / jnp.sum(w_new, axis=-1, keepdims=True)

    fit_new = w_new / jnp.sqrt(var_new)
    ki = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)      # row = i
    kj = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)      # col = j
    fitter = (fit_new[..., None, :] > fit_new[..., :, None]) | (
        (fit_new[..., None, :] == fit_new[..., :, None]) & (kj < ki))
    cum_before = jnp.sum(jnp.where(fitter, w_new[..., None, :], 0.0), axis=-1)
    is_bg = cum_before < cfg.background_ratio
    fg = ~jnp.any(matched & is_bg, axis=-1)

    w_out[...] = w_new
    mu_out[...] = mu_new
    var_out[...] = var_new
    fg_out[...] = fg


def gmm_update_pallas(state, frame, cfg: GMMConfig = GMMConfig(), *,
                      block_h: int = 8, block_w: int = 512,
                      interpret: bool = False):
    """state: {w, mu, var} each (H, W, K) f32; frame: (H, W) f32.

    Returns (new_state, fg (H, W) bool).  H % block_h == 0 and
    W % block_w == 0 (pad upstream; 4K and the test sizes satisfy this).
    """
    h, w_dim, k = state["w"].shape
    assert h % block_h == 0 and w_dim % block_w == 0, (h, w_dim)
    grid = (h // block_h, w_dim // block_w)

    state_spec = pl.BlockSpec((block_h, block_w, k), lambda i, j: (i, j, 0))
    frame_spec = pl.BlockSpec((block_h, block_w), lambda i, j: (i, j))

    kernel = functools.partial(_gmm_kernel, cfg=cfg)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[state_spec, state_spec, state_spec, frame_spec],
        out_specs=[state_spec, state_spec, state_spec, frame_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, w_dim, k), jnp.float32),
            jax.ShapeDtypeStruct((h, w_dim, k), jnp.float32),
            jax.ShapeDtypeStruct((h, w_dim, k), jnp.float32),
            jax.ShapeDtypeStruct((h, w_dim), jnp.bool_),
        ],
        interpret=interpret,
    )(state["w"], state["mu"], state["var"], frame)
    w_new, mu_new, var_new, fg = out
    return {"w": w_new, "mu": mu_new, "var": var_new}, fg
