"""Pure-jnp oracle for the GMM update kernel = the core model itself."""
from __future__ import annotations

from repro.core.gmm import GMMConfig, update


def gmm_update_reference(state, frame, cfg: GMMConfig = GMMConfig()):
    return update(state, frame, cfg)
