"""Config dataclasses for architectures, shapes, meshes and the scheduler.

Every assigned architecture gets one file in ``repro/configs/<id>.py`` that
instantiates one of the model config dataclasses below plus its shape set.
``repro.configs.registry`` maps ``--arch <id>`` to the instance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# --------------------------------------------------------------------------
# Model families
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeekMoE-style
    d_ff_expert: int = 0         # per-expert hidden size (0 -> use model d_ff)
    capacity_factor: float = 1.25
    group_size: int = 512        # tokens per dispatch group (GShard grouping)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Decoder-only LM (dense or MoE)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # "dots": save per-layer dot outputs (fast bwd, more memory);
    # "minimal": save only layer-boundary carries (full recompute)
    remat_policy: str = "dots"
    scan_layers: bool = True
    # decode KV-cache write: "dus" | "masked" | "auto" (masked iff the
    # cache seq axis is sharded — see attention.decode_attention)
    cache_update: str = "auto"
    # fuse q/k/v projections into one matmul (serving optimization)
    fused_qkv: bool = False
    # int8-resident weights (per-output-channel scales): serving mode that
    # lets 100B-class models stay HBM-resident without per-step FSDP
    # gathers (§Perf iteration 2.3)
    quant_weights: bool = False
    # int8 KV cache (per-position-per-head scales): halves the decode
    # streaming bound (§Perf iteration 2.4)
    quant_kv: bool = False
    # flash attention block sizes (TPU targets; used by the Pallas kernel)
    flash_block_q: int = 512
    flash_block_kv: int = 512

    family: str = "lm"

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + layers)."""
        d, L = self.d_model, self.n_layers
        att = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.n_heads * self.head_dim * d
        if self.moe is not None:
            ff = self.moe.d_ff_expert or self.d_ff
            mlp = (self.moe.n_experts + self.moe.n_shared) * 3 * d * ff
            mlp += d * self.moe.n_experts  # router
        else:
            mlp = 3 * d * self.d_ff
        norms = 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (att + mlp + norms) + emb + d

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE counts only routed top-k)."""
        if self.moe is None:
            return self.n_params
        d, L = self.d_model, self.n_layers
        att = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.n_heads * self.head_dim * d
        ff = self.moe.d_ff_expert or self.d_ff
        mlp = (self.moe.top_k + self.moe.n_shared) * 3 * d * ff + d * self.moe.n_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (att + mlp + 2 * d) + emb + d


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """ViT / DeiT encoder classifier."""

    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    distill_token: bool = False
    in_channels: int = 3
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    fused_qkv: bool = False
    # int8-resident encoder weights (per-output-channel scales); the
    # patch-embed / pos-embed / norms / head stay full precision
    quant_weights: bool = False
    # "reshape" (transpose+reshape patchify) or "conv" (strided conv stem)
    patch_embed: str = "reshape"
    family: str = "vision"

    @property
    def n_tokens(self) -> int:
        side = self.img_res // self.patch
        return side * side + 1 + (1 if self.distill_token else 0)

    @property
    def n_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d
        patch_embed = self.in_channels * self.patch * self.patch * d + d
        head = d * self.n_classes
        return self.n_layers * per_layer + patch_embed + head + self.n_tokens * d

    @property
    def n_active_params(self) -> int:
        return self.n_params


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    """Diffusion transformer (DiT) with adaLN-zero conditioning.

    Operates on a VAE latent grid: latent side = img_res // 8, 4 channels,
    as in the DiT paper.  ``patch`` patchifies the latent grid.
    """

    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    latent_channels: int = 4
    vae_factor: int = 8
    n_classes: int = 1000
    timestep_dim: int = 256
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    family: str = "diffusion"

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        res = img_res or self.img_res
        side = res // self.vae_factor // self.patch
        return side * side

    @property
    def n_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 6 * d * d + 4 * d  # attn+mlp+adaLN
        io = self.latent_channels * self.patch**2 * d * 2
        cond = self.timestep_dim * d + d * d + self.n_classes * d
        return self.n_layers * per_layer + io + cond

    @property
    def n_active_params(self) -> int:
        return self.n_params


@dataclasses.dataclass(frozen=True)
class EfficientNetConfig:
    """EfficientNet with compound scaling (B0 base scaled by width/depth)."""

    name: str
    img_res: int
    width_mult: float
    depth_mult: float
    n_classes: int = 1000
    dropout: float = 0.5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    family: str = "vision"

    # B0 stage template: (expand, channels, repeats, stride, kernel)
    STAGES: Tuple[Tuple[int, int, int, int, int], ...] = (
        (1, 16, 1, 1, 3),
        (6, 24, 2, 2, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    )
    stem_channels: int = 32
    head_channels: int = 1280

    def scaled_channels(self, c: int) -> int:
        c = c * self.width_mult
        new_c = max(8, int(c + 4) // 8 * 8)
        if new_c < 0.9 * c:
            new_c += 8
        return new_c

    def scaled_repeats(self, r: int) -> int:
        import math
        return int(math.ceil(self.depth_mult * r))

    @property
    def n_params(self) -> int:
        # computed exactly by the param spec tree; rough estimate here
        from repro.models import efficientnet as _e
        return _e.count_params(self)

    @property
    def n_active_params(self) -> int:
        return self.n_params


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """ViT-backbone anchor-free detector for the Tangram pipeline."""

    name: str
    canvas: int = 1024
    patch: int = 32
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    scan_layers: bool = True
    # int8-resident trunk weights (per-output-channel scales) for the
    # quantized serve path; embed/head/norms stay full precision
    quant_weights: bool = False
    family: str = "detector"

    @property
    def n_tokens(self) -> int:
        side = self.canvas // self.patch
        return side * side

    @property
    def n_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d
        return self.n_layers * per_layer + 3 * self.patch**2 * d + d * 5 + self.n_tokens * d

    @property
    def n_active_params(self) -> int:
        return self.n_params


# --------------------------------------------------------------------------
# Shapes (workload cells)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One workload cell: what step gets lowered and with what sizes."""

    name: str
    kind: str               # train | prefill | decode | gen | cls | serve
    seq_len: int = 0
    global_batch: int = 0
    img_res: int = 0
    steps: int = 0          # diffusion sampler steps

    @property
    def is_train(self) -> bool:
        return self.kind in ("train", "cls")

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES = (
    ShapeConfig("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeConfig("prefill_32k", "prefill", seq_len=32_768, global_batch=32),
    ShapeConfig("decode_32k", "decode", seq_len=32_768, global_batch=128),
    ShapeConfig("long_500k", "decode", seq_len=524_288, global_batch=1),
)

DIFFUSION_SHAPES = (
    ShapeConfig("train_256", "train", img_res=256, global_batch=256, steps=1000),
    ShapeConfig("gen_1024", "gen", img_res=1024, global_batch=4, steps=50),
    ShapeConfig("gen_fast", "gen", img_res=512, global_batch=16, steps=4),
    ShapeConfig("train_1024", "train", img_res=1024, global_batch=32, steps=1000),
)

VISION_SHAPES = (
    ShapeConfig("cls_224", "cls", img_res=224, global_batch=256),
    ShapeConfig("cls_384", "cls", img_res=384, global_batch=64),
    ShapeConfig("serve_b1", "serve", img_res=224, global_batch=1),
    ShapeConfig("serve_b128", "serve", img_res=224, global_batch=128),
)


def shapes_for(model_cfg) -> Tuple[ShapeConfig, ...]:
    fam = model_cfg.family
    if fam == "lm":
        return LM_SHAPES
    if fam == "diffusion":
        return DIFFUSION_SHAPES
    if fam in ("vision", "detector"):
        return VISION_SHAPES
    raise ValueError(f"unknown family {fam}")


# --------------------------------------------------------------------------
# Hardware + scheduler configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """TPU v5e constants used in the roofline analysis."""

    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: int = 16 * 1024**3    # per chip


@dataclasses.dataclass(frozen=True)
class TangramConfig:
    """Paper-facing knobs (Sections III-IV defaults)."""

    canvas_m: int = 1024             # canvas height M
    canvas_n: int = 1024             # canvas width N
    zone_x: int = 4                  # partition grid X
    zone_y: int = 4                  # partition grid Y
    slo_s: float = 1.0               # default SLO
    slack_sigmas: float = 3.0        # T_slack = mu + 3 sigma
    max_canvases_per_batch: int = 8  # from function memory (Eq. 5)
    # Alibaba FC function spec from Section V-A
    n_vcpu: int = 2
    mem_gb: float = 4.0
    gpu_mem_gb: float = 6.0
    model_mem_gb: float = 1.5        # tau: model residency in accelerator mem
    canvas_mem_gb: float = 0.5       # w: activation memory per canvas


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
