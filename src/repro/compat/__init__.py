"""Version-gated compatibility shims for jax API drift."""
