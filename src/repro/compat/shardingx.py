"""Version-gated sharding compatibility layer.

jax's mesh-construction API has drifted across the versions this repo
supports:

* **new jax** (>= 0.6-era): ``jax.make_mesh(shape, axes, axis_types=...)``
  with ``jax.sharding.AxisType`` explicit-sharding annotations, plus the
  ``jax.sharding.set_mesh`` context and ``get_abstract_mesh`` ambient-mesh
  query.
* **mid jax** (0.4.35 .. pre-AxisType, e.g. the 0.4.37 in the dev image):
  ``jax.make_mesh(shape, axes)`` exists but takes no ``axis_types``;
  ``AxisType``/``set_mesh``/``get_abstract_mesh`` are absent.
* **old jax** (0.4.30 .. 0.4.34): no ``jax.make_mesh`` at all — meshes are
  built from ``jax.experimental.mesh_utils.create_device_mesh`` + ``Mesh``.

Everything in the tree that constructs a mesh or needs the ambient-mesh
machinery routes through this module; ``jax.sharding.AxisType`` must never
be referenced anywhere else (enforced by ``tests/test_compat_sharding.py``).
All meshes are Auto-typed: on new jax we pass ``AxisType.Auto`` explicitly,
which matches the implicit behaviour of the older constructors, so compiled
programs are identical on both sides of the gate.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

# ---------------------------------------------------------------- feature
# detection (import-time, once) -------------------------------------------
HAS_AXIS_TYPE: bool = hasattr(jax.sharding, "AxisType")
HAS_MAKE_MESH: bool = hasattr(jax, "make_mesh")
MAKE_MESH_HAS_AXIS_TYPES: bool = (
    HAS_MAKE_MESH
    and "axis_types" in inspect.signature(jax.make_mesh).parameters)
HAS_SET_MESH: bool = hasattr(jax.sharding, "set_mesh")
HAS_ABSTRACT_MESH: bool = hasattr(jax.sharding, "get_abstract_mesh")


def auto_axis_types(n_axes: int) -> Optional[Tuple]:
    """``(AxisType.Auto,) * n`` on new jax, ``None`` where the concept
    does not exist (callers must then omit the kwarg entirely)."""
    if not HAS_AXIS_TYPE:
        return None
    return (jax.sharding.AxisType.Auto,) * n_axes


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> Mesh:
    """The one mesh factory: logical (shape, axes) -> Auto-typed Mesh.

    ``devices`` restricts construction to an explicit device list
    (defaults to all of ``jax.devices()``).
    """
    if MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             devices=devices,
                             axis_types=auto_axis_types(len(axis_names)))
    if HAS_MAKE_MESH:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             devices=devices)
    # pre-0.4.35: build the device ndarray by hand
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
    return Mesh(devs, tuple(axis_names))


def mesh_from_devices(device_array, axis_names: Sequence[str]) -> Mesh:
    """Mesh from an explicit device ndarray (the elastic re-mesh path,
    where surviving rows of a failed mesh are re-assembled in place)."""
    types = auto_axis_types(len(tuple(axis_names)))
    if types is not None:
        return Mesh(device_array, tuple(axis_names), axis_types=types)
    return Mesh(device_array, tuple(axis_names))


def use_mesh(mesh: Mesh):
    """Context manager with ``jax.sharding.set_mesh`` semantics.

    On old jax, falls back to the legacy global-mesh context
    (``Mesh.__enter__``), which is what ``set_mesh`` replaced; both make
    ``mesh`` ambient for jit lowering and sharding constraints.
    """
    if HAS_SET_MESH:
        return jax.sharding.set_mesh(mesh)
    return mesh


def get_abstract_mesh() -> Optional[object]:
    """The ambient mesh, or None when there is none.

    New jax: ``jax.sharding.get_abstract_mesh()`` (set by ``set_mesh``).
    Old jax: the legacy global physical mesh that ``use_mesh``'s
    ``with mesh:`` fallback installs — without this branch every
    logical sharding constraint would silently no-op on old jax and the
    two sides of the gate would compile different programs.
    Query axis sizes via ``mesh_axis_sizes`` (the two mesh types spell
    them differently).
    """
    if HAS_ABSTRACT_MESH:
        try:
            mesh = jax.sharding.get_abstract_mesh()
        except Exception:
            return None
    else:
        try:
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
        except Exception:
            return None
    if mesh is None or getattr(mesh, "empty", True):
        return None
    return mesh


def mesh_axis_sizes(mesh) -> dict:
    """{axis name: size} for abstract and physical meshes alike."""
    try:
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except AttributeError:
        return dict(mesh.shape)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across the same version gate:
    old jax returns a one-element list of dicts, new jax the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
