"""Shared helpers for the named-reference factories.

Every factory (``make_classify`` / ``make_clock`` / ``make_executor`` /
``make_source`` / ``make_placement`` / ``make_model``) resolves a
registry name and fails the same way: a ``ValueError`` naming the kind,
the offending name, and the known choices.  Funnelling the message
through one helper keeps the error format identical across the quartet
(and every registry added later) — CLI users and config loaders see one
shape of failure regardless of which field was wrong.
"""
from __future__ import annotations

from typing import Iterable, Mapping


def unknown_name(kind: str, name: object, known: Iterable) -> ValueError:
    """The unified unknown-registry-name error (raise the return value)."""
    return ValueError(f"unknown {kind} {name!r}; "
                      f"choose from {sorted(known, key=str)}")


def lookup(kind: str, mapping: Mapping, name: object):
    """``mapping[name]`` with the unified error on a miss."""
    try:
        return mapping[name]
    except KeyError:
        raise unknown_name(kind, name, mapping) from None
