"""Unified event-driven serving engine: one control plane for the
simulated platform and the real jit'd detector.

The engine owns the event loop every serving scenario runs on.  *Engine
time* comes from a pluggable :mod:`~repro.core.clock` — a
:class:`~repro.core.clock.VirtualClock` (default) jumps between events so
simulation and replay run as fast as the host allows, while a
:class:`~repro.core.clock.WallClock` sleeps to each event so timers fire
at real wall times (live serving).  Three event kinds, always processed
in engine-time order:

* **arrivals** — bandwidth-shaped ``data.video.Arrival`` records fed via
  :meth:`ServingEngine.run` (a whole trace) or :meth:`ServingEngine.offer`
  (streaming);
* **invoker timers** — each batching policy exposes ``next_timer()``; the
  engine fires the policy *at the timer's scheduled time*, never
  deferring to the next arrival (a gap between arrivals that straddles
  ``t_remain`` no longer inflates ``t_submit``);
* **completions** — every dispatched invocation finishes some time after
  it was submitted.  ``t_finish`` is *not* known at dispatch: executors
  expose ``submit(inv) -> handle`` and the engine resolves the handle to
  a :class:`Completion` later — from the platform model (``SimExecutor``,
  finish time known as soon as the model is consulted), or by joining the
  device future (``AsyncDeviceExecutor``).  Completion delivery is where
  outcomes are recorded, executor bookkeeping (frame-store eviction) runs,
  and batcher feedback (``on_result``) fires — the feedback loop sees
  what actually happened, not what the model predicted at dispatch.

**Event ordering at timestamp ties** (pinned by regression test): when a
completion and a timer are scheduled at the same instant, the completion
is delivered first — feedback from finished work always lands before the
next batch is cut.  When two invokers in an :class:`InvokerPool` share a
timer instant, the first-registered class fires first (dict insertion
order, i.e. order of first arrival).  Async device completions carry no
scheduled time; they are delivered as soon as the device reports them
ready (harvested at every event-loop step), with finish times clamped
monotone per worker (each worker's serial queue finishes in submit
order; cross-worker streams interleave) and simultaneous readiness
tie-broken by ``(worker index, submit seq)``.

Scheduling policy and execution substrate are independent axes:

* a **batcher** turns arrivals into :class:`~repro.core.invoker.Invocation`
  batches.  :class:`~repro.core.invoker.SLOAwareInvoker` is the paper's
  Algorithm 2; :class:`InvokerPool` keys one invoker per SLO class (or any
  user classification) so tight-deadline patches never queue behind
  loose-deadline ones; ``core.adaptive.AdaptiveInvokerPool`` layers a
  completion-driven AIMD controller on top; the baselines in
  ``core.baselines`` are alternative batchers over the same loop.
* an **executor** runs a fired invocation: :class:`SimExecutor` submits to
  the serverless ``Platform`` model, :class:`DeviceExecutor` runs the real
  stitch -> (sharded) detect -> unstitch -> route pipeline synchronously,
  and :class:`AsyncDeviceExecutor` exploits JAX async dispatch — submit
  returns after the host-side stitch + jit dispatch, the device crunches
  in the background while the engine keeps ingesting arrivals and
  restitching, and the engine blocks only when the bounded in-flight
  queue is full or the trace is draining.  Invocation boundaries depend
  only on arrivals and the batcher, so the same trace produces identical
  patch->invocation groupings on all three.

Batcher protocol (duck-typed; ``SLOAwareInvoker`` already conforms):

    on_patch(t, patch) -> List[Invocation]   # may fire immediately
    poll(t)            -> Optional[Invocation]
    flush(t)           -> Optional[Invocation]  # engine loops until None
    next_timer()       -> float                 # inf when idle
    on_result(inv, t_finish)                    # optional feedback, called
                                                # at completion delivery

Executor protocol:

    submit(inv) -> ExecHandle       # dispatch; handle.t_finish set when
                                    # the finish time is already known
    resolve(handle) -> Completion   # join; blocks if work is in flight
    ready(handle) -> bool           # optional, async executors only
    max_inflight: int               # optional bound on unresolved handles
    on_complete(comp)               # optional, at completion delivery

Executors that only implement the legacy ``execute(inv) -> Completion``
are still accepted (the engine wraps them in a pre-resolved handle).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.clock import Clock, VirtualClock
from repro.core.framestore import FrameStore
from repro.core.invoker import Invocation, SLOAwareInvoker
from repro.core.partitioning import Patch
from repro.core.stitching import validate
from repro.data.video import Arrival
from repro.serverless.platform import Platform


# ------------------------------------------------------------- outcomes ----

@dataclasses.dataclass
class PatchOutcome:
    patch: Patch
    t_arrive: float
    t_submit: float
    t_finish: float
    model: Optional[str] = None   # registry model that served the patch

    @property
    def latency(self) -> float:
        return self.t_finish - self.patch.t_gen

    @property
    def violated(self) -> bool:
        return self.t_finish > self.patch.deadline

    @property
    def wait(self) -> float:
        return self.t_submit - self.t_arrive


@dataclasses.dataclass
class Results:
    name: str
    outcomes: List[PatchOutcome]
    canvas_efficiencies: List[float]
    batch_sizes: List[int]
    patches_per_batch: List[int]
    bytes_sent: float
    total_cost: float
    invocations: int
    exec_seconds: float
    transmission_seconds: float
    mean_consolidation: float = 0.0   # patches per invocation (platform view)
    worker_stats: Optional[List[dict]] = None  # per-worker pool counters
                                      # (WorkerPoolExecutor.worker_stats())
    source_stats: Optional[dict] = None  # ingestion-side accounting
                                      # (repro.sources SourceStats.to_dict():
                                      # frames dropped/degraded under
                                      # backpressure, arrivals, bytes)
    model_stats: Optional[dict] = None  # per-model platform/cache counters
                                      # (Platform.model_stats() merged with
                                      # WorkerPoolExecutor.model_cache_stats())
    shard_stats: Optional[List[dict]] = None  # per-shard fleet rows
                                      # (ShardedEngine.shard_stats():
                                      # arrivals, utilization, violations,
                                      # backlog high water)

    @property
    def n_patches(self) -> int:
        return len(self.outcomes)

    @property
    def violation_rate(self) -> float:
        if not self.outcomes:
            return 0.0
        return sum(o.violated for o in self.outcomes) / len(self.outcomes)

    def class_violation_rate(self, classify: Callable[[Patch], object],
                             key: object) -> float:
        """Violation rate restricted to one SLO class (mixed-SLO studies)."""
        mine = [o for o in self.outcomes if classify(o.patch) == key]
        if not mine:
            return 0.0
        return sum(o.violated for o in mine) / len(mine)

    @property
    def mean_latency(self) -> float:
        if not self.outcomes:
            return 0.0
        return sum(o.latency for o in self.outcomes) / len(self.outcomes)

    @property
    def amortized_latency(self) -> float:
        """Total function execution time amortized per patch (Fig. 14)."""
        if not self.outcomes:
            return 0.0
        return self.exec_seconds / len(self.outcomes)

    def class_breakdown(self) -> dict:
        """Per-SLO-class outcome breakdown (keyed by the patch's SLO)."""
        by: Dict[object, List[PatchOutcome]] = {}
        for o in self.outcomes:
            by.setdefault(o.patch.slo, []).append(o)
        return {
            str(slo): {
                "patches": len(outs),
                "violations": sum(o.violated for o in outs),
                "violation_rate": round(
                    sum(o.violated for o in outs) / len(outs), 4),
                "mean_latency_s": round(
                    sum(o.latency for o in outs) / len(outs), 4),
            }
            for slo, outs in sorted(by.items(), key=lambda kv: str(kv[0]))
        }

    def model_breakdown(self) -> dict:
        """Per-model rows: outcome accounting (violations, latency) merged
        with the platform/cache counters in ``model_stats`` (batches,
        cold starts, weight loads, weight-cache hit rate) — the debugging
        surface for mixed-model runs."""
        by: Dict[str, List[PatchOutcome]] = {}
        for o in self.outcomes:
            if o.model is not None:
                by.setdefault(o.model, []).append(o)
        rows: Dict[str, dict] = {}
        for model, outs in sorted(by.items()):
            rows[model] = {
                "patches": len(outs),
                "violations": sum(o.violated for o in outs),
                "violation_rate": round(
                    sum(o.violated for o in outs) / len(outs), 4),
                "mean_latency_s": round(
                    sum(o.latency for o in outs) / len(outs), 4),
            }
        for model, st in sorted((self.model_stats or {}).items()):
            rows.setdefault(model, {}).update(st)
        return rows

    def summary(self) -> dict:
        out = {
            "name": self.name,
            "patches": self.n_patches,
            "violation_rate": round(self.violation_rate, 4),
            "mean_latency_s": round(self.mean_latency, 4),
            "cost_usd": round(self.total_cost, 6),
            "invocations": self.invocations,
            "bytes_mb": round(self.bytes_sent / 1e6, 3),
            "mean_canvas_eff": round(
                sum(self.canvas_efficiencies)
                / max(len(self.canvas_efficiencies), 1), 4),
            "amortized_latency_s": round(self.amortized_latency, 4),
            "mean_consolidation": round(self.mean_consolidation, 2),
            "class_violations": self.class_breakdown(),
        }
        models = self.model_breakdown()
        if models:
            out["models"] = models
        if self.worker_stats is not None:
            # horizon = span of delivered work; utilization is each
            # worker's busy time over it, so placement-policy skew shows
            # up directly in the benchmark JSON
            horizon = max((o.t_finish for o in self.outcomes), default=0.0)
            out["per_worker"] = [
                dict(ws, utilization=round(ws.get("busy_s", 0.0)
                                           / max(horizon, 1e-12), 4))
                for ws in self.worker_stats
            ]
        if self.source_stats is not None:
            out["source"] = self.source_stats
        if self.shard_stats is not None:
            out["per_shard"] = self.shard_stats
        return out


@dataclasses.dataclass
class Completion:
    """One finished invocation, delivered at ``t_finish`` engine time."""
    invocation: Invocation
    t_finish: float
    record: object = None     # platform ExecutionRecord (SimExecutor)
    outputs: object = None    # routed device outputs (DeviceExecutor)
    worker: int = 0           # pool worker that ran it (0 outside a pool)
    model: Optional[str] = None  # registry model that ran it (filled from
                              # the invocation at delivery when unset)


@dataclasses.dataclass
class ExecHandle:
    """An in-flight invocation, returned by ``Executor.submit``.

    ``t_finish`` is set when the executor already knows the finish time
    at submit (the platform model, or a sync device run) — the engine
    then schedules delivery on the event heap.  When ``None`` the work is
    genuinely in flight (async device futures) and the engine resolves
    the handle when it reports ready, the in-flight bound is hit, or the
    trace drains.

    ``worker`` is the pool worker index the invocation was placed on
    (:class:`~repro.core.workers.WorkerPoolExecutor`; 0 for single-device
    executors) and ``seq`` the engine's submit sequence number — together
    they are the pinned completion tie-break ``(worker, seq)`` that makes
    multi-worker delivery order reproducible when several handles report
    ready at the same harvest.
    """
    invocation: Invocation
    t_finish: Optional[float] = None
    completion: Optional[Completion] = None
    payload: object = None            # executor-private in-flight state
    worker: int = 0
    seq: int = -1
    model: Optional[str] = None       # invocation's model key (engine-set)
    load_s: float = 0.0               # weight-cache load cost still to be
                                      # added to t_finish at resolve (async
                                      # handles; 0 once applied)


# ----------------------------------------------------------- invoker pool ----

def slo_class(patch: Patch) -> float:
    """Default classification: one invoker per distinct SLO value."""
    return patch.slo


class InvokerPool:
    """Per-class SLO-aware invokers behind one batcher interface.

    ``classify`` maps a patch to its class key (default: its SLO value;
    pass e.g. ``lambda p: (p.slo, p.camera_id // 4)`` to also group
    cameras).  ``make_invoker(key)`` builds the class's invoker on first
    use, so each class can have its own canvas geometry and latency
    table.  Every fired ``Invocation`` is tagged with its class ``key``,
    and — when ``model_of`` is given — with the registry model name its
    class resolves to (``model_of(key)``), so executors, placement, and
    the platform model all see which network the batch runs.
    """

    def __init__(self, make_invoker: Callable[[object], SLOAwareInvoker],
                 classify: Callable[[Patch], object] = slo_class,
                 model_of: Optional[Callable[[object],
                                             Optional[str]]] = None):
        self.make_invoker = make_invoker
        self.classify = classify
        self.model_of = model_of
        self.invokers: Dict[object, SLOAwareInvoker] = {}

    def _invoker(self, key: object) -> SLOAwareInvoker:
        inv = self.invokers.get(key)
        if inv is None:
            inv = self.invokers[key] = self.make_invoker(key)
        return inv

    def _tag(self, fired, key):
        model = self.model_of(key) if self.model_of is not None else None
        for f in fired:
            f.key = key
            if f.model is None:
                f.model = model
        return fired

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        key = self.classify(patch)
        return self._tag(self._invoker(key).on_patch(t_now, patch), key)

    def queue_depth(self) -> int:
        """Patches currently queued (unfired) across every class — the
        pool half of the engine's ingestion-backpressure signal."""
        return sum(len(inv.queue) for inv in self.invokers.values())

    def next_timer(self) -> float:
        return min((inv.next_timer() for inv in self.invokers.values()),
                   default=math.inf)

    def poll(self, t_now: float) -> Optional[Invocation]:
        """Fire the due invoker with the earliest timer.

        Timer ties resolve to the *first-registered* class (dict
        insertion order = order of each class's first arrival) — pinned
        by a regression test so multi-class schedules are deterministic.
        """
        due = [(inv.next_timer(), key) for key, inv in self.invokers.items()
               if inv.next_timer() <= t_now]
        if not due:
            return None
        _, key = min(due, key=lambda x: x[0])
        fired = self.invokers[key].poll(t_now)
        if fired is not None:
            self._tag([fired], key)
        return fired

    def flush(self, t_now: float) -> Optional[Invocation]:
        for key, inv in self.invokers.items():
            fired = inv.flush(t_now)
            if fired is not None:
                self._tag([fired], key)
                return fired
        return None


def uniform_pool(canvas_m: int, canvas_n: int, latency, max_canvases: int = 8,
                 incremental: bool = True,
                 classify: Optional[Callable[[Patch], object]] = None,
                 model_of: Optional[Callable[[object],
                                             Optional[str]]] = None
                 ) -> InvokerPool:
    """Pool where every class shares one geometry/latency spec.

    ``classify=None`` gives the paper's single shared queue (every patch
    maps to one class); pass :func:`slo_class` for per-SLO pools.
    ``model_of`` tags fired invocations with their class's registry
    model name (see :class:`InvokerPool`).
    """
    return InvokerPool(
        lambda key: SLOAwareInvoker(canvas_m, canvas_n, latency,
                                    max_canvases, incremental=incremental),
        classify=classify or (lambda p: None), model_of=model_of)


# -------------------------------------------------------------- executors ----

class SimExecutor:
    """Executor over the discrete-event serverless ``Platform`` model.

    The model is consulted at submit, so the handle's finish time is
    known immediately and the engine schedules delivery on the event
    heap — the simulation analogue of "the device will interrupt us at
    t_finish".

    Multi-model serving: ``model_loads`` maps a registry model name to
    its weight-load seconds and ``model_tables`` to its latency table
    (both typically from :class:`~repro.core.models.ModelSpec`).  A
    model-tagged invocation is then submitted with its own execution
    profile and load cost, and the platform's per-model warm pools make
    an instance warm for model A cold for model B.  Untagged invocations
    (or an empty mapping) keep the historical single-model behaviour
    byte-for-byte.
    """

    def __init__(self, platform: Platform,
                 model_loads: Optional[Dict[str, float]] = None,
                 model_tables: Optional[Dict[str, object]] = None):
        self.platform = platform
        self.model_loads = model_loads or {}
        self.model_tables = model_tables or {}

    def submit(self, inv: Invocation) -> ExecHandle:
        size = (inv.cost_canvases if inv.cost_canvases is not None
                else len(inv.canvases))
        if inv.model is None:
            rec = self.platform.submit(inv.t_submit, size,
                                       n_patches=len(inv.patches))
        else:
            rec = self.platform.submit(
                inv.t_submit, size, n_patches=len(inv.patches),
                model=inv.model,
                model_load_s=self.model_loads.get(inv.model, 0.0),
                latency=self.model_tables.get(inv.model))
        comp = Completion(inv, rec.t_finish, record=rec, model=inv.model)
        return ExecHandle(inv, t_finish=rec.t_finish, completion=comp)

    def resolve(self, handle: ExecHandle) -> Completion:
        return handle.completion

    def execute(self, inv: Invocation) -> Completion:  # legacy shim
        return self.resolve(self.submit(inv))


def _leaf_ready(x) -> bool:
    """Duck-typed readiness: jax Arrays and future-likes expose
    ``is_ready()``; anything else (numpy, scalars) is ready by
    definition."""
    probe = getattr(x, "is_ready", None)
    if probe is None:
        return True
    try:
        return bool(probe())
    except TypeError:           # is_ready is a property on some types
        return bool(probe)


@dataclasses.dataclass
class ModelRuntime:
    """One servable model on the device path: the jit'd function, its
    params, and the canvas geometry / sharding it runs under.  The
    values of :class:`DeviceExecutor`'s ``models`` mapping (or zero-arg
    callables returning one, for lazy builds through the registry).

    The optional fused-path fields feed the fused device hot path
    (``kernels/stitch/fused_embed.py``): ``tokens_fn(params, tokens)``
    is the detector trunk minus the patch embed (``forward_tokens``),
    ``embed_kernel`` / ``embed_bias`` the full-precision patch-embed
    projection the fused stitch kernel applies in VMEM, and ``patch``
    the detector's patch size (fused token/grid geometry).  When they
    are absent a ``fuse=True`` executor falls back to the unfused
    pipeline for this model."""
    serve_fn: Callable
    params: object
    canvas_m: int
    canvas_n: int
    mesh: object = None
    rules: object = None
    tokens_fn: Optional[Callable] = None
    embed_kernel: object = None
    embed_bias: object = None
    patch: Optional[int] = None


class DeviceExecutor:
    """Executor over the real pipeline: batched stitch -> (data-parallel)
    detect -> inverse unstitch -> per-frame routing, joined synchronously
    at submit (``t_finish`` = ``t_submit`` + measured wall execution, the
    same quantity the offline profiling table estimates, so SLO
    accounting stays consistent between simulation and device).

    The pipeline is split into :meth:`_launch` (host-side crop gather +
    slot packing + jit dispatch — *returns before the device finishes*,
    courtesy of JAX async dispatch) and :meth:`_finalize` (block on the
    device values, route detections, account).  This class joins the two
    back-to-back; :class:`AsyncDeviceExecutor` keeps them apart so device
    execution overlaps arrival ingestion.

    Owns the frame store: ``add_frame`` registers a frame's pixels with a
    reference count (how many patches were cut from it); the engine's
    completion event decrements the counts and evicts a frame once every
    patch cut from it has been routed, so long serving runs no longer
    leak every frame ever seen.

    ``sync`` joins dispatched device work (default
    ``jax.block_until_ready``); tests and benchmarks substitute a hook
    that also joins non-JAX future-likes.

    Multi-model serving: ``models`` maps a registry model name to a
    :class:`ModelRuntime` — or to a zero-arg callable returning one,
    resolved and cached on first use so unused models are never built.
    A model-tagged invocation runs its own jit'd function, params, and
    canvas geometry; untagged invocations (and tags missing from the
    mapping) run the default runtime built from the positional ctor
    arguments, which keeps every single-model call site unchanged.
    """

    def __init__(self, serve_fn, params, canvas_m: int, canvas_n: int, *,
                 use_pallas: bool = False, fuse: bool = False,
                 mesh=None, rules=None,
                 clock: Callable[[], float] = time.perf_counter,
                 sync: Optional[Callable[[object], None]] = None,
                 models: Optional[Dict[str, object]] = None,
                 tokens_fn: Optional[Callable] = None,
                 embed_kernel=None, embed_bias=None,
                 patch: Optional[int] = None):
        self.serve_fn = serve_fn
        self.params = params
        self.m, self.n = canvas_m, canvas_n
        self.use_pallas = use_pallas
        self.fuse = fuse
        self.mesh = mesh
        self.rules = rules
        self.clock = clock
        self.sync = sync
        self.models = dict(models) if models else {}
        self.tokens_fn = tokens_fn
        self.embed_kernel = embed_kernel
        self.embed_bias = embed_bias
        self.patch = patch
        self._runtimes: Dict[Optional[str], ModelRuntime] = {}
        self.store = FrameStore()
        self.n_invocations = 0
        self.n_fused = 0
        self.n_detections = 0
        self.n_sharded = 0
        self.evidence_bytes = 0

    def _runtime(self, model: Optional[str]) -> ModelRuntime:
        """Resolve an invocation's model tag to its runtime (default
        runtime for ``None`` or unmapped tags); lazy entries are built
        once and cached."""
        rt = self._runtimes.get(model)
        if rt is not None:
            return rt
        entry = self.models.get(model) if model is not None else None
        if entry is None:
            rt = ModelRuntime(self.serve_fn, self.params, self.m, self.n,
                              mesh=self.mesh, rules=self.rules,
                              tokens_fn=self.tokens_fn,
                              embed_kernel=self.embed_kernel,
                              embed_bias=self.embed_bias, patch=self.patch)
        elif callable(entry):
            rt = entry()
        else:
            rt = entry
        self._runtimes[model] = rt
        return rt

    # ------------------------------------------------------- frame store ----
    # The store itself is the striped-lock FrameStore (concurrency-safe:
    # shard threads of the parallel fleet runtime share it); ``frames`` /
    # ``_refs`` stay available as point-in-time dict views so tests and
    # diagnostics that predate the store keep reading the same shapes.

    def add_frame(self, frame_id, pixels: np.ndarray, n_patches: int):
        """Register a frame the edge cut ``n_patches`` patches from.

        Frames that produced no patches are never referenced again and
        are not stored at all.
        """
        self.store.add(frame_id, pixels, n_patches)

    def on_complete(self, comp: Completion):
        """Completion event: release every routed patch's frame ref."""
        release = self.store.release
        for p in comp.invocation.patches:
            release(p.frame_id)

    @property
    def frames(self) -> Dict[object, np.ndarray]:
        return self.store.snapshot()

    @property
    def _refs(self) -> Dict[object, int]:
        return self.store.refs_snapshot()

    # --------------------------------------------------------- execution ----

    def _launch(self, inv: Invocation) -> dict:
        """Host-side stitch + jit dispatch.  Everything here returns as
        soon as the work is *enqueued* on the device (JAX async
        dispatch); nothing blocks on device values."""
        # imported here so the pure-simulation control plane never touches
        # the kernel/jit stack
        import jax.numpy as jnp

        from repro.kernels.stitch import ops as stitch_ops

        t0 = self.clock()
        rt = self._runtime(inv.model)
        plan = inv.batch_plan()
        crops = []
        store = self.store
        for patch in inv.patches:
            frame = store.get(patch.frame_id)
            if frame is None:
                crops.append(np.zeros((patch.h, patch.w, 3), np.float32))
            else:
                crops.append(frame[patch.y0:patch.y1, patch.x0:patch.x1])
        slots = stitch_ops.pack_plan_host(crops, plan)
        records = jnp.asarray(plan.records)
        impl = "pallas_interpret" if self.use_pallas else "xla"
        if self.fuse and rt.tokens_fn is not None \
                and rt.embed_kernel is not None and rt.patch is not None:
            # fused hot path: stitch->patch-embed emits the token batch
            # directly (no canvas batch in HBM), the trunk runs from
            # tokens, and decode+gather lands straight in per-patch slot
            # grids — no host round-trip through canvas-space outputs.
            # The canvas batch never exists, so mesh sharding (which
            # pads canvases, not records) does not apply here.
            tokens = stitch_ops.stitch_embed(
                jnp.asarray(slots), records, rt.embed_kernel,
                rt.embed_bias, rt.canvas_m, rt.canvas_n, rt.patch,
                impl=impl)
            raw = rt.tokens_fn(rt.params, tokens)
            fused = stitch_ops.unstitch_decode(
                raw, records, rt.patch, plan.slot_capacity, impl=impl)
            self.n_invocations += 1
            self.n_fused += 1
            return {"plan": plan, "fused": fused, "slots": slots, "t0": t0}
        canvases = stitch_ops.stitch_canvases(
            jnp.asarray(slots), records, rt.canvas_m, rt.canvas_n, impl=impl)
        sharded = False
        if rt.mesh is not None:
            canvases, sharded = shard_canvases(canvases, rt.mesh,
                                               rt.rules)
        obj, boxes = rt.serve_fn(rt.params, canvases)
        # inverse gather, grouped by source frame alongside the routed
        # detections.  The box head has no pixel-space output, so the
        # canvases stand in for a per-pixel head (e.g. segmentation): the
        # gathered slots equal the input crops, and the value here is
        # exercising the unstitch path every invocation.  slot_capacity
        # (pow2-bucketed) keeps the jit static shapes stable across
        # invocations; rows past num_patches are never read.
        patch_out = stitch_ops.unstitch_patches(
            canvases, records, plan.slot_capacity, plan.hmax, plan.wmax,
            impl=impl)
        self.n_invocations += 1
        self.n_sharded += bool(sharded)
        return {"plan": plan, "obj": obj, "boxes": boxes,
                "patch_out": patch_out, "t0": t0}

    def _finalize(self, inv: Invocation, payload: dict) -> Completion:
        """Join the device values and do the host-side routing."""
        import jax

        from repro.kernels.stitch import ops as stitch_ops

        sync = self.sync or jax.block_until_ready
        plan = payload["plan"]
        if "fused" in payload:
            sync(payload["fused"])
            per_frame = stitch_ops.route_fused(
                plan, inv.patches, np.asarray(payload["fused"]))
            # the unfused evidence (gathered slots) equals the input
            # crops by construction, so the fused path serves it from
            # the packed slots it already holds on the host
            evidence = payload["slots"]
        else:
            sync((payload["obj"], payload["patch_out"]))
            per_frame = stitch_ops.route_detections(
                plan, inv.patches, np.asarray(payload["obj"]),
                np.asarray(payload["boxes"]))
            evidence = np.asarray(payload["patch_out"])
        per_frame_pixels: Dict[object, List[np.ndarray]] = {}
        for i, patch in enumerate(inv.patches):
            # copy: a view would pin the whole pow2-padded batch in memory
            per_frame_pixels.setdefault(patch.frame_id, []).append(
                np.ascontiguousarray(evidence[i, :patch.h, :patch.w]))
        wall = self.clock() - payload["t0"]

        self.n_detections += sum(len(v) for v in per_frame.values())
        self.evidence_bytes += sum(
            a.nbytes for v in per_frame_pixels.values() for a in v)
        return Completion(inv, inv.t_submit + wall,
                          outputs=(per_frame, per_frame_pixels),
                          model=inv.model)

    def submit(self, inv: Invocation) -> ExecHandle:
        comp = self._finalize(inv, self._launch(inv))
        return ExecHandle(inv, t_finish=comp.t_finish, completion=comp)

    def resolve(self, handle: ExecHandle) -> Completion:
        if handle.completion is None:
            handle.completion = self._finalize(handle.invocation,
                                               handle.payload)
            handle.payload = None
        return handle.completion

    def execute(self, inv: Invocation) -> Completion:  # legacy shim
        return self.resolve(self.submit(inv))


class AsyncDeviceExecutor(DeviceExecutor):
    """Overlapped device execution: submit returns after the host-side
    stitch + jit *dispatch*, so the engine keeps ingesting arrivals and
    restitching while the device works through its queue.

    ``max_inflight`` bounds the number of unresolved handles the engine
    may hold (device memory for canvases + outputs is pinned per handle);
    when the bound is hit the engine retires an already-ready handle if
    there is one and otherwise blocks on the oldest.  A single device
    queue executes in order, so this executor's dispatches finish
    oldest-first and the engine's per-worker monotone clamp only smooths
    timer jitter; across a worker pool completions harvest out of order
    between workers.
    """

    def __init__(self, *args, max_inflight: int = 4, **kwargs):
        super().__init__(*args, **kwargs)
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.max_inflight = max_inflight

    def submit(self, inv: Invocation) -> ExecHandle:
        return ExecHandle(inv, t_finish=None, payload=self._launch(inv))

    def ready(self, handle: ExecHandle) -> bool:
        if handle.completion is not None:
            return True
        p = handle.payload
        if "fused" in p:
            return _leaf_ready(p["fused"])
        return (_leaf_ready(p["obj"]) and _leaf_ready(p["patch_out"])
                and _leaf_ready(p["boxes"]))


def shard_canvases(canvases, mesh, rules):
    """Lay the canvas batch out data-parallel over the serve mesh.

    The batch is padded to a multiple of the "data"-axis size (records
    never reference pad rows, so the detector output for them is simply
    ignored), then device_put with the batch axis split over "data".
    Pow2-style padding also stabilises jit static shapes: every batch
    compiles to a multiple of the axis size.  Returns the sharded batch
    and whether the data axis actually split it (False on 1 device).
    """
    import jax
    import jax.numpy as jnp

    from repro.compat import shardingx
    from repro.sharding import divisible_sharding

    n_data = shardingx.mesh_axis_sizes(mesh).get("data", 1)
    pad = (-canvases.shape[0]) % n_data
    if pad:
        canvases = jnp.concatenate(
            [canvases,
             jnp.zeros((pad,) + canvases.shape[1:], canvases.dtype)])
    sh = divisible_sharding(mesh, canvases.shape,
                            ("batch", None, None, None), rules)
    return jax.device_put(canvases, sh), bool(sh.spec) and n_data > 1


_EXECUTORS = {
    "sim": SimExecutor,
    "device": DeviceExecutor,
    "async_device": AsyncDeviceExecutor,
}


def make_executor(name: str, **cfg):
    """Executor-name -> instance (``sim`` | ``device`` | ``async_device``),
    mirroring ``make_placement`` / ``make_clock`` / ``make_source``.

    ``cfg`` forwards to the executor constructor: ``sim`` takes
    ``platform=`` (plus ``model_loads=`` / ``model_tables=``); the
    device executors take the pipeline arguments (``serve_fn, params,
    canvas_m, canvas_n, ...``, plus ``models=``).  ``max_inflight`` and
    the other-substrate model kwargs are accepted—and dropped—where they
    do not apply, so one config dict can drive any name.
    """
    from repro.core.registry import lookup

    cls = lookup("executor", _EXECUTORS, name)
    device_only = {"fuse", "tokens_fn", "embed_kernel", "embed_bias",
                   "patch"}
    if cls is SimExecutor:
        drop = {"max_inflight", "models"} | device_only
    elif cls is AsyncDeviceExecutor:
        drop = {"model_loads", "model_tables"}
    else:
        drop = {"max_inflight", "model_loads", "model_tables"}
    return cls(**{k: v for k, v in cfg.items() if k not in drop})


# ------------------------------------------------------------ event loop ----

class ServingEngine:
    """The one event loop.  Feed arrivals; timers and completions fire at
    their scheduled engine times; fired invocations run on the executor.

    ``clock`` defaults to a fresh :class:`VirtualClock` (simulation /
    replay).  Pass a :class:`~repro.core.clock.WallClock` for live
    serving: the engine then sleeps to each event instant instead of
    jumping, and in-flight async device work completes during those
    waits.

    ``ingestion_window`` bounds the backlog the engine is willing to
    accumulate, in patches: queued-but-unfired patches in the pool plus
    patches inside unresolved invocations.  The engine never refuses an
    offer — the bound is advisory, read by live sources
    (:mod:`repro.sources`) through :meth:`overloaded`, which respond by
    dropping frames or degrading RoI quality.  ``None`` (default)
    disables the signal: trace replay ingests everything, as before.
    """

    def __init__(self, pool, executor, clock: Optional[Clock] = None,
                 check_invariants: bool = False,
                 ingestion_window: Optional[int] = None):
        if ingestion_window is not None and ingestion_window < 1:
            raise ValueError(f"ingestion_window must be >= 1, got "
                             f"{ingestion_window}")
        self.pool = pool
        self.executor = executor
        self.clock = clock if clock is not None else VirtualClock()
        self.check_invariants = check_invariants
        self.ingestion_window = ingestion_window
        self.backlog_high_water = 0
        self.outcomes: List[PatchOutcome] = []
        self.invocations: List[Invocation] = []
        self.completions: List[Completion] = []
        # arrival bookkeeping lives in reused slots: _slot_patch holds the
        # strong patch ref (so an id() cannot be recycled while its entry
        # is live) and _slot_t the arrival time; delivered outcomes clear
        # the slot onto the free list for the next arrival.  The table
        # therefore stays sized to the *peak backlog*, not the trace
        # length, and ingestion does one list write per arrival instead
        # of growing two dicts.
        self._slot_patch: List[Optional[Patch]] = []
        self._slot_t: List[float] = []
        self._free_slots: List[int] = []
        self._slot_of: Dict[int, int] = {}    # id(patch) -> live slot
        self.arrivals_total = 0
        # incremental backlog counters: every offered patch increments
        # _queued, firing moves its count to _inflight_count, delivery
        # retires it — so backlog() is O(1) per read instead of walking
        # the pool queues plus every unresolved invocation on *each*
        # arrival (the per-event cost that capped fleet-scale ingestion)
        self._queued = 0
        self._inflight_count = 0
        # ready() is resolved once: the per-event getattr on the hot
        # path was measurable at fleet arrival rates
        self._ready_probe = getattr(executor, "ready", None)
        self._scheduled: List = []   # heap of (t_finish, seq, ExecHandle)
        self._inflight: collections.deque = collections.deque()
        self._event_seq = 0
        self._last_async_finish: Dict[int, float] = {}   # per worker
        self.inflight_high_water = 0

    @property
    def now(self) -> float:
        """Engine time of the last event processed."""
        return self.clock.now()

    # ----------------------------------------------------------- feeding ----

    def run(self, arrivals: Sequence[Arrival]) -> List[PatchOutcome]:
        """Drive a whole (sorted-by-``t_arrive``) arrival trace to empty."""
        self.offer_batch(arrivals)
        self.finish()
        return self.outcomes

    def serve(self, source) -> List[PatchOutcome]:
        """Pull loop over a :mod:`repro.sources` source.

        The source's event iterator receives *this engine* as its
        feedback handle: between frames it reads :meth:`overloaded` /
        :meth:`backlog` and throttles itself (drop / degrade).  With a
        trace source (backpressure ignored) this is event-for-event
        identical to :meth:`run` on the same arrivals — pinned by the
        boundary-identity test.
        """
        for arr in source.events(self):
            self.offer(arr)
        self.finish()
        return self.outcomes

    def offer(self, arrival: Arrival):
        """One arrival: first fire everything due strictly before it."""
        self.advance(arrival.t_arrive)
        self.clock.advance_to(arrival.t_arrive)
        self._ingest(arrival)

    def offer_batch(self, arrivals: Sequence[Arrival]):
        """Ingest a run of arrivals (sorted by ``t_arrive``) in one call.

        Semantically identical to :meth:`offer` in a loop — pinned by a
        regression test — but skips the per-arrival event probe
        (completion harvest + timer scan + heap peek) whenever no timer
        or scheduled completion is due before the arrival, which is the
        common case inside a fleet shard.  Arrivals fall back to the
        full :meth:`offer` path while async work is in flight, where the
        per-event harvest is load-bearing.
        """
        for arr in arrivals:
            if self._ready_probe is not None and self._inflight:
                self.offer(arr)
                continue
            t = arr.t_arrive
            if self._next_event() < t:
                self.advance(t)
            self.clock.advance_to(t)
            self._ingest(arr)

    def _ingest(self, arrival: Arrival):
        """Arrival bookkeeping + batcher feed (clock already advanced)."""
        patch = arrival.patch
        if self._free_slots:
            slot = self._free_slots.pop()
            self._slot_patch[slot] = patch
            self._slot_t[slot] = arrival.t_arrive
        else:
            slot = len(self._slot_patch)
            self._slot_patch.append(patch)
            self._slot_t.append(arrival.t_arrive)
        self._slot_of[id(patch)] = slot
        self.arrivals_total += 1
        self._queued += 1
        for inv in self.pool.on_patch(arrival.t_arrive, patch):
            self._dispatch(inv)
        backlog = self._queued + self._inflight_count
        if backlog > self.backlog_high_water:
            self.backlog_high_water = backlog
        if self.check_invariants:
            depth = getattr(self.pool, "queue_depth", None)
            if depth is not None:
                assert self._queued == depth(), (self._queued, depth())

    def _next_event(self) -> float:
        """Engine time of the next due timer or scheduled completion."""
        t = self.pool.next_timer()
        if self._scheduled:
            t_comp = self._scheduled[0][0]
            if t_comp < t:
                return t_comp
        return t

    # ------------------------------------------------- ingestion window ----

    def queued_patches(self) -> int:
        """Patches accepted but not yet fired (pool queues)."""
        return self._queued

    def inflight_patches(self) -> int:
        """Patches inside unresolved invocations (scheduled + in flight)."""
        return self._inflight_count

    def backlog(self) -> int:
        """Total unfinished patches — the backpressure quantity live
        sources compare against ``ingestion_window``.  O(1): maintained
        incrementally at offer / dispatch / delivery.  The counters
        assume the batcher contract that every offered patch eventually
        leaves through a fired invocation (true of every in-repo
        batcher); ``check_invariants`` cross-checks against the pool's
        authoritative queue depth on each arrival."""
        return self._queued + self._inflight_count

    def overloaded(self) -> bool:
        """True when the backlog has filled the ingestion window."""
        return (self.ingestion_window is not None
                and self.backlog() >= self.ingestion_window)

    def advance(self, t: float):
        """Process every timer/completion event scheduled before ``t``.

        Tie rule (regression-pinned): a completion and a timer at the
        same instant deliver the completion first.
        """
        while True:
            self._harvest_ready()
            t_timer = self.pool.next_timer()
            t_comp = self._scheduled[0][0] if self._scheduled else math.inf
            t_next = min(t_timer, t_comp)
            if t_next >= t:
                return
            self.clock.advance_to(t_next)
            if t_comp <= t_timer:
                self._deliver_scheduled()
            else:
                fired = self.pool.poll(t_timer)
                if fired is None:       # defensive: a policy may decline
                    return
                self._dispatch(fired)

    def finish(self, t_end: Optional[float] = None):
        """Drain timers at their scheduled times, flush stragglers, and
        deliver every remaining completion."""
        self.advance(math.inf)
        t = self.now if t_end is None else t_end
        while True:
            fired = self.pool.flush(t)
            if fired is None:
                break
            self._dispatch(fired)
        while self._inflight:
            self._resolve_one()
        while self._scheduled:
            self.clock.advance_to(self._scheduled[0][0])
            self._deliver_scheduled()

    # --------------------------------------------------------- internals ----

    def _dispatch(self, inv: Invocation):
        # canvas-less invocations are legitimate only for batchers that
        # bill via cost_canvases (the padded-tile baselines); a canvas-
        # packing batcher emitting patches without canvases is a bug
        if self.check_invariants and inv.cost_canvases is None:
            validate(inv.canvases)
            # every queued patch must be placed exactly once (the unstitch
            # gather relies on this); checked on the packing itself so the
            # simulation never pays for device record packing
            placed = sorted(p.patch_idx for c in inv.canvases
                            for p in c.placements)
            assert placed == list(range(len(inv.patches))), placed
        self.invocations.append(inv)
        n = len(inv.patches)
        self._queued -= n
        self._inflight_count += n
        bound = getattr(self.executor, "max_inflight", None)
        if bound is not None:
            # make room before submitting (the submit below may pin
            # device memory for its canvases): take any already-finished
            # handle first, and only block on the oldest when none is
            while len(self._inflight) >= bound:
                self._resolve_one()
        handle = self._submit(inv)
        self._event_seq += 1
        handle.seq = self._event_seq
        if handle.model is None:
            handle.model = inv.model
        if handle.t_finish is not None:
            heapq.heappush(self._scheduled,
                           (handle.t_finish, self._event_seq, handle))
        else:
            self._inflight.append(handle)
            self.inflight_high_water = max(self.inflight_high_water,
                                           len(self._inflight))

    def _submit(self, inv: Invocation) -> ExecHandle:
        submit = getattr(self.executor, "submit", None)
        if submit is not None:
            return submit(inv)
        comp = self.executor.execute(inv)          # legacy executor
        return ExecHandle(inv, t_finish=comp.t_finish, completion=comp)

    @staticmethod
    def _delivery_order(handle: ExecHandle):
        """Pinned completion tie-break: worker index, then submit seq —
        so multi-worker replays deliver simultaneously-ready handles in a
        reproducible order (regression-tested)."""
        return (handle.worker, handle.seq)

    def _harvest_ready(self):
        """Deliver async completions the device has already finished.

        Non-blocking: *every* in-flight handle is probed, not just the
        FIFO head — with a worker pool (or any out-of-order substrate) a
        slow batch at the head must not pin completed later batches in
        flight (head-of-line harvest bug, regression-tested).  Handles
        ready at the same harvest deliver in ``(worker, seq)`` order."""
        ready = self._ready_probe
        if ready is None:
            return
        while True:
            done = [h for h in self._inflight if ready(h)]
            if not done:
                return
            for handle in sorted(done, key=self._delivery_order):
                self._inflight.remove(handle)
                self._resolve_inflight(handle)

    def _resolve_one(self):
        """Retire one in-flight handle: any already-ready handle first
        (lowest ``(worker, seq)``), else block on the FIFO head."""
        ready = self._ready_probe
        if ready is not None:
            done = [h for h in self._inflight if ready(h)]
            if done:
                handle = min(done, key=self._delivery_order)
                self._inflight.remove(handle)
                self._resolve_inflight(handle)
                return
        self._resolve_inflight(self._inflight.popleft())

    def _resolve_inflight(self, handle: ExecHandle):
        comp = self.executor.resolve(handle)
        # async finishes are measured on the device's own wall timer;
        # clamp monotone *per worker* — a worker is a serial queue, so
        # its dispatches really do finish in submit order and the clamp
        # only smooths timer jitter.  Across workers finishes genuinely
        # interleave: a global clamp would inflate the recorded latency
        # (and fabricate SLO violations) for a fast worker's completion
        # delivered after a slow worker's.
        last = self._last_async_finish.get(handle.worker, 0.0)
        comp.t_finish = max(last, comp.t_finish)
        self._last_async_finish[handle.worker] = comp.t_finish
        self._deliver(comp)

    def _deliver_scheduled(self):
        _, _, handle = heapq.heappop(self._scheduled)
        self._deliver(self.executor.resolve(handle))

    def _deliver(self, comp: Completion):
        """Completion delivery: executor bookkeeping, outcome recording,
        then batcher feedback — all observing the *actual* finish."""
        on_complete = getattr(self.executor, "on_complete", None)
        if on_complete is not None:
            on_complete(comp)
        inv = comp.invocation
        if comp.model is None:
            comp.model = inv.model
        self._inflight_count -= len(inv.patches)
        for p in inv.patches:
            slot = self._slot_of.pop(id(p), None)
            if slot is None:
                t_arrive = inv.t_submit
            else:
                t_arrive = self._slot_t[slot]
                self._slot_patch[slot] = None
                self._free_slots.append(slot)
            self.outcomes.append(
                PatchOutcome(p, t_arrive, inv.t_submit, comp.t_finish,
                             model=comp.model))
        on_result = getattr(self.pool, "on_result", None)
        if on_result is not None:
            on_result(inv, comp.t_finish)
        # the executor's on_complete is the delivery point for outputs;
        # dropping the payload here keeps the retained completion log
        # light — otherwise a long device run would pin every routed
        # pixel batch for the engine's lifetime
        comp.outputs = None
        self.completions.append(comp)
