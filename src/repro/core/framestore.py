"""Striped-lock refcounted frame store for the device executors.

The frame store keeps each source frame's pixels alive exactly as long
as patches cut from it are still in flight: ``add`` registers a frame
with a reference count (one per patch), ``release`` drops one reference
at completion delivery, and the frame is evicted when the pool-wide last
patch has been routed.  Historically this was two plain dicts inside
:class:`~repro.core.engine.DeviceExecutor`; the parallel fleet runtime
(:mod:`repro.core.parallel`) runs shard engines on concurrent threads
that share one store (patches of one frame can route to *different*
shards), so the dicts move behind stripe locks:

* frame ids hash onto ``n_stripes`` independent ``(lock, frames, refs)``
  stripes, so threads touching different frames almost never contend —
  the store scales with stripe count instead of serializing every
  ``get`` behind one global lock;
* add / get / release on *one* frame serialize on its stripe, so the
  refcount decrements stay exact and eviction fires exactly once no
  matter which shard thread routes the last patch.

``snapshot()`` / ``refs_snapshot()`` materialize plain-dict views for
tests and diagnostics; the hot path (``get`` per patch in
``DeviceExecutor._launch``) never copies.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

__all__ = ["FrameStore"]


class FrameStore:
    """Refcounted pixel store with striped locks (thread-safe)."""

    def __init__(self, n_stripes: int = 16):
        if n_stripes < 1:
            raise ValueError(f"n_stripes must be >= 1, got {n_stripes}")
        self.n_stripes = n_stripes
        self._stripes = [(threading.Lock(), {}, {})
                         for _ in range(n_stripes)]

    def _stripe(self, frame_id):
        return self._stripes[hash(frame_id) % self.n_stripes]

    def add(self, frame_id, pixels, n_patches: int) -> None:
        """Register a frame the edge cut ``n_patches`` patches from.

        Frames that produced no patches are never referenced again and
        are not stored at all.
        """
        if n_patches <= 0:
            return
        lock, frames, refs = self._stripe(frame_id)
        with lock:
            frames[frame_id] = pixels
            refs[frame_id] = refs.get(frame_id, 0) + n_patches

    def get(self, frame_id) -> Optional[object]:
        """The frame's pixels, or None once evicted / never stored."""
        lock, frames, _ = self._stripe(frame_id)
        with lock:
            return frames.get(frame_id)

    def release(self, frame_id) -> None:
        """Drop one patch reference; evict the frame at zero."""
        lock, frames, refs = self._stripe(frame_id)
        with lock:
            left = refs.get(frame_id)
            if left is None:
                return
            if left <= 1:
                del refs[frame_id]
                frames.pop(frame_id, None)
            else:
                refs[frame_id] = left - 1

    def __len__(self) -> int:
        return sum(len(frames) for _, frames, _ in self._stripes)

    def __contains__(self, frame_id) -> bool:
        lock, frames, _ = self._stripe(frame_id)
        with lock:
            return frame_id in frames

    # ------------------------------------------------------ diagnostics ----

    def snapshot(self) -> Dict:
        """Point-in-time ``{frame_id: pixels}`` copy (tests/diagnostics;
        the hot path reads through :meth:`get`)."""
        out: Dict = {}
        for lock, frames, _ in self._stripes:
            with lock:
                out.update(frames)
        return out

    def refs_snapshot(self) -> Dict:
        """Point-in-time ``{frame_id: live patch refs}`` copy."""
        out: Dict = {}
        for lock, _, refs in self._stripes:
            with lock:
                out.update(refs)
        return out
