"""Latency Estimator (Section III-C).

Profiles canvas-batch inference offline per batch size, stores (mu, sigma),
and serves the conservative slack ``T_slack = mu + k * sigma`` (k = 3 in
the paper).  Two profile sources:

* ``AnalyticalLatencyModel`` — deterministic roofline-derived time for the
  TPU target: t = max(flops/peak, bytes/hbm_bw) + fixed overhead, with a
  configured jitter fraction as sigma.  Used by the simulator so results
  are hardware-parameterized and reproducible.
* ``measure`` — times a real callable (the CPU detector in the examples),
  the paper's 1000-iteration offline profiling, scaled down.

``OnlineLatencyTable`` closes the loop at serving time: it starts as the
profiled table and folds observed per-worker, per-batch completion times
back into ``mu_sigma`` via EWMA, so the firing decision tracks the device
the system is actually running on instead of a stale offline profile.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.config import HardwareConfig


@dataclasses.dataclass
class LatencyTable:
    """batch_size -> (mu, sigma) with linear inter/extrapolation."""

    table: Dict[int, Tuple[float, float]]
    slack_sigmas: float = 3.0
    #: interpolation memo — ``mu_sigma`` sits on the per-arrival firing
    #: path (every probe calls ``t_slack``), and the sorted()-per-miss
    #: lookup was measurable at fleet arrival rates.  The profile is
    #: treated as frozen after construction (nothing in-repo mutates
    #: ``table`` in place); the size guard invalidates the memo if a
    #: caller nevertheless adds profile points.
    _miss_cache: Dict[int, Tuple[float, float]] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    _cache_size: int = dataclasses.field(default=-1, init=False,
                                         repr=False, compare=False)

    def mu_sigma(self, batch: int) -> Tuple[float, float]:
        hit = self.table.get(batch)
        if hit is not None:
            return hit
        if self._cache_size == len(self.table):
            memo = self._miss_cache.get(batch)
            if memo is not None:
                return memo
        else:
            self._miss_cache.clear()
            self._cache_size = len(self.table)
        out = self._interpolate(batch)
        self._miss_cache[batch] = out
        return out

    def _interpolate(self, batch: int) -> Tuple[float, float]:
        keys = sorted(self.table)
        if not keys:
            raise ValueError("empty latency table")
        if batch <= keys[0]:
            # clamp, don't extrapolate through the origin: below the
            # smallest profiled point the fixed per-invocation overhead
            # dominates, and ``mu * batch / k`` would drop it entirely,
            # making t_slack over-optimistic (under-reported SLO
            # violations).  The smallest profiled mu is a conservative
            # floor for any smaller batch.
            return self.table[keys[0]]
        if batch >= keys[-1]:
            # extrapolate from the last two points (throughput regime)
            if len(keys) == 1:
                k = keys[0]
                mu, sg = self.table[k]
                return mu * batch / k, sg * batch / k
            k0, k1 = keys[-2], keys[-1]
            (m0, s0), (m1, s1) = self.table[k0], self.table[k1]
            slope = (m1 - m0) / (k1 - k0)
            return m1 + slope * (batch - k1), max(s0, s1)
        lo = max(k for k in keys if k <= batch)
        hi = min(k for k in keys if k >= batch)
        (m0, s0), (m1, s1) = self.table[lo], self.table[hi]
        f = (batch - lo) / (hi - lo)
        return m0 + f * (m1 - m0), s0 + f * (s1 - s0)

    def t_slack(self, batch: int) -> float:
        """Conservative inference-time estimate for a batch of canvases."""
        if batch <= 0:
            return 0.0
        mu, sigma = self.mu_sigma(batch)
        return mu + self.slack_sigmas * sigma

    # ------------------------------------------------------ serialization ----
    # ``dataclasses.asdict`` alone does not survive a JSON round-trip:
    # json stringifies the int batch keys and list-ifies the (mu, sigma)
    # tuples, so a reloaded table would miss every exact-key lookup.
    # These helpers are the benchmark-JSON logging surface.

    def to_dict(self) -> dict:
        return {"kind": "profile",
                "slack_sigmas": self.slack_sigmas,
                "table": {str(k): [float(m), float(s)]
                          for k, (m, s) in sorted(self.table.items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "LatencyTable":
        return cls({int(k): (float(m), float(s))
                    for k, (m, s) in d["table"].items()},
                   slack_sigmas=float(d.get("slack_sigmas", 3.0)))


class OnlineLatencyTable:
    """A latency estimator that refreshes itself from delivered completions.

    Starts as (and, with zero observations, is *exactly*) the profiled
    ``seed`` table — including PR 2's clamp below the smallest profiled
    point — then folds every observed ``(batch, elapsed)`` completion back
    in:

    * **per-batch EWMA** — batch sizes that have been observed directly
      serve an EWMA mean and an EWMA-variance-derived sigma (floored at
      the drift-scaled seed sigma, so the estimate never becomes
      overconfident just because recent observations happened to agree);
    * **global drift ratio** — batch sizes *not* yet observed serve the
      seed estimate scaled by the EWMA of observed/seed ratios, clamped to
      ``ratio_bounds`` so one wild measurement cannot blow up (or zero
      out) the whole table.

    Per-worker drift ratios are tracked alongside (``drift(worker=i)``)
    so a heterogeneous pool is visible to diagnostics and placement,
    while the served estimate aggregates all workers — the invoker cannot
    know which worker its next batch will land on.

    Non-finite or non-positive observations are rejected (counted in
    ``n_rejected``), which keeps every served ``(mu, sigma)`` finite with
    ``mu > 0`` and ``sigma >= 0`` under adversarial observation streams —
    property-pinned in the tests.

    The class duck-types :class:`LatencyTable` (``mu_sigma`` /
    ``t_slack`` / ``slack_sigmas``): hand the *same instance* to the
    invokers and to the executor that calls :meth:`observe`, and firing
    decisions track real device speed with no further wiring.
    """

    _TINY = 1e-12

    def __init__(self, seed: LatencyTable, alpha: float = 0.25,
                 ratio_bounds: Tuple[float, float] = (0.05, 50.0)):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        lo, hi = ratio_bounds
        if not 0.0 < lo <= hi:
            raise ValueError(f"bad ratio_bounds {ratio_bounds}")
        self.seed = seed
        self.alpha = alpha
        self.ratio_bounds = ratio_bounds
        self._mu: Dict[int, float] = {}
        self._var: Dict[int, float] = {}
        self._count: Dict[int, int] = {}
        self._ratio: Optional[float] = None
        self._worker_ratio: Dict[object, float] = {}
        self.n_observations = 0
        self.n_rejected = 0
        # Shard threads of the parallel fleet runtime fold observations
        # and serve estimates concurrently; the EWMA recurrences are
        # read-modify-write, so both sides take this lock.  RLock keeps
        # mu_sigma -> seed fallbacks reentrant-safe.
        self._lock = threading.RLock()

    @property
    def slack_sigmas(self) -> float:
        return self.seed.slack_sigmas

    def _clamped(self, ratio: Optional[float]) -> float:
        if ratio is None:
            return 1.0
        lo, hi = self.ratio_bounds
        return min(max(ratio, lo), hi)

    def drift(self, worker: Optional[object] = None) -> float:
        """Clamped EWMA of observed/seed latency (1.0 = profile holds).

        ``worker=None`` aggregates every worker; a worker with no
        observations reports the aggregate drift."""
        with self._lock:
            if worker is not None and worker in self._worker_ratio:
                return self._clamped(self._worker_ratio[worker])
            return self._clamped(self._ratio)

    def observe(self, batch: int, elapsed: float,
                worker: Optional[object] = None,
                model: Optional[str] = None) -> bool:
        """Fold one delivered completion in.  Returns False (and changes
        nothing) for observations that are non-finite, non-positive, or
        for empty batches.  Valid observations are clamped into
        ``ratio_bounds`` times the seed estimate before the EWMA update,
        so a single wild measurement moves the table by at most the
        configured drift range and every internal statistic stays finite
        (no overflow through the EWMA recurrences).

        ``model`` is accepted (and ignored) so this single-model
        estimator and the per-model :class:`LatencyBank` are drop-in
        interchangeable behind the worker pool's feedback hook."""
        try:
            elapsed = float(elapsed)
        except (TypeError, ValueError):
            with self._lock:
                self.n_rejected += 1
            return False
        if batch < 1 or not math.isfinite(elapsed) or elapsed <= 0.0:
            with self._lock:
                self.n_rejected += 1
            return False
        with self._lock:
            self.n_observations += 1
            a = self.alpha
            lo, hi = self.ratio_bounds
            seed_mu = max(self.seed.mu_sigma(batch)[0], self._TINY)
            elapsed = min(max(elapsed, lo * seed_mu), hi * seed_mu)
            if batch not in self._mu:
                self._mu[batch] = elapsed
                self._var[batch] = 0.0
                self._count[batch] = 1
            else:
                delta = elapsed - self._mu[batch]
                self._mu[batch] += a * delta
                # EWMA variance (West): decay old spread, add the new
                # deviation's contribution
                self._var[batch] = (1.0 - a) * (self._var[batch]
                                                + a * delta * delta)
                self._count[batch] += 1
            r = elapsed / seed_mu             # in [lo, hi] by construction
            self._ratio = r if self._ratio is None else (
                self._ratio + a * (r - self._ratio))
            if worker is not None:
                prev = self._worker_ratio.get(worker)
                self._worker_ratio[worker] = r if prev is None else (
                    prev + a * (r - prev))
        return True

    def mu_sigma(self, batch: int) -> Tuple[float, float]:
        with self._lock:
            if self.n_observations == 0:
                return self.seed.mu_sigma(batch)  # exactly the seed
            r = self._clamped(self._ratio)
            seed_mu, seed_sigma = self.seed.mu_sigma(batch)
            if batch in self._mu:
                mu = max(self._mu[batch], self._TINY)
                sigma = max(math.sqrt(max(self._var[batch], 0.0)),
                            seed_sigma * r, 0.0)
                return mu, sigma
            return max(seed_mu * r, self._TINY), max(seed_sigma * r, 0.0)

    def t_slack(self, batch: int) -> float:
        if batch <= 0:
            return 0.0
        mu, sigma = self.mu_sigma(batch)
        return mu + self.slack_sigmas * sigma

    # ------------------------------------------------------ serialization ----

    def to_dict(self) -> dict:
        """JSON-safe spec of this estimator: the seed profile plus the
        EWMA knobs.  Learned state (per-batch EWMAs, drift ratios) is
        deliberately *not* serialized — a config log describes how the
        estimator was built, and a deserialized estimator starts exactly
        at its seed, the same contract as a fresh construction."""
        return {"kind": "online",
                "seed": self.seed.to_dict(),
                "alpha": self.alpha,
                "ratio_bounds": list(self.ratio_bounds)}

    @classmethod
    def from_dict(cls, d: dict) -> "OnlineLatencyTable":
        return cls(LatencyTable.from_dict(d["seed"]),
                   alpha=float(d.get("alpha", 0.25)),
                   ratio_bounds=tuple(d.get("ratio_bounds", (0.05, 50.0))))


class LatencyBank:
    """Per-model latency estimates behind one estimator interface.

    ``tables`` maps a registry model name to its estimator — a
    :class:`LatencyTable` or (for the feedback loop) an
    :class:`OnlineLatencyTable` per model.  The bank duck-types the
    worker pool's ``estimator`` contract (``observe`` / ``drift``):
    observations route to the invocation's model's table, so two SLO
    classes running different networks each track their *own* device
    speed and ``t_slack`` / AIMD stay correct per model — a heavy
    model's drift never pollutes a light model's firing decision.

    ``observe`` with ``model=None`` (an untagged invocation) routes to
    the ``default`` table — the sole entry when the bank holds exactly
    one, else nowhere (returns False): attributing an unattributed
    observation to an arbitrary model would corrupt that model's EWMA.
    """

    def __init__(self, tables: Dict[str, object],
                 default: Optional[str] = None):
        if not tables:
            raise ValueError("LatencyBank needs at least one table")
        self.tables: Dict[str, object] = dict(tables)
        if default is not None and default not in self.tables:
            from repro.core.registry import unknown_name
            raise unknown_name("model", default, self.tables)
        if default is None and len(self.tables) == 1:
            default = next(iter(self.tables))
        self.default = default

    def table(self, model: Optional[str]):
        """The estimator for one model (``None``: the default table)."""
        from repro.core.registry import lookup
        if model is None:
            model = self.default
        return lookup("model", self.tables, model)

    def observe(self, batch: int, elapsed: float,
                worker: Optional[object] = None,
                model: Optional[str] = None) -> bool:
        name = model if model is not None else self.default
        tbl = self.tables.get(name)
        observe = getattr(tbl, "observe", None)
        if observe is None:
            return False
        return observe(batch, elapsed, worker=worker)

    def drift(self, worker: Optional[object] = None,
              model: Optional[str] = None) -> float:
        """One model's drift, or (``model=None``) the mean drift over
        models that track one — the pool-diagnostics aggregate."""
        if model is not None:
            tbl = self.table(model)
            drift = getattr(tbl, "drift", None)
            return drift(worker=worker) if drift is not None else 1.0
        drifts = [t.drift(worker=worker) for t in self.tables.values()
                  if hasattr(t, "drift")]
        if not drifts:
            return 1.0
        return sum(drifts) / len(drifts)

    # ------------------------------------------------------ serialization ----

    def to_dict(self) -> dict:
        return {"kind": "bank",
                "default": self.default,
                "tables": {name: t.to_dict()
                           for name, t in sorted(self.tables.items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "LatencyBank":
        return cls({name: latency_from_dict(t)
                    for name, t in d["tables"].items()},
                   default=d.get("default"))


def latency_from_dict(d: dict):
    """Inverse of the latency ``to_dict`` family, keyed on the embedded
    ``kind`` tag (``profile`` | ``online`` | ``bank``)."""
    from repro.core.registry import lookup

    kind = d.get("kind", "profile")
    loaders = {"profile": LatencyTable.from_dict,
               "online": OnlineLatencyTable.from_dict,
               "bank": LatencyBank.from_dict}
    return lookup("latency spec kind", loaders, kind)(d)


@dataclasses.dataclass(frozen=True)
class AnalyticalLatencyModel:
    """Roofline latency for a canvas batch on the serving slice."""

    flops_per_canvas: float           # fwd FLOPs for one M x N canvas
    bytes_per_canvas: float           # HBM traffic for one canvas
    weight_bytes: float               # model weights read once per batch
    chips: int = 4                    # function slice size
    hw: HardwareConfig = HardwareConfig()
    overhead_s: float = 0.004         # dispatch/launch overhead
    jitter_frac: float = 0.05         # sigma = jitter_frac * mu
    mxu_eff: float = 0.55             # achievable fraction of peak

    def mu_sigma(self, batch: int) -> Tuple[float, float]:
        fl = self.flops_per_canvas * batch / (
            self.chips * self.hw.peak_flops * self.mxu_eff)
        by = (self.bytes_per_canvas * batch + self.weight_bytes) / (
            self.chips * self.hw.hbm_bw)
        mu = max(fl, by) + self.overhead_s
        return mu, self.jitter_frac * mu

    def build_table(self, max_batch: int = 16,
                    slack_sigmas: float = 3.0) -> LatencyTable:
        return LatencyTable(
            {b: self.mu_sigma(b) for b in range(1, max_batch + 1)},
            slack_sigmas=slack_sigmas)


def measure(fn: Callable[[int], object], batch_sizes, iters: int = 30,
            warmup: int = 3, slack_sigmas: float = 3.0,
            sync: Optional[Callable[[object], None]] = None) -> LatencyTable:
    """Offline profiling of a real callable (paper: 1000 iterations).

    ``fn(batch)`` may dispatch asynchronously (jax jit returns before the
    computation finishes); pass its result-synchronisation as ``sync``
    (e.g. ``jax.block_until_ready``) so the wait lands inside the timed
    region — bare ``perf_counter`` around an async dispatch measures
    dispatch, not compute.
    """
    table = {}
    for b in batch_sizes:
        for _ in range(warmup):
            r = fn(b)
            if sync is not None:
                sync(r)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            r = fn(b)
            if sync is not None:
                sync(r)
            ts.append(time.perf_counter() - t0)
        table[b] = (float(np.mean(ts)), float(np.std(ts)))
    return LatencyTable(table, slack_sigmas=slack_sigmas)


def detector_flops(n_tokens: int, patch: int, n_layers: int, d_model: int,
                   d_ff: int) -> float:
    """Forward FLOPs of the ViT detector over ``n_tokens`` patch tokens.

    Attention has the quadratic 2*S*d score/context term, so a full 4K
    frame as one input costs *more* than proportionally vs tiled canvases
    — exactly the effect that makes the Masked Frame baseline slow."""
    s = n_tokens
    attn = 4 * d_model * d_model + 2 * s * d_model  # per token: proj + scores
    mlp = 2 * d_model * d_ff * 2
    per_token = 2 * (attn + mlp)
    embed = 2 * 3 * patch * patch * d_model
    return s * (n_layers * per_token + embed)


def detector_latency_model(res_h: int, res_w: int, *, patch: int = 32,
                           n_layers: int = 12, d_model: int = 768,
                           d_ff: int = 3072, chips: int = 4,
                           hw: Optional[HardwareConfig] = None,
                           overhead_s: float = 0.004,
                           jitter_frac: float = 0.05
                           ) -> AnalyticalLatencyModel:
    """Analytical model for the ViT detector on inputs of res_h x res_w."""
    tokens = (res_h // patch) * (res_w // patch)
    flops = detector_flops(tokens, patch, n_layers, d_model, d_ff)
    act_bytes = res_h * res_w * 3 * 4 + 8 * n_layers * tokens * d_model * 2
    d = d_model
    weight_bytes = n_layers * (4 * d * d + 2 * d * d_ff) * 2
    return AnalyticalLatencyModel(
        flops_per_canvas=flops, bytes_per_canvas=act_bytes,
        weight_bytes=weight_bytes, chips=chips,
        hw=hw or HardwareConfig(), overhead_s=overhead_s,
        jitter_frac=jitter_frac)
