"""Latency Estimator (Section III-C).

Profiles canvas-batch inference offline per batch size, stores (mu, sigma),
and serves the conservative slack ``T_slack = mu + k * sigma`` (k = 3 in
the paper).  Two profile sources:

* ``AnalyticalLatencyModel`` — deterministic roofline-derived time for the
  TPU target: t = max(flops/peak, bytes/hbm_bw) + fixed overhead, with a
  configured jitter fraction as sigma.  Used by the simulator so results
  are hardware-parameterized and reproducible.
* ``measure`` — times a real callable (the CPU detector in the examples),
  the paper's 1000-iteration offline profiling, scaled down.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.config import HardwareConfig


@dataclasses.dataclass
class LatencyTable:
    """batch_size -> (mu, sigma) with linear inter/extrapolation."""

    table: Dict[int, Tuple[float, float]]
    slack_sigmas: float = 3.0

    def mu_sigma(self, batch: int) -> Tuple[float, float]:
        if batch in self.table:
            return self.table[batch]
        keys = sorted(self.table)
        if not keys:
            raise ValueError("empty latency table")
        if batch <= keys[0]:
            # clamp, don't extrapolate through the origin: below the
            # smallest profiled point the fixed per-invocation overhead
            # dominates, and ``mu * batch / k`` would drop it entirely,
            # making t_slack over-optimistic (under-reported SLO
            # violations).  The smallest profiled mu is a conservative
            # floor for any smaller batch.
            return self.table[keys[0]]
        if batch >= keys[-1]:
            # extrapolate from the last two points (throughput regime)
            if len(keys) == 1:
                k = keys[0]
                mu, sg = self.table[k]
                return mu * batch / k, sg * batch / k
            k0, k1 = keys[-2], keys[-1]
            (m0, s0), (m1, s1) = self.table[k0], self.table[k1]
            slope = (m1 - m0) / (k1 - k0)
            return m1 + slope * (batch - k1), max(s0, s1)
        lo = max(k for k in keys if k <= batch)
        hi = min(k for k in keys if k >= batch)
        (m0, s0), (m1, s1) = self.table[lo], self.table[hi]
        f = (batch - lo) / (hi - lo)
        return m0 + f * (m1 - m0), s0 + f * (s1 - s0)

    def t_slack(self, batch: int) -> float:
        """Conservative inference-time estimate for a batch of canvases."""
        if batch <= 0:
            return 0.0
        mu, sigma = self.mu_sigma(batch)
        return mu + self.slack_sigmas * sigma


@dataclasses.dataclass(frozen=True)
class AnalyticalLatencyModel:
    """Roofline latency for a canvas batch on the serving slice."""

    flops_per_canvas: float           # fwd FLOPs for one M x N canvas
    bytes_per_canvas: float           # HBM traffic for one canvas
    weight_bytes: float               # model weights read once per batch
    chips: int = 4                    # function slice size
    hw: HardwareConfig = HardwareConfig()
    overhead_s: float = 0.004         # dispatch/launch overhead
    jitter_frac: float = 0.05         # sigma = jitter_frac * mu
    mxu_eff: float = 0.55             # achievable fraction of peak

    def mu_sigma(self, batch: int) -> Tuple[float, float]:
        fl = self.flops_per_canvas * batch / (
            self.chips * self.hw.peak_flops * self.mxu_eff)
        by = (self.bytes_per_canvas * batch + self.weight_bytes) / (
            self.chips * self.hw.hbm_bw)
        mu = max(fl, by) + self.overhead_s
        return mu, self.jitter_frac * mu

    def build_table(self, max_batch: int = 16,
                    slack_sigmas: float = 3.0) -> LatencyTable:
        return LatencyTable(
            {b: self.mu_sigma(b) for b in range(1, max_batch + 1)},
            slack_sigmas=slack_sigmas)


def measure(fn: Callable[[int], object], batch_sizes, iters: int = 30,
            warmup: int = 3, slack_sigmas: float = 3.0,
            sync: Optional[Callable[[object], None]] = None) -> LatencyTable:
    """Offline profiling of a real callable (paper: 1000 iterations).

    ``fn(batch)`` may dispatch asynchronously (jax jit returns before the
    computation finishes); pass its result-synchronisation as ``sync``
    (e.g. ``jax.block_until_ready``) so the wait lands inside the timed
    region — bare ``perf_counter`` around an async dispatch measures
    dispatch, not compute.
    """
    table = {}
    for b in batch_sizes:
        for _ in range(warmup):
            r = fn(b)
            if sync is not None:
                sync(r)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            r = fn(b)
            if sync is not None:
                sync(r)
            ts.append(time.perf_counter() - t0)
        table[b] = (float(np.mean(ts)), float(np.std(ts)))
    return LatencyTable(table, slack_sigmas=slack_sigmas)


def detector_flops(n_tokens: int, patch: int, n_layers: int, d_model: int,
                   d_ff: int) -> float:
    """Forward FLOPs of the ViT detector over ``n_tokens`` patch tokens.

    Attention has the quadratic 2*S*d score/context term, so a full 4K
    frame as one input costs *more* than proportionally vs tiled canvases
    — exactly the effect that makes the Masked Frame baseline slow."""
    s = n_tokens
    attn = 4 * d_model * d_model + 2 * s * d_model  # per token: proj + scores
    mlp = 2 * d_model * d_ff * 2
    per_token = 2 * (attn + mlp)
    embed = 2 * 3 * patch * patch * d_model
    return s * (n_layers * per_token + embed)


def detector_latency_model(res_h: int, res_w: int, *, patch: int = 32,
                           n_layers: int = 12, d_model: int = 768,
                           d_ff: int = 3072, chips: int = 4,
                           hw: Optional[HardwareConfig] = None,
                           overhead_s: float = 0.004,
                           jitter_frac: float = 0.05
                           ) -> AnalyticalLatencyModel:
    """Analytical model for the ViT detector on inputs of res_h x res_w."""
    tokens = (res_h // patch) * (res_w // patch)
    flops = detector_flops(tokens, patch, n_layers, d_model, d_ff)
    act_bytes = res_h * res_w * 3 * 4 + 8 * n_layers * tokens * d_model * 2
    d = d_model
    weight_bytes = n_layers * (4 * d * d + 2 * d * d_ff) * 2
    return AnalyticalLatencyModel(
        flops_per_canvas=flops, bytes_per_canvas=act_bytes,
        weight_bytes=weight_bytes, chips=chips,
        hw=hw or HardwareConfig(), overhead_s=overhead_s,
        jitter_frac=jitter_frac)
