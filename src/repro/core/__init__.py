"""Tangram core: GMM RoI extraction, adaptive frame partitioning (Alg. 1),
patch stitching + SLO-aware batching (Alg. 2), latency/cost models."""
