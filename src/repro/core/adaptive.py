"""Completion-driven adaptive batching: AIMD over the invoker pool.

The offline latency table tells the invoker how long *inference* takes;
it cannot see what the platform adds on top — queueing behind busy
instances, cold starts, stragglers.  Under a sustained load step the
static configuration therefore keeps firing batches whose ``t_remain``
was computed against an optimistic world, and the tight SLO classes eat
the violations.

:class:`AdaptiveInvokerPool` closes the loop the way OCTOPINF-style
workload-aware servers do: every delivered completion (the engine calls
``on_result`` at *completion-delivery* time, so the signal is what
actually happened) updates two per-class knobs on the live invoker:

* ``max_canvases`` — classic AIMD.  A violation multiplies the class's
  canvas budget by ``decrease`` (smaller batches start sooner and run
  shorter); ``patience`` consecutive clean completions add ``increase``
  back, up to the configured ceiling, recovering consolidation once the
  platform catches up.
* ``margin`` — extra firing slack subtracted from ``t_remain``.  On a
  violation it jumps to the observed excess (actual completion latency
  minus the table's conservative estimate, or the deadline miss if
  larger): the class now fires early enough to absorb the queueing delay
  completions are reporting.  Sustained clean completions decay it
  geometrically so light load drifts back to the paper's Eqn. 8.

Per-class canvas geometry flows through the same factory the static pool
uses: :class:`ClassSpec` + :func:`pool_from_specs` give each SLO class
its own canvas size, latency table, and starting budget, with or without
the AIMD controller on top.

With an :class:`~repro.core.latency.OnlineLatencyTable` as a class's
latency source the two feedback loops compose instead of fighting:
sustained service-time drift folds into the table (so ``t_remain`` for
*future* batches moves with real device speed), while the margin absorbs
only the residual the estimator cannot see — the violation excess is
measured against the *current* estimate, not the snapshot taken when the
invocation fired.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional

from repro.core.engine import InvokerPool, slo_class
from repro.core.invoker import Invocation, SLOAwareInvoker
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch


@dataclasses.dataclass(frozen=True)
class AIMDConfig:
    """Knobs for the completion-feedback controller."""
    min_canvases: int = 1         # multiplicative-decrease floor
    max_canvases: Optional[int] = None   # additive-increase ceiling; None
                                  # caps at the class invoker's configured
                                  # static budget (the operator's memory
                                  # bound is never silently exceeded)
    increase: int = 1             # canvases added per clean streak
    decrease: float = 0.5         # budget multiplier on violation
    patience: int = 3             # clean completions per increase step
    margin_decay: float = 0.75    # margin multiplier per increase step
    margin_headroom: float = 1.5  # safety factor on the observed excess
                                  # (>1: firing exactly one excess earlier
                                  # lands finishes right on the deadline)


@dataclasses.dataclass
class ClassState:
    """Controller state for one SLO class."""
    max_canvases: int
    ceiling: int = 0
    margin: float = 0.0
    streak: int = 0
    completions: int = 0
    violations: int = 0


class AdaptiveInvokerPool(InvokerPool):
    """An :class:`~repro.core.engine.InvokerPool` whose per-class
    ``max_canvases`` / firing margin track delivered completions."""

    def __init__(self, make_invoker: Callable[[object], SLOAwareInvoker],
                 classify: Callable[[Patch], object] = slo_class,
                 cfg: Optional[AIMDConfig] = None,
                 model_of: Optional[Callable[[object],
                                             Optional[str]]] = None):
        super().__init__(make_invoker, classify, model_of=model_of)
        self.cfg = cfg or AIMDConfig()
        self.state: Dict[object, ClassState] = {}

    def _invoker(self, key: object) -> SLOAwareInvoker:
        inv = super()._invoker(key)
        if key not in self.state:
            ceiling = (self.cfg.max_canvases
                       if self.cfg.max_canvases is not None
                       else inv.max_canvases)
            self.state[key] = ClassState(max_canvases=inv.max_canvases,
                                         ceiling=ceiling, margin=inv.margin)
        return inv

    def on_result(self, inv: Invocation, t_finish: float):
        """Engine callback at completion delivery (not dispatch)."""
        invoker = self.invokers.get(inv.key)
        st = self.state.get(inv.key)
        if invoker is None or st is None or not inv.patches:
            return
        cfg = self.cfg
        st.completions += 1
        deadline = min(p.deadline for p in inv.patches)
        # what the platform added beyond the conservative inference
        # estimate — measured against the *current* estimate, not the
        # snapshot the invocation was scheduled with: with an
        # OnlineLatencyTable as the class's latency source, service-time
        # drift migrates into the table and the margin keeps absorbing
        # only what the estimator still cannot see (queueing, cold
        # starts), instead of double-counting the same delay
        est = max(inv.t_slack,
                  invoker.latency.t_slack(len(inv.canvases)
                                          or len(inv.patches)))
        excess = max(0.0, (t_finish - inv.t_submit) - est)
        if t_finish > deadline:
            st.violations += 1
            st.streak = 0
            st.max_canvases = max(cfg.min_canvases,
                                  int(st.max_canvases * cfg.decrease))
            miss = t_finish - deadline
            st.margin = max(st.margin,
                            cfg.margin_headroom * max(excess, miss))
        else:
            st.streak += 1
            if st.streak >= cfg.patience:
                st.streak = 0
                st.max_canvases = min(st.ceiling,
                                      st.max_canvases + cfg.increase)
                st.margin *= cfg.margin_decay
        invoker.max_canvases = st.max_canvases
        invoker.margin = st.margin


# -------------------------------------------------- per-class geometry ----

@dataclasses.dataclass(frozen=True)
class ClassSpec:
    """One SLO class's invoker recipe (geometry, latency, budget)."""
    canvas_m: int
    canvas_n: int
    latency: LatencyTable
    max_canvases: int = 8
    incremental: bool = True

    def build(self) -> SLOAwareInvoker:
        return SLOAwareInvoker(self.canvas_m, self.canvas_n, self.latency,
                               self.max_canvases,
                               incremental=self.incremental)


def pool_from_specs(specs: Mapping[object, ClassSpec],
                    default: Optional[ClassSpec] = None,
                    classify: Callable[[Patch], object] = slo_class,
                    adaptive: Optional[AIMDConfig] = None,
                    model_of: Optional[Callable[[object],
                                                Optional[str]]] = None
                    ) -> InvokerPool:
    """Pool with per-class canvas geometry, optionally AIMD-controlled.

    ``specs[key]`` builds class ``key``'s invoker; unknown keys fall back
    to ``default`` (the unified unknown-name ``ValueError`` surfaces a
    missing class early when no default is given).  Pass an
    :class:`AIMDConfig` to put the completion-feedback controller on top
    of every class; ``model_of`` tags fired invocations with their
    class's registry model (see :class:`~repro.core.engine.InvokerPool`).
    """
    def make(key):
        spec = specs.get(key, default)
        if spec is None:
            from repro.core.registry import unknown_name
            raise unknown_name("SLO class", key, specs)
        return spec.build()

    if adaptive is not None:
        return AdaptiveInvokerPool(make, classify, adaptive,
                                   model_of=model_of)
    return InvokerPool(make, classify, model_of=model_of)


def adaptive_uniform_pool(canvas_m: int, canvas_n: int,
                          latency: LatencyTable, max_canvases: int = 8,
                          incremental: bool = True,
                          classify: Optional[Callable[[Patch], object]] = None,
                          cfg: Optional[AIMDConfig] = None
                          ) -> AdaptiveInvokerPool:
    """AIMD counterpart of :func:`repro.core.engine.uniform_pool`: one
    shared geometry spec, per-class budgets/margins adapted online."""
    return AdaptiveInvokerPool(
        lambda key: SLOAwareInvoker(canvas_m, canvas_n, latency,
                                    max_canvases, incremental=incremental),
        classify=classify or (lambda p: None), cfg=cfg)
