"""Pluggable engine clocks: virtual time for simulation/replay, wall time
for live serving.

Every timer decision in the serving engine is made against *engine time*
(seconds, starting at 0 with the trace).  What engine time *is* depends
on the clock:

* :class:`VirtualClock` — simulation and trace replay.  ``advance_to``
  jumps instantly, so a 10-minute trace executes as fast as the host can
  process events.  This is the engine's default and reproduces the exact
  `self.now = max(self.now, t)` semantics the event loop historically
  hard-coded.
* :class:`WallClock` — live serving.  Engine time is anchored to
  ``time.perf_counter`` at construction; ``advance_to`` genuinely sleeps
  until the target instant, so invoker timers fire at real wall times and
  device executions overlap with the wait for the next arrival.  The
  ``speed`` factor (engine seconds per wall second) exists so wall-clock
  behaviour can be exercised in CI without waiting out a real trace:
  ``WallClock(speed=100)`` replays a 5-second trace in 50 ms while
  keeping every relative ordering intact.

Both clocks are monotone: ``advance_to`` never moves engine time
backwards, and ``now()`` never decreases.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Protocol, runtime_checkable
from repro.core.registry import lookup


@runtime_checkable
class Clock(Protocol):
    """What :class:`~repro.core.engine.ServingEngine` needs from a clock."""

    #: True when ``advance_to`` jumps instantly (simulation semantics).
    virtual: bool

    def now(self) -> float:
        """Current engine time in seconds."""

    def advance_to(self, t: float) -> None:
        """Move engine time forward to ``t`` (no-op when already past)."""


class VirtualClock:
    """Discrete-event time: ``advance_to`` jumps, nothing sleeps."""

    virtual = True

    def __init__(self, t0: float = 0.0):
        self._t = t0

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = t


class WallClock:
    """Engine time anchored to real time; ``advance_to`` sleeps.

    ``speed`` is engine-seconds per wall-second (1.0 = real time;
    >1 compresses the trace for tests).  ``now()`` is clamped monotone so
    a caller never observes time running backwards even if the underlying
    timer is perturbed.
    """

    virtual = False

    def __init__(self, speed: float = 1.0,
                 time_fn: Callable[[], float] = time.perf_counter,
                 sleep_fn: Callable[[float], None] = time.sleep):
        if speed <= 0:
            raise ValueError(f"speed must be positive, got {speed}")
        self.speed = speed
        self._time_fn = time_fn
        self._sleep_fn = sleep_fn
        self._epoch = time_fn()
        self._floor = 0.0

    def now(self) -> float:
        t = (self._time_fn() - self._epoch) * self.speed
        if t > self._floor:
            self._floor = t
        return self._floor

    def advance_to(self, t: float) -> None:
        dt = (t - self.now()) / self.speed
        if dt > 0:
            self._sleep_fn(dt)
        # an event scheduled at t has, by definition, happened by the time
        # advance_to returns — even if sleep undershot by a scheduler tick
        if t > self._floor:
            self._floor = t

    def shard_view(self) -> "WallClock":
        """A shard-local view of this wall clock for a runner thread.

        Shares the epoch, speed, and time/sleep functions — so every
        view reads the *same* engine timeline and sleeps against the
        same wall — but owns a private monotonicity floor.  ``now()``
        bumps the floor on every read; sharing one floor across shard
        threads would be a data race and would let a fast shard's reads
        drag a slow shard's clock forward.  Engine times stamped through
        different views stay directly comparable.
        """
        view = WallClock.__new__(WallClock)
        view.speed = self.speed
        view._time_fn = self._time_fn
        view._sleep_fn = self._sleep_fn
        view._epoch = self._epoch
        view._floor = 0.0
        return view


class _BarrierMember:
    """One shard's handle on a :class:`BarrierVirtualClock`.

    Behaves exactly like a private :class:`VirtualClock` between sync
    points (``advance_to`` jumps instantly, no sleeping), so a shard
    engine's transcript is identical to one driven by a plain virtual
    clock.  ``sync()`` is the rendezvous: the runner thread calls it at
    end-of-input, blocking until every member arrives, at which point
    all member times are lifted to the fleet-wide maximum.
    """

    virtual = True

    def __init__(self, parent: "BarrierVirtualClock", t0: float):
        self.parent = parent
        self._t = t0

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = t

    def sync(self) -> None:
        self.parent._sync()


class BarrierVirtualClock:
    """Virtual time for N shard threads with a barrier rendezvous.

    Each shard gets a member clock (:meth:`clock`) it advances privately
    — discrete-event semantics, no cross-thread coordination on the hot
    path.  At end-of-input, threaded runners call ``member.sync()``,
    which blocks until all ``parties`` members arrive and then lifts
    every member to the maximum member time; the sequential path calls
    :meth:`align` instead, which performs the same lift without
    blocking (a single thread at a barrier would deadlock).  Both paths
    leave every member at the same engine time, which is what makes
    ``parallel=True`` and sequential transcripts comparable under
    deterministic virtual time.

    ``timeout_s`` bounds the barrier wait so a deadlocked or crashed
    shard thread surfaces as a ``RuntimeError`` instead of hanging the
    fleet (and the test lane) forever.
    """

    virtual = True

    def __init__(self, parties: int, t0: float = 0.0,
                 timeout_s: float = 60.0):
        if parties < 1:
            raise ValueError(f"parties must be >= 1, got {parties}")
        self.parties = parties
        self.timeout_s = timeout_s
        self.members: List[_BarrierMember] = [
            _BarrierMember(self, t0) for _ in range(parties)]
        self._cv = threading.Condition()
        self._arrived = 0
        self._generation = 0

    def clock(self, shard: int) -> _BarrierMember:
        return self.members[shard]

    def align(self) -> None:
        """Lift every member to the max member time (non-blocking)."""
        t = max(m._t for m in self.members)
        for m in self.members:
            if t > m._t:
                m._t = t

    def _sync(self) -> None:
        with self._cv:
            gen = self._generation
            self._arrived += 1
            if self._arrived == self.parties:
                self.align()
                self._arrived = 0
                self._generation += 1
                self._cv.notify_all()
                return
            if not self._cv.wait_for(
                    lambda: self._generation != gen,
                    timeout=self.timeout_s):
                raise RuntimeError(
                    f"barrier clock timed out after {self.timeout_s}s "
                    f"({self._arrived}/{self.parties} shards arrived — "
                    "deadlocked or crashed shard thread?)")


_CLOCKS = {
    "virtual": VirtualClock,
    "wall": WallClock,
}


def make_clock(name: str, **cfg) -> Clock:
    """Clock-name -> instance (``virtual`` | ``wall``), mirroring
    ``make_placement`` / ``make_source``.  ``cfg`` forwards to the clock
    constructor (e.g. ``make_clock("wall", speed=100.0)``); ``speed`` is
    accepted—and ignored—for the virtual clock so one config dict can
    drive either name."""
    cls = lookup("clock", _CLOCKS, name)
    if cls is VirtualClock:
        cfg = {k: v for k, v in cfg.items() if k != "speed"}
    return cls(**cfg)
