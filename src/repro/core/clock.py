"""Pluggable engine clocks: virtual time for simulation/replay, wall time
for live serving.

Every timer decision in the serving engine is made against *engine time*
(seconds, starting at 0 with the trace).  What engine time *is* depends
on the clock:

* :class:`VirtualClock` — simulation and trace replay.  ``advance_to``
  jumps instantly, so a 10-minute trace executes as fast as the host can
  process events.  This is the engine's default and reproduces the exact
  `self.now = max(self.now, t)` semantics the event loop historically
  hard-coded.
* :class:`WallClock` — live serving.  Engine time is anchored to
  ``time.perf_counter`` at construction; ``advance_to`` genuinely sleeps
  until the target instant, so invoker timers fire at real wall times and
  device executions overlap with the wait for the next arrival.  The
  ``speed`` factor (engine seconds per wall second) exists so wall-clock
  behaviour can be exercised in CI without waiting out a real trace:
  ``WallClock(speed=100)`` replays a 5-second trace in 50 ms while
  keeping every relative ordering intact.

Both clocks are monotone: ``advance_to`` never moves engine time
backwards, and ``now()`` never decreases.
"""
from __future__ import annotations

import time
from typing import Callable, Protocol, runtime_checkable
from repro.core.registry import lookup


@runtime_checkable
class Clock(Protocol):
    """What :class:`~repro.core.engine.ServingEngine` needs from a clock."""

    #: True when ``advance_to`` jumps instantly (simulation semantics).
    virtual: bool

    def now(self) -> float:
        """Current engine time in seconds."""

    def advance_to(self, t: float) -> None:
        """Move engine time forward to ``t`` (no-op when already past)."""


class VirtualClock:
    """Discrete-event time: ``advance_to`` jumps, nothing sleeps."""

    virtual = True

    def __init__(self, t0: float = 0.0):
        self._t = t0

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = t


class WallClock:
    """Engine time anchored to real time; ``advance_to`` sleeps.

    ``speed`` is engine-seconds per wall-second (1.0 = real time;
    >1 compresses the trace for tests).  ``now()`` is clamped monotone so
    a caller never observes time running backwards even if the underlying
    timer is perturbed.
    """

    virtual = False

    def __init__(self, speed: float = 1.0,
                 time_fn: Callable[[], float] = time.perf_counter,
                 sleep_fn: Callable[[float], None] = time.sleep):
        if speed <= 0:
            raise ValueError(f"speed must be positive, got {speed}")
        self.speed = speed
        self._time_fn = time_fn
        self._sleep_fn = sleep_fn
        self._epoch = time_fn()
        self._floor = 0.0

    def now(self) -> float:
        t = (self._time_fn() - self._epoch) * self.speed
        if t > self._floor:
            self._floor = t
        return self._floor

    def advance_to(self, t: float) -> None:
        dt = (t - self.now()) / self.speed
        if dt > 0:
            self._sleep_fn(dt)
        # an event scheduled at t has, by definition, happened by the time
        # advance_to returns — even if sleep undershot by a scheduler tick
        if t > self._floor:
            self._floor = t


_CLOCKS = {
    "virtual": VirtualClock,
    "wall": WallClock,
}


def make_clock(name: str, **cfg) -> Clock:
    """Clock-name -> instance (``virtual`` | ``wall``), mirroring
    ``make_placement`` / ``make_source``.  ``cfg`` forwards to the clock
    constructor (e.g. ``make_clock("wall", speed=100.0)``); ``speed`` is
    accepted—and ignored—for the virtual clock so one config dict can
    drive either name."""
    cls = lookup("clock", _CLOCKS, name)
    if cls is VirtualClock:
        cfg = {k: v for k, v in cfg.items() if k != "speed"}
    return cls(**cfg)
