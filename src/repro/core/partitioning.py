"""Algorithm 1: Adaptive Frame Partitioning.

Divide the frame into X x Y zones, affiliate each RoI with the zone of
maximum overlap, shrink each non-empty zone to the minimum enclosing
rectangle of its RoIs, and cut the zones out as patches.

Two implementations with identical semantics:
  * ``partition``      — jit-able JAX, static (X*Y) patch slots + validity,
  * ``partition_host`` — plain numpy for the host-side scheduler/tests.

Patch sizes are rounded up to multiples of ``align`` (encoder/stitcher
tile friendliness), clamped to the frame.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Patch:
    """A cut-out region with Tangram metadata (Section III-A)."""
    x0: int
    y0: int
    x1: int
    y1: int
    frame_id: int = 0
    camera_id: int = 0
    t_gen: float = 0.0          # generation time
    slo: float = 1.0            # seconds

    @property
    def w(self) -> int:
        return self.x1 - self.x0

    @property
    def h(self) -> int:
        return self.y1 - self.y0

    @property
    def area(self) -> int:
        return self.w * self.h

    @property
    def deadline(self) -> float:
        return self.t_gen + self.slo


def _overlap_1d(a0, a1, b0, b1):
    return jnp.maximum(0, jnp.minimum(a1, b1) - jnp.maximum(a0, b0))


def partition(boxes: jnp.ndarray, valid: jnp.ndarray, frame_w: int,
              frame_h: int, zone_x: int, zone_y: int, align: int = 16
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """boxes: (K, 4) int32 xyxy RoIs; valid: (K,) bool.

    Returns (patches (X*Y, 4) int32 xyxy, patch_valid (X*Y,) bool).
    """
    n_zones = zone_x * zone_y
    zw, zh = frame_w // zone_x, frame_h // zone_y
    zi = jnp.arange(n_zones, dtype=jnp.int32)
    zx0 = (zi % zone_x) * zw
    zy0 = (zi // zone_x) * zh
    zx1 = zx0 + zw
    zy1 = zy0 + zh

    bx0, by0, bx1, by1 = (boxes[:, i] for i in range(4))
    ox = _overlap_1d(bx0[:, None], bx1[:, None], zx0[None, :], zx1[None, :])
    oy = _overlap_1d(by0[:, None], by1[:, None], zy0[None, :], zy1[None, :])
    overlap = ox * oy                                    # (K, Z)
    zone_of = jnp.argmax(overlap, axis=1)                # (K,)
    has_overlap = jnp.max(overlap, axis=1) > 0
    use = valid & has_overlap

    onehot = jax.nn.one_hot(zone_of, n_zones, dtype=jnp.int32) * use[:, None]
    big = jnp.int32(1 << 30)
    px0 = jnp.min(jnp.where(onehot > 0, bx0[:, None], big), axis=0)
    py0 = jnp.min(jnp.where(onehot > 0, by0[:, None], big), axis=0)
    px1 = jnp.max(jnp.where(onehot > 0, bx1[:, None], -big), axis=0)
    py1 = jnp.max(jnp.where(onehot > 0, by1[:, None], -big), axis=0)
    patch_valid = jnp.sum(onehot, axis=0) > 0

    # align sizes up, clamp to frame
    def align_up(lo, hi, limit):
        size = hi - lo
        size = ((size + align - 1) // align) * align
        hi = jnp.minimum(lo + size, limit)
        lo = jnp.maximum(hi - size, 0)
        return lo, hi

    px0, px1 = align_up(px0, px1, frame_w)
    py0, py1 = align_up(py0, py1, frame_h)
    patches = jnp.stack([px0, py0, px1, py1], axis=-1) * patch_valid[:, None]
    return patches.astype(jnp.int32), patch_valid


def partition_host(boxes: np.ndarray, frame_w: int, frame_h: int,
                   zone_x: int, zone_y: int, align: int = 16,
                   frame_id: int = 0, camera_id: int = 0, t_gen: float = 0.0,
                   slo: float = 1.0) -> List[Patch]:
    """Numpy Algorithm 1 producing Patch objects for the scheduler."""
    if len(boxes) == 0:
        return []
    zw, zh = frame_w // zone_x, frame_h // zone_y
    zones: dict = {}
    for (x0, y0, x1, y1) in boxes:
        # zone of max overlap
        best, best_area = None, 0
        for zyi in range(zone_y):
            for zxi in range(zone_x):
                ox = max(0, min(x1, (zxi + 1) * zw) - max(x0, zxi * zw))
                oy = max(0, min(y1, (zyi + 1) * zh) - max(y0, zyi * zh))
                if ox * oy > best_area:
                    best_area = ox * oy
                    best = zyi * zone_x + zxi
        if best is None:
            continue
        zones.setdefault(best, []).append((x0, y0, x1, y1))

    patches = []
    for z, bs in sorted(zones.items()):
        x0 = min(b[0] for b in bs)
        y0 = min(b[1] for b in bs)
        x1 = max(b[2] for b in bs)
        y1 = max(b[3] for b in bs)
        w = int(np.ceil((x1 - x0) / align) * align)
        h = int(np.ceil((y1 - y0) / align) * align)
        x1 = min(x0 + w, frame_w)
        x0 = max(x1 - w, 0)
        y1 = min(y0 + h, frame_h)
        y0 = max(y1 - h, 0)
        patches.append(Patch(int(x0), int(y0), int(x1), int(y1),
                             frame_id=frame_id, camera_id=camera_id,
                             t_gen=t_gen, slo=slo))
    return patches


def patch_pixels(frame: np.ndarray, p: Patch) -> np.ndarray:
    return frame[p.y0:p.y1, p.x0:p.x1]


def coverage(patches: List[Patch], boxes: np.ndarray) -> float:
    """Fraction of ground-truth boxes fully covered by some patch
    (the Table III accuracy proxy: a covered object is detectable)."""
    if len(boxes) == 0:
        return 1.0
    covered = 0
    for (x0, y0, x1, y1) in boxes:
        for p in patches:
            if p.x0 <= x0 and p.y0 <= y0 and p.x1 >= x1 and p.y1 >= y1:
                covered += 1
                break
    return covered / len(boxes)
