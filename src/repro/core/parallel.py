"""True-parallel fleet runtime: per-shard engine threads.

:class:`~repro.core.fleet.ShardedEngine` scaled *scheduling* to fleet
size, but every shard still turned on one Python event loop — on a
multi-core node the router saturates one core while the rest idle.
Here each shard's :class:`~repro.core.engine.ServingEngine` runs on its
own :class:`ShardRunner` thread behind a bounded SPSC arrival queue:

::

    router thread                      shard threads
    -------------                      -------------
    arrivals ──┬─► inbox[0] ──► ShardRunner 0 ──► engine 0 ─┐
               ├─► inbox[1] ──► ShardRunner 1 ──► engine 1 ─┼─► merge
               └─► inbox[2] ──► ShardRunner 2 ──► engine 2 ─┘

The router is the only producer and the runner the only consumer of
each inbox, so a full inbox backpressures the router without any other
coordination.  Each runner drains ``offer_batch`` runs exactly as the
sequential path does, so the per-shard transcript is unchanged; the
harvest reuses the base class's pinned ``(t_finish, shard index,
within-shard delivery order)`` merge, so cross-shard outcome order is
also unchanged.  Shards coordinate only at submit/complete boundaries —
device dispatch (jit / Pallas launches release the GIL) and
sim-platform sleeps genuinely overlap.

Shared vs shard-local state (what makes the overlap safe):

* shard-local — invoker pool, arrival slots, event heap, clock
  (:meth:`WallClock.shard_view` per thread, or a barrier-clock member);
* shared, concurrency-safe — the refcounted
  :class:`~repro.core.framestore.FrameStore` (striped locks),
  ``split_platform``'s :class:`~repro.core.cost.CostMeter` (locked
  accumulator), :class:`~repro.core.latency.OnlineLatencyTable`
  (lock-guarded EWMA folds).

``ParallelShardedEngine`` with the runners never started (no arrivals)
degrades to the sequential finish, and the ``parallel=False`` config
path never constructs this class at all — sequential serving is
bit-identical to PR 9, pinned by the transcript-equivalence tests.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, List, Optional, Sequence

from repro.core.engine import PatchOutcome, ServingEngine
from repro.core.fleet import FleetPlan, ShardedEngine
from repro.data.video import Arrival

__all__ = ["ShardRunner", "ParallelShardedEngine"]

#: end-of-input sentinel (identity-compared; never a valid batch)
_STOP = object()


class ShardRunner(threading.Thread):
    """One shard's event loop on its own thread.

    The router thread feeds ``submit``; this thread drains the bounded
    inbox into ``engine.offer_batch`` and, at the stop sentinel, syncs
    the shard's barrier clock (when it has one) and finishes the
    engine — so trailing-canvas flushes overlap across shards too.

    ``submitted`` is written only by the router and ``consumed`` only
    by this thread (single-writer counters); their difference is the
    queued-arrival backlog without taking any lock.
    """

    def __init__(self, shard: int, engine: ServingEngine,
                 queue_depth: int = 64):
        super().__init__(name=f"shard-runner-{shard}", daemon=True)
        self.shard = shard
        self.engine = engine
        self.inbox: "queue.Queue" = queue.Queue(maxsize=queue_depth)
        self.error: Optional[BaseException] = None
        self.submitted = 0
        self.consumed = 0
        self._t_end: Optional[float] = None

    def pending(self) -> int:
        """Arrivals submitted but not yet drained into the engine."""
        return self.submitted - self.consumed

    def submit(self, batch: Sequence[Arrival]) -> None:
        """Enqueue one same-shard arrival run (router thread only).

        Blocks when the inbox is full — the bounded queue *is* the
        backpressure on a shard that falls behind."""
        self.submitted += len(batch)
        self.inbox.put(batch)

    def stop(self, t_end: Optional[float] = None) -> None:
        """Signal end-of-input; the runner finishes its engine and
        exits (router thread only)."""
        self._t_end = t_end
        self.inbox.put(_STOP)

    def run(self) -> None:
        eng = self.engine
        stopped = False
        try:
            while True:
                item = self.inbox.get()
                if item is _STOP:
                    stopped = True
                    break
                eng.offer_batch(item)
                self.consumed += len(item)
            sync = getattr(eng.clock, "sync", None)
            if sync is not None:
                sync()
            eng.finish(self._t_end)
        except BaseException as exc:        # delivered by finish()
            self.error = exc
            # unblock peers at a barrier clock, then drain the inbox so
            # the router's bounded put() never blocks on a dead shard
            sync = getattr(eng.clock, "sync", None)
            if sync is not None:
                try:
                    sync()
                except BaseException:
                    pass
            while not stopped:
                if self.inbox.get() is _STOP:
                    stopped = True


class ParallelShardedEngine(ShardedEngine):
    """:class:`ShardedEngine` with each shard on a :class:`ShardRunner`.

    Same construction, routing, merge rule, and observability surface
    as the sequential engine; only the *execution* of the shard loops
    moves onto threads.  Runners start lazily on the first offer and
    are joined (and their errors re-raised) by :meth:`finish`.
    """

    def __init__(self, shards: Sequence[ServingEngine],
                 shard_of_camera: Callable[[int], int],
                 plan: Optional[FleetPlan] = None,
                 queue_depth: int = 64):
        super().__init__(shards, shard_of_camera, plan=plan)
        if queue_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1, got {queue_depth}")
        self.queue_depth = queue_depth
        self._runners: Optional[List[ShardRunner]] = None

    # ----------------------------------------------------------- feeding ----

    def _start(self) -> List[ShardRunner]:
        if self._runners is None:
            self._runners = [ShardRunner(s, eng, self.queue_depth)
                             for s, eng in enumerate(self.shards)]
            for r in self._runners:
                r.start()
        return self._runners

    def offer(self, arrival: Arrival):
        self._outcomes = None
        self._start()[self.shard_of(arrival.patch)].submit([arrival])

    def run(self, arrivals: Sequence[Arrival]) -> List[PatchOutcome]:
        """Route a merged fleet trace to the shard threads and drain.

        Same consecutive-run batching as the sequential engine — the
        router touches each same-shard *run*, not each event."""
        self._outcomes = None
        runners = self._start()
        shard_of_camera = self.shard_of_camera
        run_buf: List[Arrival] = []
        current = -1
        for arr in arrivals:
            s = shard_of_camera(arr.patch.camera_id)
            if s != current:
                if run_buf:
                    runners[current].submit(run_buf)
                    run_buf = []
                current = s
            run_buf.append(arr)
        if run_buf:
            runners[current].submit(run_buf)
        self.finish()
        return self.outcomes

    # ------------------------------------------------------------ finish ----

    def finish(self, t_end: Optional[float] = None):
        runners, self._runners = self._runners, None
        if runners is None:
            # nothing ever routed through the threads — sequential
            # finish (aligns barrier clocks, finishes every shard)
            super().finish(t_end)
            return
        for r in runners:
            r.stop(t_end)
        for r in runners:
            r.join()
        for r in runners:
            if r.error is not None:
                raise r.error
        for s, eng in enumerate(self.shards):
            for inv in eng.invocations:
                if inv.shard is None:
                    inv.shard = s
        self._finished = True
        self._outcomes = None

    # ------------------------------------------------------- backpressure ----

    def backlog(self) -> int:
        """Global backlog: shard-engine backlogs plus arrivals still
        queued in runner inboxes (advisory read across threads)."""
        n = super().backlog()
        if self._runners is not None:
            n += sum(r.pending() for r in self._runners)
        return n
