"""Tangram scheduler: a thin adapter over the unified serving engine.

The event loop, the per-class invoker pool, and the executor abstraction
live in :mod:`repro.core.engine`; this module wires them to the paper's
scenario (bandwidth-shaped arrivals -> SLO-aware batching -> serverless
platform) and assembles the ``Results`` record that every benchmark
(Figs. 8-14) reads.  ``PatchOutcome``/``Results`` are re-exported here
for backwards compatibility.

Configuration is one :class:`~repro.core.config.ServeConfig`::

    sched = TangramScheduler(256, 256, table, platform,
                             config=ServeConfig(classify="slo",
                                                n_workers=2,
                                                online_latency=True))

Every field is a plain value or a named reference resolved through the
factories (``make_classify`` / ``make_placement`` / ``make_clock``), so
the exact scheduler configuration can be logged into benchmark JSON via
``config.to_dict()`` and rebuilt with ``ServeConfig.from_dict``.

The pre-config keyword arguments (``max_canvases=``, ``adaptive=``,
``n_workers=``, ...) still work through a deprecation shim that warns
once per process and forwards onto a ``ServeConfig``; non-serializable
legacy values (a ``classify`` callable, a ``Clock`` or placement
*instance*) are honoured as direct overrides but cannot be expressed in
the config record — pass registry names to keep configs loggable.

Ingestion is pluggable the same way: :meth:`TangramScheduler.run` shapes
patch streams through a :class:`~repro.sources.TraceSource` (the replay
special case — event-for-event identical to the historical
``shape_arrivals`` path), while :meth:`serve_source` accepts any
:mod:`repro.sources` source, with the engine's ingestion window feeding
backpressure to it and the source's drop/degrade accounting landing in
``Results.summary()["source"]``.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Sequence

from repro.core.adaptive import AdaptiveInvokerPool, adaptive_uniform_pool
from repro.core.clock import Clock, make_clock
from repro.core.config import ServeConfig, make_classify
from repro.core.engine import (InvokerPool, PatchOutcome, Results,
                               ServingEngine, SimExecutor, uniform_pool)
from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import LatencyBank, LatencyTable, OnlineLatencyTable
from repro.core.models import make_model
from repro.core.partitioning import Patch
from repro.core.registry import unknown_name
from repro.core.workers import WorkerPoolExecutor, make_placement
from repro.serverless.platform import (Platform, mean_consolidation,
                                       model_stats as records_model_stats,
                                       split_platform)

__all__ = ["PatchOutcome", "Results", "ServeConfig", "TangramScheduler"]

#: legacy keyword -> ServeConfig field (the deprecation shim's mapping)
_LEGACY_FIELDS = ("max_canvases", "check_invariants", "classify",
                  "incremental", "adaptive", "clock", "n_workers",
                  "placement", "online_latency", "ingestion_window")
_legacy_warned = False


def _warn_legacy_once(names):
    global _legacy_warned
    if _legacy_warned:
        return
    _legacy_warned = True
    warnings.warn(
        f"TangramScheduler keyword arguments {sorted(names)} are "
        f"deprecated; pass config=ServeConfig(...) instead "
        f"(repro.core.config)", DeprecationWarning, stacklevel=3)


class TangramScheduler:
    """The cloud-side scheduler of Fig. 5.

    ``config.classify=None`` keeps the paper's single shared queue;
    ``"slo"`` shards the invoker per SLO class so tight deadlines never
    wait behind loose ones.  ``config.clock="virtual"`` (default) gives
    every run a fresh virtual clock (simulation).
    """

    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 platform: Platform,
                 config: Optional[ServeConfig] = None,
                 executor: object = None, **legacy):
        config = config if config is not None else ServeConfig()
        self._executor_override = executor
        # -------------------------------------------- deprecation shim ----
        # Old keyword arguments forward onto the config.  Values that a
        # config cannot express (callables / instances) become direct
        # overrides resolved below in place of the named references.
        classify_override: Optional[Callable[[Patch], object]] = None
        clock_override: Optional[Clock] = None
        placement_override: object = None
        if legacy:
            unknown = set(legacy) - set(_LEGACY_FIELDS)
            if unknown:
                raise TypeError(
                    f"unexpected TangramScheduler arguments "
                    f"{sorted(unknown)}")
            _warn_legacy_once(legacy)
            fields = {}
            for name, value in legacy.items():
                if name == "classify" and callable(value):
                    classify_override = value
                elif name == "clock" and isinstance(value, Clock):
                    clock_override = value
                elif name == "placement" and not (
                        value is None or isinstance(value, str)):
                    placement_override = value
                else:
                    fields[name] = value
            config = config.replace(**fields)

        self.config = config
        classify = (classify_override if classify_override is not None
                    else make_classify(config.classify))
        self.estimator = None          # OnlineLatencyTable | LatencyBank
        self._model_specs: dict = {}
        self._model_tables: dict = {}  # base tables (platform sampling)
        if config.multi_model:
            # ---- multi-model: registry specs drive geometry + latency ----
            # The ctor's canvas_m/canvas_n/latency become the legacy
            # single-model fallback and are ignored here: each class's
            # invoker takes its model's canvas geometry and latency table
            # straight off the ModelSpec, so t_slack is per-model.
            specs = {n: make_model(n) for n in config.model_names()}
            self._model_specs = specs
            base = {n: (s.table if s.table is not None
                        else s.latency_table())
                    for n, s in specs.items()}
            self._model_tables = base
            if config.online_latency:
                online = {n: OnlineLatencyTable(t) for n, t in base.items()}
                self.estimator = LatencyBank(online)
                invoker_tables = online
            else:
                invoker_tables = dict(base)

            def make_invoker(key):
                model = config.resolve_model(key)
                if model is None:
                    raise unknown_name("SLO class", key,
                                       config.model_map or {})
                spec = specs[model]
                return SLOAwareInvoker(spec.canvas_m, spec.canvas_n,
                                       invoker_tables[model],
                                       config.max_canvases,
                                       incremental=config.incremental)

            pool_classify = classify or (lambda p: None)
            if config.adaptive is not None:
                self.pool = AdaptiveInvokerPool(
                    make_invoker, pool_classify, config.adaptive,
                    model_of=config.resolve_model)
            else:
                self.pool = InvokerPool(make_invoker, pool_classify,
                                        model_of=config.resolve_model)
        else:
            if config.online_latency:
                latency = self.estimator = OnlineLatencyTable(latency)
            if config.adaptive is not None:
                self.pool = adaptive_uniform_pool(
                    canvas_m, canvas_n, latency, config.max_canvases,
                    incremental=config.incremental, classify=classify,
                    cfg=config.adaptive)
            else:
                self.pool = uniform_pool(canvas_m, canvas_n, latency,
                                         config.max_canvases,
                                         incremental=config.incremental,
                                         classify=classify)
        self.platform = platform
        self.n_workers = config.n_workers
        self.placement = (placement_override
                          if placement_override is not None
                          else make_placement(config.placement)
                          if config.placement is not None else None)
        self.clock = clock_override
        self.check_invariants = config.check_invariants

    def _clock(self) -> Optional[Clock]:
        """A legacy clock instance wins; otherwise "virtual" keeps the
        engine default (a fresh VirtualClock per engine) and "wall"
        builds a fresh wall clock per run."""
        if self.clock is not None:
            return self.clock
        if self.config.clock == "virtual":
            return None
        return make_clock(self.config.clock, speed=self.config.wall_speed)

    def _sim_executor(self, platform: Platform) -> SimExecutor:
        """A SimExecutor over ``platform``, multi-model aware: each
        model's submissions carry its weight-load cost and sample from
        its own base latency table (per-model warm-pool economics)."""
        if not self._model_specs:
            return SimExecutor(platform)
        loads = {n: s.load_s for n, s in self._model_specs.items()}
        return SimExecutor(platform, model_loads=loads,
                           model_tables=self._model_tables)

    def _executor(self):
        """One SimExecutor, or a worker pool over platform capacity
        shards (shared cost meter: billing aggregates unchanged).  A
        ctor-supplied ``executor`` (e.g. a device worker pool) is used
        as-is — the platform then only carries the cost meter."""
        if self._executor_override is not None:
            return self._executor_override, [self.platform]
        if self.n_workers == 1 and self.estimator is None:
            return self._sim_executor(self.platform), [self.platform]
        platforms = (split_platform(self.platform, self.n_workers)
                     if self.n_workers > 1 else [self.platform])
        pool = WorkerPoolExecutor([self._sim_executor(p) for p in platforms],
                                  placement=self.placement,
                                  estimator=self.estimator)
        return pool, platforms

    def run(self, streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            name: str = "tangram") -> Results:
        """Replay per-camera patch streams over shaped uplinks — the
        historical entry point, now a :class:`~repro.sources.TraceSource`
        special case of :meth:`serve_source`."""
        from repro.sources import TraceSource
        return self.serve_source(
            TraceSource(streams=streams, bandwidth_bps=bandwidth_bps),
            name=name)

    def serve_source(self, source, name: str = "tangram") -> Results:
        """Serve any :mod:`repro.sources` source end-to-end and assemble
        the ``Results`` record (bandwidth + drop/degrade accounting from
        ``source.stats()``)."""
        executor, platforms = self._executor()
        engine = ServingEngine(self.pool, executor,
                               clock=self._clock(),
                               check_invariants=self.check_invariants,
                               ingestion_window=self.config.ingestion_window)
        outcomes = engine.serve(source)

        stats = source.stats()
        source_stats = stats.to_dict()
        source_stats["backlog_high_water"] = engine.backlog_high_water
        source_stats["ingestion_window"] = self.config.ingestion_window
        records = [r for p in platforms for r in p.records]
        model_stats = records_model_stats(records)
        cache_stats = (executor.model_cache_stats()
                       if hasattr(executor, "model_cache_stats") else {})
        for model, row in cache_stats.items():
            model_stats.setdefault(model, {}).update(row)
        return Results(
            name=name, outcomes=outcomes,
            canvas_efficiencies=[c.efficiency for inv in engine.invocations
                                 for c in inv.canvases],
            batch_sizes=[len(inv.canvases) for inv in engine.invocations],
            patches_per_batch=[len(inv.patches)
                               for inv in engine.invocations],
            bytes_sent=stats.bytes_sent,
            total_cost=self.platform.total_cost,
            invocations=len(records),
            exec_seconds=self.platform.meter.busy_seconds,
            transmission_seconds=stats.transmission_seconds,
            mean_consolidation=mean_consolidation(records),
            worker_stats=(executor.worker_stats()
                          if isinstance(executor, WorkerPoolExecutor)
                          else None),
            source_stats=source_stats,
            model_stats=model_stats or None)
