"""Tangram scheduler: a thin adapter over the unified serving engine.

The event loop, the per-class invoker pool, and the executor abstraction
live in :mod:`repro.core.engine`; this module wires them to the paper's
scenario (bandwidth-shaped arrivals -> SLO-aware batching -> serverless
platform) and assembles the ``Results`` record that every benchmark
(Figs. 8-14) reads.  ``PatchOutcome``/``Results`` are re-exported here
for backwards compatibility.

Pass ``adaptive=AIMDConfig(...)`` to put the completion-driven AIMD
controller (:mod:`repro.core.adaptive`) on the pool: per-class canvas
budgets and firing margins then track delivered completions instead of
staying at the static configuration.

Pass ``n_workers > 1`` to serve through a
:class:`~repro.core.workers.WorkerPoolExecutor` over per-worker platform
capacity shards (:func:`~repro.serverless.platform.split_platform`) —
the simulation twin of routing invocations across device mesh slices;
``placement`` picks the routing policy.  ``online_latency=True`` wraps
the profiled table in an :class:`~repro.core.latency.OnlineLatencyTable`
shared between the invokers and the executor, so firing decisions track
observed completion times instead of the static profile.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

from repro.core.adaptive import AIMDConfig, adaptive_uniform_pool
from repro.core.clock import Clock
from repro.core.engine import (PatchOutcome, Results, ServingEngine,
                               SimExecutor, uniform_pool)
from repro.core.latency import LatencyTable, OnlineLatencyTable
from repro.core.partitioning import Patch
from repro.core.workers import WorkerPoolExecutor, make_placement
from repro.data.video import merge_arrivals, shape_arrivals
from repro.serverless.platform import (Platform, mean_consolidation,
                                       split_platform)

__all__ = ["PatchOutcome", "Results", "TangramScheduler"]


class TangramScheduler:
    """The cloud-side scheduler of Fig. 5.

    ``classify=None`` keeps the paper's single shared queue; pass
    ``engine.slo_class`` (or any ``Patch -> key`` function) to shard the
    invoker per SLO class so tight deadlines never wait behind loose ones.
    ``clock`` defaults to a fresh virtual clock per run (simulation).
    """

    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 platform: Platform, max_canvases: int = 8,
                 check_invariants: bool = False,
                 classify: Optional[Callable[[Patch], object]] = None,
                 incremental: bool = True,
                 adaptive: Optional[AIMDConfig] = None,
                 clock: Optional[Clock] = None,
                 n_workers: int = 1,
                 placement: Union[str, object, None] = None,
                 online_latency: bool = False):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.estimator: Optional[OnlineLatencyTable] = None
        if online_latency:
            latency = self.estimator = OnlineLatencyTable(latency)
        if adaptive is not None:
            self.pool = adaptive_uniform_pool(
                canvas_m, canvas_n, latency, max_canvases,
                incremental=incremental, classify=classify, cfg=adaptive)
        else:
            self.pool = uniform_pool(canvas_m, canvas_n, latency,
                                     max_canvases, incremental=incremental,
                                     classify=classify)
        self.platform = platform
        self.n_workers = n_workers
        self.placement = (make_placement(placement)
                          if isinstance(placement, str) else placement)
        self.clock = clock
        self.check_invariants = check_invariants

    def _executor(self):
        """One SimExecutor, or a worker pool over platform capacity
        shards (shared cost meter: billing aggregates unchanged)."""
        if self.n_workers == 1 and self.estimator is None:
            return SimExecutor(self.platform), [self.platform]
        platforms = (split_platform(self.platform, self.n_workers)
                     if self.n_workers > 1 else [self.platform])
        pool = WorkerPoolExecutor([SimExecutor(p) for p in platforms],
                                  placement=self.placement,
                                  estimator=self.estimator)
        return pool, platforms

    def run(self, streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            name: str = "tangram") -> Results:
        per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
        arrivals = merge_arrivals(per_cam)
        executor, platforms = self._executor()
        engine = ServingEngine(self.pool, executor,
                               clock=self.clock,
                               check_invariants=self.check_invariants)
        outcomes = engine.run(arrivals)

        bytes_sent = sum(a.n_bytes for cam in per_cam for a in cam)
        trans = sum(a.t_arrive - a.patch.t_gen for cam in per_cam for a in cam)
        records = [r for p in platforms for r in p.records]
        return Results(
            name=name, outcomes=outcomes,
            canvas_efficiencies=[c.efficiency for inv in engine.invocations
                                 for c in inv.canvases],
            batch_sizes=[len(inv.canvases) for inv in engine.invocations],
            patches_per_batch=[len(inv.patches)
                               for inv in engine.invocations],
            bytes_sent=bytes_sent,
            total_cost=self.platform.total_cost,
            invocations=len(records),
            exec_seconds=self.platform.meter.busy_seconds,
            transmission_seconds=trans,
            mean_consolidation=mean_consolidation(records),
            worker_stats=(executor.worker_stats()
                          if isinstance(executor, WorkerPoolExecutor)
                          else None))
