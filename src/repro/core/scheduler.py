"""End-to-end Tangram scheduler: arrivals -> invoker -> platform -> metrics.

Drives the SLO-aware invoker with bandwidth-shaped patch arrivals over a
virtual clock and dispatches invocations to the serverless platform model.
Produces the ``Results`` record that every benchmark (Figs. 8-14) reads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.invoker import Invocation, SLOAwareInvoker
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.core.stitching import total_efficiency, validate
from repro.data.video import Arrival, merge_arrivals, shape_arrivals
from repro.serverless.platform import Platform


@dataclasses.dataclass
class PatchOutcome:
    patch: Patch
    t_arrive: float
    t_submit: float
    t_finish: float

    @property
    def latency(self) -> float:
        return self.t_finish - self.patch.t_gen

    @property
    def violated(self) -> bool:
        return self.t_finish > self.patch.deadline

    @property
    def wait(self) -> float:
        return self.t_submit - self.t_arrive


@dataclasses.dataclass
class Results:
    name: str
    outcomes: List[PatchOutcome]
    canvas_efficiencies: List[float]
    batch_sizes: List[int]
    patches_per_batch: List[int]
    bytes_sent: float
    total_cost: float
    invocations: int
    exec_seconds: float
    transmission_seconds: float
    mean_consolidation: float = 0.0   # patches per invocation (platform view)

    @property
    def n_patches(self) -> int:
        return len(self.outcomes)

    @property
    def violation_rate(self) -> float:
        if not self.outcomes:
            return 0.0
        return sum(o.violated for o in self.outcomes) / len(self.outcomes)

    @property
    def mean_latency(self) -> float:
        if not self.outcomes:
            return 0.0
        return sum(o.latency for o in self.outcomes) / len(self.outcomes)

    @property
    def amortized_latency(self) -> float:
        """Total function execution time amortized per patch (Fig. 14)."""
        if not self.outcomes:
            return 0.0
        return self.exec_seconds / len(self.outcomes)

    def summary(self) -> dict:
        return {
            "name": self.name,
            "patches": self.n_patches,
            "violation_rate": round(self.violation_rate, 4),
            "mean_latency_s": round(self.mean_latency, 4),
            "cost_usd": round(self.total_cost, 6),
            "invocations": self.invocations,
            "bytes_mb": round(self.bytes_sent / 1e6, 3),
            "mean_canvas_eff": round(
                sum(self.canvas_efficiencies)
                / max(len(self.canvas_efficiencies), 1), 4),
            "amortized_latency_s": round(self.amortized_latency, 4),
            "mean_consolidation": round(self.mean_consolidation, 2),
        }


class TangramScheduler:
    """The cloud-side scheduler of Fig. 5."""

    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 platform: Platform, max_canvases: int = 8,
                 check_invariants: bool = False):
        self.invoker = SLOAwareInvoker(canvas_m, canvas_n, latency,
                                       max_canvases)
        self.platform = platform
        self.check_invariants = check_invariants
        self.outcomes: List[PatchOutcome] = []
        self.canvas_effs: List[float] = []
        self.batch_sizes: List[int] = []
        self.patches_per_batch: List[int] = []
        self._arrive_at = {}

    def _dispatch(self, inv: Invocation):
        if self.check_invariants:
            validate(inv.canvases)
            # every queued patch must be placed exactly once (the unstitch
            # gather relies on this); checked on the packing itself so the
            # simulation never pays for device record packing
            placed = sorted(p.patch_idx for c in inv.canvases
                            for p in c.placements)
            assert placed == list(range(len(inv.patches))), placed
        rec = self.platform.submit(inv.t_submit, len(inv.canvases),
                                   n_patches=len(inv.patches))
        self.batch_sizes.append(len(inv.canvases))
        self.patches_per_batch.append(len(inv.patches))
        for c in inv.canvases:
            self.canvas_effs.append(c.efficiency)
        for p in inv.patches:
            self.outcomes.append(PatchOutcome(
                p, self._arrive_at.get(id(p), inv.t_submit), inv.t_submit,
                rec.t_finish))

    def run(self, streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            name: str = "tangram") -> Results:
        per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
        arrivals = merge_arrivals(per_cam)
        inv = self.invoker

        for arr in arrivals:
            while inv.next_timer() < arr.t_arrive:
                fired = inv.poll(inv.next_timer())
                if fired is None:
                    break
                self._dispatch(fired)
            self._arrive_at[id(arr.patch)] = arr.t_arrive
            for fired in inv.on_patch(arr.t_arrive, arr.patch):
                self._dispatch(fired)

        while inv.next_timer() < math.inf:
            fired = inv.poll(inv.next_timer())
            if fired is None:
                break
            self._dispatch(fired)

        bytes_sent = sum(a.n_bytes for cam in per_cam for a in cam)
        trans = sum(a.t_arrive - a.patch.t_gen for cam in per_cam for a in cam)
        return Results(
            name=name, outcomes=self.outcomes,
            canvas_efficiencies=self.canvas_effs,
            batch_sizes=self.batch_sizes,
            patches_per_batch=self.patches_per_batch,
            bytes_sent=bytes_sent,
            total_cost=self.platform.total_cost,
            invocations=len(self.platform.records),
            exec_seconds=self.platform.meter.busy_seconds,
            transmission_seconds=trans,
            mean_consolidation=self.platform.mean_consolidation)
