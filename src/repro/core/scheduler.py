"""Tangram scheduler: a thin adapter over the unified serving engine.

The event loop, the per-class invoker pool, and the executor abstraction
live in :mod:`repro.core.engine`; this module wires them to the paper's
scenario (bandwidth-shaped arrivals -> SLO-aware batching -> serverless
platform) and assembles the ``Results`` record that every benchmark
(Figs. 8-14) reads.  ``PatchOutcome``/``Results`` are re-exported here
for backwards compatibility.

Configuration is one :class:`~repro.core.config.ServeConfig`::

    sched = TangramScheduler(256, 256, table, platform,
                             config=ServeConfig(classify="slo",
                                                n_workers=2,
                                                online_latency=True))

Every field is a plain value or a named reference resolved through the
factories (``make_classify`` / ``make_placement`` / ``make_clock``), so
the exact scheduler configuration can be logged into benchmark JSON via
``config.to_dict()`` and rebuilt with ``ServeConfig.from_dict``.

The pre-config keyword arguments (``max_canvases=``, ``adaptive=``,
``n_workers=``, ...) still work through a deprecation shim that warns
once per process and forwards onto a ``ServeConfig``; non-serializable
legacy values (a ``classify`` callable, a ``Clock`` or placement
*instance*) are honoured as direct overrides but cannot be expressed in
the config record — pass registry names to keep configs loggable.

Ingestion is pluggable the same way: :meth:`TangramScheduler.run` shapes
patch streams through a :class:`~repro.sources.TraceSource` (the replay
special case — event-for-event identical to the historical
``shape_arrivals`` path), while :meth:`serve_source` accepts any
:mod:`repro.sources` source, with the engine's ingestion window feeding
backpressure to it and the source's drop/degrade accounting landing in
``Results.summary()["source"]``.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Sequence

from repro.core.adaptive import AdaptiveInvokerPool, adaptive_uniform_pool
from repro.core.clock import Clock, make_clock
from repro.core.config import ServeConfig, make_classify
from repro.core.engine import (InvokerPool, PatchOutcome, Results,
                               ServingEngine, SimExecutor, uniform_pool)
from repro.core.fleet import (FleetCostModel, FleetInvokerPool, FleetPlan,
                              ShardedEngine, fleet_uniform_pool,
                              make_planner)
from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import LatencyBank, LatencyTable, OnlineLatencyTable
from repro.core.models import make_model
from repro.core.parallel import ParallelShardedEngine
from repro.core.partitioning import Patch
from repro.core.registry import unknown_name
from repro.core.workers import (ReservedClassPlacement, WorkerPoolExecutor,
                                make_placement)
from repro.serverless.platform import (Platform, mean_consolidation,
                                       model_stats as records_model_stats,
                                       split_platform)

__all__ = ["PatchOutcome", "Results", "ServeConfig", "TangramScheduler"]

#: legacy keyword -> ServeConfig field (the deprecation shim's mapping)
_LEGACY_FIELDS = ("max_canvases", "check_invariants", "classify",
                  "incremental", "adaptive", "clock", "n_workers",
                  "placement", "online_latency", "ingestion_window")
_legacy_warned = False


def _warn_legacy_once(names):
    global _legacy_warned
    if _legacy_warned:
        return
    _legacy_warned = True
    warnings.warn(
        f"TangramScheduler keyword arguments {sorted(names)} are "
        f"deprecated; pass config=ServeConfig(...) instead "
        f"(repro.core.config)", DeprecationWarning, stacklevel=3)


class TangramScheduler:
    """The cloud-side scheduler of Fig. 5.

    ``config.classify=None`` keeps the paper's single shared queue;
    ``"slo"`` shards the invoker per SLO class so tight deadlines never
    wait behind loose ones.  ``config.clock="virtual"`` (default) gives
    every run a fresh virtual clock (simulation).
    """

    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 platform: Platform,
                 config: Optional[ServeConfig] = None,
                 executor: object = None, **legacy):
        config = config if config is not None else ServeConfig()
        self._executor_override = executor
        # -------------------------------------------- deprecation shim ----
        # Old keyword arguments forward onto the config.  Values that a
        # config cannot express (callables / instances) become direct
        # overrides resolved below in place of the named references.
        classify_override: Optional[Callable[[Patch], object]] = None
        clock_override: Optional[Clock] = None
        placement_override: object = None
        if legacy:
            unknown = set(legacy) - set(_LEGACY_FIELDS)
            if unknown:
                raise TypeError(
                    f"unexpected TangramScheduler arguments "
                    f"{sorted(unknown)}")
            _warn_legacy_once(legacy)
            fields = {}
            for name, value in legacy.items():
                if name == "classify" and callable(value):
                    classify_override = value
                elif name == "clock" and isinstance(value, Clock):
                    clock_override = value
                elif name == "placement" and not (
                        value is None or isinstance(value, str)):
                    placement_override = value
                else:
                    fields[name] = value
            config = config.replace(**fields)

        self.config = config
        classify = (classify_override if classify_override is not None
                    else make_classify(config.classify))
        self.estimator = None          # OnlineLatencyTable | LatencyBank
        self._model_specs: dict = {}
        self._model_tables: dict = {}  # base tables (platform sampling)
        if config.multi_model:
            # ---- multi-model: registry specs drive geometry + latency ----
            # The ctor's canvas_m/canvas_n/latency become the legacy
            # single-model fallback and are ignored here: each class's
            # invoker takes its model's canvas geometry and latency table
            # straight off the ModelSpec, so t_slack is per-model.
            specs = {n: make_model(n) for n in config.model_names()}
            self._model_specs = specs
            base = {n: (s.table if s.table is not None
                        else s.latency_table())
                    for n, s in specs.items()}
            self._model_tables = base
            if config.online_latency:
                online = {n: OnlineLatencyTable(t) for n, t in base.items()}
                self.estimator = LatencyBank(online)
                invoker_tables = online
            else:
                invoker_tables = dict(base)

            def make_invoker(key):
                model = config.resolve_model(key)
                if model is None:
                    raise unknown_name("SLO class", key,
                                       config.model_map or {})
                spec = specs[model]
                return SLOAwareInvoker(spec.canvas_m, spec.canvas_n,
                                       invoker_tables[model],
                                       config.max_canvases,
                                       incremental=config.incremental)

            pool_classify = classify or (lambda p: None)

            def make_pool(fleet: bool = False):
                # adaptive pools keep the stock O(classes) scan even in
                # fleet mode (FleetInvokerPool is scan-equivalent, so
                # this is a speed difference, not a behaviour one)
                if config.adaptive is not None:
                    return AdaptiveInvokerPool(
                        make_invoker, pool_classify, config.adaptive,
                        model_of=config.resolve_model)
                cls = FleetInvokerPool if fleet else InvokerPool
                return cls(make_invoker, pool_classify,
                           model_of=config.resolve_model)
        else:
            if config.online_latency:
                latency = self.estimator = OnlineLatencyTable(latency)

            def make_pool(fleet: bool = False):
                if config.adaptive is not None:
                    return adaptive_uniform_pool(
                        canvas_m, canvas_n, latency, config.max_canvases,
                        incremental=config.incremental, classify=classify,
                        cfg=config.adaptive)
                fn = fleet_uniform_pool if fleet else uniform_pool
                return fn(canvas_m, canvas_n, latency, config.max_canvases,
                          incremental=config.incremental, classify=classify)
        self._make_pool = make_pool
        self.pool = make_pool()
        # the planner's cost model samples one latency table; multi-model
        # configs use the first registry model's (they only differ in
        # scale, and the planner wants a trend, not exactness)
        self._planner_table = (next(iter(self._model_tables.values()))
                               if self._model_tables else latency)
        self.platform = platform
        self.n_workers = config.n_workers
        self.placement = (placement_override
                          if placement_override is not None
                          else make_placement(config.placement)
                          if config.placement is not None else None)
        self.clock = clock_override
        self.check_invariants = config.check_invariants

    def _clock(self) -> Optional[Clock]:
        """A legacy clock instance wins; otherwise "virtual" keeps the
        engine default (a fresh VirtualClock per engine) and "wall"
        builds a fresh wall clock per run."""
        if self.clock is not None:
            return self.clock
        if self.config.clock == "virtual":
            return None
        return make_clock(self.config.clock, speed=self.config.wall_speed)

    def _shard_clocks(self, n: int) -> list:
        """One clock per shard.  Sequential: n independent `_clock()`
        instances (unchanged).  Parallel: "virtual" stays None (each
        engine builds a private VirtualClock — shard threads never share
        virtual time), and a wall clock fans out into per-thread
        :meth:`~repro.core.clock.WallClock.shard_view`\\ s so every
        shard reads the same epoch through a thread-private floor."""
        if not self.config.parallel:
            return [self._clock() for _ in range(n)]
        base = self._clock()
        if base is None:
            return [None] * n
        if hasattr(base, "shard_view"):
            return [base.shard_view() for _ in range(n)]
        return [base] + [self._clock() for _ in range(n - 1)]  # legacy override

    def _sim_executor(self, platform: Platform) -> SimExecutor:
        """A SimExecutor over ``platform``, multi-model aware: each
        model's submissions carry its weight-load cost and sample from
        its own base latency table (per-model warm-pool economics)."""
        if not self._model_specs:
            return SimExecutor(platform)
        loads = {n: s.load_s for n, s in self._model_specs.items()}
        return SimExecutor(platform, model_loads=loads,
                           model_tables=self._model_tables)

    def _executor(self):
        """One SimExecutor, or a worker pool over platform capacity
        shards (shared cost meter: billing aggregates unchanged).  A
        ctor-supplied ``executor`` (e.g. a device worker pool) is used
        as-is — the platform then only carries the cost meter."""
        if self._executor_override is not None:
            return self._executor_override, [self.platform]
        if self.n_workers == 1 and self.estimator is None:
            return self._sim_executor(self.platform), [self.platform]
        platforms = (split_platform(self.platform, self.n_workers)
                     if self.n_workers > 1 else [self.platform])
        pool = WorkerPoolExecutor([self._sim_executor(p) for p in platforms],
                                  placement=self.placement,
                                  estimator=self.estimator)
        return pool, platforms

    def run(self, streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            name: str = "tangram") -> Results:
        """Replay per-camera patch streams over shaped uplinks — the
        historical entry point, now a :class:`~repro.sources.TraceSource`
        special case of :meth:`serve_source`."""
        from repro.sources import TraceSource
        return self.serve_source(
            TraceSource(streams=streams, bandwidth_bps=bandwidth_bps),
            name=name)

    # ------------------------------------------------------ fleet sharding ----

    def _fleet_plan(self, source) -> FleetPlan:
        """Plan the shard layout for ``config.shards`` shards.  Sources
        exposing ``camera_rates()`` (e.g. ``FleetCameraSource``) feed the
        planner; otherwise routing falls back to ``camera_id % shards``
        with the worker budget split evenly."""
        config = self.config
        s = config.shards
        budget = max(config.n_workers, s)
        rates = (source.camera_rates()
                 if hasattr(source, "camera_rates") else None)
        if not rates:
            per, extra = divmod(budget, s)
            return FleetPlan(n_shards=s,
                             workers=tuple(per + (1 if i < extra else 0)
                                           for i in range(s)))
        planner = make_planner(
            config.planner or "cost",
            cost_model=FleetCostModel(latency=self._planner_table),
            worker_budget=budget)
        class_rates = (source.class_rates()
                       if hasattr(source, "class_rates") else None)
        return planner.plan(rates, class_rates=class_rates, n_shards=s)

    def _serve_sharded(self, source, name: str) -> Results:
        """The ``config.shards`` path of :meth:`serve_source`: plan the
        layout, build one private engine per shard over its platform
        slice (worker sub-pools honour the plan's per-class
        reservations), serve through a :class:`ShardedEngine`, and fold
        the per-shard rows into ``Results.shard_stats``."""
        config = self.config
        plan = self._fleet_plan(source)
        s_count = plan.n_shards
        weights = [max(plan.workers_of(s), 1) for s in range(s_count)]
        shard_platforms = (split_platform(self.platform, s_count,
                                          weights=weights)
                           if s_count > 1 else [self.platform])
        window = (max(1, config.ingestion_window // s_count)
                  if config.ingestion_window else None)
        engines = []
        platforms = []
        clocks = self._shard_clocks(s_count)
        for s in range(s_count):
            w = plan.workers_of(s)
            plat = shard_platforms[s]
            if w > 1:
                worker_plats = split_platform(plat, w)
                reserved = (plan.reservations[s]
                            if plan.reservations else {})
                placement = (ReservedClassPlacement(reserved) if reserved
                             else self.placement)
                executor = WorkerPoolExecutor(
                    [self._sim_executor(p) for p in worker_plats],
                    placement=placement, estimator=self.estimator)
                platforms.extend(worker_plats)
            else:
                executor = self._sim_executor(plat)
                platforms.append(plat)
            engines.append(ServingEngine(
                self._make_pool(fleet=True), executor,
                clock=clocks[s],
                check_invariants=self.check_invariants,
                ingestion_window=window))
        engine_cls = (ParallelShardedEngine if config.parallel
                      else ShardedEngine)
        sharded = engine_cls(engines, plan.shard_of, plan=plan)
        outcomes = sharded.serve(source)

        stats = source.stats()
        source_stats = stats.to_dict()
        source_stats["backlog_high_water"] = sharded.backlog_high_water
        source_stats["ingestion_window"] = config.ingestion_window
        records = [r for p in platforms for r in p.records]
        invocations = sharded.invocations
        return Results(
            name=name, outcomes=outcomes,
            canvas_efficiencies=[c.efficiency for inv in invocations
                                 for c in inv.canvases],
            batch_sizes=[len(inv.canvases) for inv in invocations],
            patches_per_batch=[len(inv.patches) for inv in invocations],
            bytes_sent=stats.bytes_sent,
            total_cost=self.platform.total_cost,
            invocations=len(records),
            exec_seconds=self.platform.meter.busy_seconds,
            transmission_seconds=stats.transmission_seconds,
            mean_consolidation=mean_consolidation(records),
            source_stats=source_stats,
            model_stats=records_model_stats(records) or None,
            shard_stats=sharded.shard_stats())

    def serve_source(self, source, name: str = "tangram") -> Results:
        """Serve any :mod:`repro.sources` source end-to-end and assemble
        the ``Results`` record (bandwidth + drop/degrade accounting from
        ``source.stats()``)."""
        if self.config.shards is not None:
            return self._serve_sharded(source, name)
        executor, platforms = self._executor()
        engine = ServingEngine(self.pool, executor,
                               clock=self._clock(),
                               check_invariants=self.check_invariants,
                               ingestion_window=self.config.ingestion_window)
        outcomes = engine.serve(source)

        stats = source.stats()
        source_stats = stats.to_dict()
        source_stats["backlog_high_water"] = engine.backlog_high_water
        source_stats["ingestion_window"] = self.config.ingestion_window
        records = [r for p in platforms for r in p.records]
        model_stats = records_model_stats(records)
        cache_stats = (executor.model_cache_stats()
                       if hasattr(executor, "model_cache_stats") else {})
        for model, row in cache_stats.items():
            model_stats.setdefault(model, {}).update(row)
        return Results(
            name=name, outcomes=outcomes,
            canvas_efficiencies=[c.efficiency for inv in engine.invocations
                                 for c in inv.canvases],
            batch_sizes=[len(inv.canvases) for inv in engine.invocations],
            patches_per_batch=[len(inv.patches)
                               for inv in engine.invocations],
            bytes_sent=stats.bytes_sent,
            total_cost=self.platform.total_cost,
            invocations=len(records),
            exec_seconds=self.platform.meter.busy_seconds,
            transmission_seconds=stats.transmission_seconds,
            mean_consolidation=mean_consolidation(records),
            worker_stats=(executor.worker_stats()
                          if isinstance(executor, WorkerPoolExecutor)
                          else None),
            source_stats=source_stats,
            model_stats=model_stats or None)
