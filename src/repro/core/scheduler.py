"""Tangram scheduler: a thin adapter over the unified serving engine.

The event loop, the per-class invoker pool, and the executor abstraction
live in :mod:`repro.core.engine`; this module wires them to the paper's
scenario (bandwidth-shaped arrivals -> SLO-aware batching -> serverless
platform) and assembles the ``Results`` record that every benchmark
(Figs. 8-14) reads.  ``PatchOutcome``/``Results`` are re-exported here
for backwards compatibility.

Pass ``adaptive=AIMDConfig(...)`` to put the completion-driven AIMD
controller (:mod:`repro.core.adaptive`) on the pool: per-class canvas
budgets and firing margins then track delivered completions instead of
staying at the static configuration.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.adaptive import AIMDConfig, adaptive_uniform_pool
from repro.core.clock import Clock
from repro.core.engine import (PatchOutcome, Results, ServingEngine,
                               SimExecutor, uniform_pool)
from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.data.video import merge_arrivals, shape_arrivals
from repro.serverless.platform import Platform

__all__ = ["PatchOutcome", "Results", "TangramScheduler"]


class TangramScheduler:
    """The cloud-side scheduler of Fig. 5.

    ``classify=None`` keeps the paper's single shared queue; pass
    ``engine.slo_class`` (or any ``Patch -> key`` function) to shard the
    invoker per SLO class so tight deadlines never wait behind loose ones.
    ``clock`` defaults to a fresh virtual clock per run (simulation).
    """

    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 platform: Platform, max_canvases: int = 8,
                 check_invariants: bool = False,
                 classify: Optional[Callable[[Patch], object]] = None,
                 incremental: bool = True,
                 adaptive: Optional[AIMDConfig] = None,
                 clock: Optional[Clock] = None):
        if adaptive is not None:
            self.pool = adaptive_uniform_pool(
                canvas_m, canvas_n, latency, max_canvases,
                incremental=incremental, classify=classify, cfg=adaptive)
        else:
            self.pool = uniform_pool(canvas_m, canvas_n, latency,
                                     max_canvases, incremental=incremental,
                                     classify=classify)
        self.platform = platform
        self.clock = clock
        self.check_invariants = check_invariants

    def run(self, streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            name: str = "tangram") -> Results:
        per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
        arrivals = merge_arrivals(per_cam)
        engine = ServingEngine(self.pool, SimExecutor(self.platform),
                               clock=self.clock,
                               check_invariants=self.check_invariants)
        outcomes = engine.run(arrivals)

        bytes_sent = sum(a.n_bytes for cam in per_cam for a in cam)
        trans = sum(a.t_arrive - a.patch.t_gen for cam in per_cam for a in cam)
        return Results(
            name=name, outcomes=outcomes,
            canvas_efficiencies=[c.efficiency for inv in engine.invocations
                                 for c in inv.canvases],
            batch_sizes=[len(inv.canvases) for inv in engine.invocations],
            patches_per_batch=[len(inv.patches)
                               for inv in engine.invocations],
            bytes_sent=bytes_sent,
            total_cost=self.platform.total_cost,
            invocations=len(self.platform.records),
            exec_seconds=self.platform.meter.busy_seconds,
            transmission_seconds=trans,
            mean_consolidation=self.platform.mean_consolidation)
