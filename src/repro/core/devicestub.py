"""In-process accelerator stand-in for overlap tests and benchmarks.

A real accelerator accepts a dispatch, queues it behind earlier work, and
crunches without consuming host CPU; the host only stalls when it *joins*
a result.  :class:`StubAccelerator` reproduces exactly that contract with
a single worker thread (a serial device queue) and a fixed per-invocation
service time, so engine-level overlap measurements are deterministic and
independent of how fast the host's XLA happens to be:

* ``serve_fn(params, canvases)`` enqueues one detector call and
  immediately returns :class:`DeviceFuture` handles — the same shape
  contract as the jit'd detector (objectness ``(B, s, s)``, boxes
  ``(B, s, s, 4)``).
* ``DeviceFuture.is_ready()`` / ``result()`` mirror ``jax.Array``'s
  readiness probe and ``block_until_ready`` join, so
  ``AsyncDeviceExecutor`` drives stub and real device identically.
* ``sync(tree)`` is the executor's ``sync`` hook: joins every
  ``DeviceFuture`` in the tree and ``block_until_ready``s any real JAX
  arrays alongside them (the stitch/unstitch legs still run under XLA).
"""
from __future__ import annotations

import concurrent.futures
import time
from typing import Callable, Tuple, Union

import numpy as np


class DeviceFuture:
    """One output of an in-flight stub call (duck-types a jax.Array)."""

    def __init__(self, fut: concurrent.futures.Future, idx: int):
        self._fut = fut
        self._idx = idx

    def is_ready(self) -> bool:
        return self._fut.done()

    def result(self) -> np.ndarray:
        return self._fut.result()[self._idx]

    def __array__(self, dtype=None):
        a = np.asarray(self.result())
        return a.astype(dtype) if dtype is not None else a


class StubAccelerator:
    """Serial device queue with a fixed per-invocation service time."""

    def __init__(self, service_s: float, grid: int = 2):
        self.service_s = service_s
        self.grid = grid
        self.n_calls = 0
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)

    def serve_fn(self, params, canvases) -> Tuple[DeviceFuture, DeviceFuture]:
        b = int(canvases.shape[0])
        self.n_calls += 1
        fut = self._pool.submit(self._run, b, canvases)
        return DeviceFuture(fut, 0), DeviceFuture(fut, 1)

    def _run(self, b: int, canvases):
        # causal ordering of a real detector: service cannot start before
        # the input batch exists — join the (possibly still-dispatching)
        # stitched canvases first, off the caller's thread
        try:
            import jax
            jax.block_until_ready(canvases)
        except ImportError:            # plain numpy input
            pass
        time.sleep(self.service_s)
        s = self.grid
        return (np.zeros((b, s, s), np.float32),
                np.zeros((b, s, s, 4), np.float32))

    def sync(self, tree) -> None:
        import jax

        leaves = jax.tree_util.tree_leaves(
            tree, is_leaf=lambda v: isinstance(v, DeviceFuture))
        for leaf in leaves:
            if isinstance(leaf, DeviceFuture):
                leaf.result()
            else:
                jax.block_until_ready(leaf)

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "StubAccelerator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class VirtualAccelerator:
    """Engine-time device stand-in: a submit/complete executor whose
    finish times live on the *engine* clock, not the wall clock.

    ``service`` is seconds per invocation — a float, or a callable
    ``service(batch_size) -> seconds`` for batch-dependent devices.  The
    executor models one serial device queue: an invocation starts when
    both it has been submitted and the queue is free, and finishes
    ``service`` later, so ``t_finish = max(t_submit, queue_free) +
    service``.  No threads, no sleeps — drifted-device and placement
    tests stay exactly reproducible under the virtual clock (a real
    ``StubAccelerator`` would race engine virtual time against wall
    sleeps).

    Handles carry their finish time at submit, so the engine schedules
    delivery on the event heap — the device analogue of ``SimExecutor``'s
    "the model tells us now, the event fires later".
    """

    def __init__(self, service: Union[float, Callable[[int], float]]):
        self.service = service
        self.queue_free = 0.0
        self.n_calls = 0
        self.per_batch: list = []      # (t_submit, batch, t_finish) log

    def _service_s(self, batch: int) -> float:
        if callable(self.service):
            return float(self.service(batch))
        return float(self.service)

    def submit(self, inv) -> "ExecHandle":
        from repro.core.engine import Completion, ExecHandle

        batch = len(inv.canvases) or len(inv.patches)
        start = max(inv.t_submit, self.queue_free)
        t_finish = start + self._service_s(batch)
        self.queue_free = t_finish
        self.n_calls += 1
        self.per_batch.append((inv.t_submit, batch, t_finish))
        return ExecHandle(inv, t_finish=t_finish,
                          completion=Completion(inv, t_finish))

    def resolve(self, handle) -> "Completion":
        return handle.completion
