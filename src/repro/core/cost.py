"""Serverless cost models.

``alibaba_cost`` is Eqn. (1) of the paper with the published unit prices
(Alibaba Cloud Function Compute, GPU instances).  ``TPUCostModel`` maps the
same objective to chip-seconds on the v5e serving fabric so the scheduler
optimizes an identical quantity on either substrate.
"""
from __future__ import annotations

import dataclasses
import threading

# unit prices from Section III-B
P_C = 2.138e-5        # $ / vCPU-second
P_M = 2.138e-5        # $ / GB(mem)-second
P_G = 1.05e-4         # $ / GB(GPU mem)-second
P_REQ = 2e-7          # $ / request


def alibaba_cost(t_f: float, n_vcpu: float = 2.0, mem_gb: float = 4.0,
                 gpu_mem_gb: float = 6.0) -> float:
    """Eqn. (1): C = T_f * (n_C P_C + m_M P_M + m_G P_G) + P_req."""
    return t_f * (n_vcpu * P_C + mem_gb * P_M + gpu_mem_gb * P_G) + P_REQ


def rate_per_second(n_vcpu: float = 2.0, mem_gb: float = 4.0,
                    gpu_mem_gb: float = 6.0) -> float:
    return n_vcpu * P_C + mem_gb * P_M + gpu_mem_gb * P_G


@dataclasses.dataclass(frozen=True)
class TPUCostModel:
    """Chip-second pricing for a v5e slice (on-demand list-ish price)."""

    usd_per_chip_hour: float = 1.2
    chips: int = 4                    # chips in one function slice
    p_req: float = P_REQ

    def cost(self, t_f: float) -> float:
        return t_f * self.chips * self.usd_per_chip_hour / 3600.0 + self.p_req


@dataclasses.dataclass
class CostMeter:
    """Accumulates per-invocation costs (Fig. 8 / Fig. 12 accounting).

    `split_platform` hands one meter to every shard's platform so fleet
    billing aggregates exactly; under the parallel fleet runtime those
    shards charge from concurrent threads, so the read-modify-write
    accumulation happens under a lock.  (The lock is uncontended in the
    sequential path and invisible to the dataclass API — `total`,
    `invocations` and `busy_seconds` stay plain readable fields.)
    """

    n_vcpu: float = 2.0
    mem_gb: float = 4.0
    gpu_mem_gb: float = 6.0
    total: float = 0.0
    invocations: int = 0
    busy_seconds: float = 0.0

    def __post_init__(self):
        self._lock = threading.Lock()

    def charge(self, t_f: float) -> float:
        c = alibaba_cost(t_f, self.n_vcpu, self.mem_gb, self.gpu_mem_gb)
        with self._lock:
            self.total += c
            self.invocations += 1
            self.busy_seconds += t_f
        return c
