"""Online SLO-aware Batching Invoker (Algorithm 2, lines 1-23).

Event-driven over a virtual clock.  On every patch arrival the queue is
restitched, the Latency Estimator gives the conservative batch time
T_slack, and the invocation instant is ``t_remain = t_DDL - T_slack``
(Eqn. 8).  The invoker fires:

* at ``t_remain`` (timer)                                    [lines 19-22]
* immediately, dispatching the *previous* canvases, when adding the new
  patch would make the earliest deadline unmeetable or overflow function
  memory; the new patch seeds the next queue                 [lines 11-17]

Note: line 11 of the paper's pseudo-code reads ``t_remain > t``; the prose
("If the estimated t_remain has already exceeded the current time ...
adding this patch to the queue would violate the SLO") makes clear the
intended condition is ``t_remain < t`` — we implement the prose.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.core.stitching import BatchPlan, Canvas, build_batch_plan, stitch


@dataclasses.dataclass
class Invocation:
    t_submit: float
    canvases: List[Canvas]
    patches: List[Patch]
    t_slack: float
    reason: str                 # timer | slo_pressure | memory | late | flush
    plan: Optional[BatchPlan] = None   # built lazily by batch_plan()

    @property
    def batch_size(self) -> int:
        return len(self.canvases)

    def batch_plan(self) -> BatchPlan:
        """The device-ready multi-canvas plan for this invocation.  Built
        on first use so pure-simulation paths (scheduler sweeps) never pay
        for record packing; executors that move pixels always need it."""
        if self.plan is None:
            m = self.canvases[0].m if self.canvases else 1
            n = self.canvases[0].n if self.canvases else 1
            self.plan = build_batch_plan(self.patches, self.canvases, m, n)
        return self.plan


class SLOAwareInvoker:
    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 max_canvases: int = 8):
        self.m, self.n = canvas_m, canvas_n
        self.latency = latency
        self.max_canvases = max_canvases
        self.queue: List[Patch] = []
        self.canvases: List[Canvas] = []
        self.t_remain: float = math.inf

    # ------------------------------------------------------------ events ----

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        """Lines 4-18.  Returns invocations fired by this arrival."""
        fired: List[Invocation] = []
        old_queue = list(self.queue)
        old_canvases = self.canvases

        self.queue.append(patch)
        self._restitch()

        if self.t_remain < t_now or len(self.canvases) > self.max_canvases:
            reason = ("memory" if len(self.canvases) > self.max_canvases
                      else "slo_pressure")
            if old_queue:
                fired.append(Invocation(
                    t_now, old_canvases, old_queue,
                    self.latency.t_slack(len(old_canvases)), reason))
            self.queue = [patch]
            self._restitch()
            if self.t_remain < t_now:
                # a lone patch that still cannot meet its SLO: fire ASAP to
                # minimise lateness (not covered by the paper's pseudo-code)
                fired.append(self._fire(t_now, "late"))
        return fired

    def poll(self, t_now: float) -> Optional[Invocation]:
        """Lines 19-22: the timer alignment check."""
        if self.queue and t_now >= self.t_remain:
            return self._fire(max(t_now, self.t_remain), "timer")
        return None

    def flush(self, t_now: float) -> Optional[Invocation]:
        if self.queue:
            return self._fire(t_now, "flush")
        return None

    def next_timer(self) -> float:
        return self.t_remain if self.queue else math.inf

    # ---------------------------------------------------------- internals ----

    def _restitch(self):
        self.canvases = stitch(self.queue, self.m, self.n)
        t_ddl = min(p.deadline for p in self.queue)
        t_slack = self.latency.t_slack(len(self.canvases))
        self.t_remain = t_ddl - t_slack

    def _fire(self, t_now: float, reason: str) -> Invocation:
        inv = Invocation(t_now, self.canvases, list(self.queue),
                         self.latency.t_slack(len(self.canvases)), reason)
        self.queue = []
        self.canvases = []
        self.t_remain = math.inf
        return inv
