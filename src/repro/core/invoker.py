"""Online SLO-aware Batching Invoker (Algorithm 2, lines 1-23).

Event-driven over a virtual clock.  On every patch arrival the queue is
restitched, the Latency Estimator gives the conservative batch time
T_slack, and the invocation instant is ``t_remain = t_DDL - T_slack``
(Eqn. 8).  The invoker fires:

* at ``t_remain`` (timer)                                    [lines 19-22]
* immediately, dispatching the *previous* canvases, when adding the new
  patch would make the earliest deadline unmeetable or overflow function
  memory; the new patch seeds the next queue                 [lines 11-17]

Note: line 11 of the paper's pseudo-code reads ``t_remain > t``; the prose
("If the estimated t_remain has already exceeded the current time ...
adding this patch to the queue would violate the SLO") makes clear the
intended condition is ``t_remain < t`` — we implement the prose.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.latency import LatencyTable
from repro.core.partitioning import Patch
from repro.core.stitching import (BatchPlan, Canvas, PackState,
                                  build_batch_plan, stitch)


@dataclasses.dataclass
class Invocation:
    t_submit: float
    canvases: List[Canvas]
    patches: List[Patch]
    t_slack: float
    reason: str                 # timer | slo_pressure | memory | late | flush
    plan: Optional[BatchPlan] = None   # built lazily by batch_plan()
    key: object = None          # SLO class, when fired via an InvokerPool
    cost_canvases: Optional[float] = None  # billing override (baselines)
    model: Optional[str] = None  # registry model name (InvokerPool's
                                # model_of; None: the implicit single model)
    shard: Optional[int] = None  # fleet shard that fired it (tagged by
                                # ShardedEngine; None outside a fleet)

    @property
    def batch_size(self) -> int:
        return len(self.canvases)

    def batch_plan(self) -> BatchPlan:
        """The device-ready multi-canvas plan for this invocation.  Built
        on first use so pure-simulation paths (scheduler sweeps) never pay
        for record packing; executors that move pixels always need it."""
        if self.plan is None:
            m = self.canvases[0].m if self.canvases else 1
            n = self.canvases[0].n if self.canvases else 1
            self.plan = build_batch_plan(self.patches, self.canvases, m, n)
        return self.plan


class SLOAwareInvoker:
    """One SLO class's batching queue.

    ``incremental=True`` (default) keeps the guillotine free-rect state
    live across arrivals (``PackState``): each arrival is a read-only fit
    probe plus one placement, and the full repack only happens when the
    queue is rebuilt after a fire — the paper's from-scratch semantics at
    O(canvases) instead of O(queue * canvases) per arrival.
    ``incremental=False`` keeps the literal restitch-everything behaviour
    for equivalence tests and the perf benchmark's baseline arm.

    ``max_canvases`` and ``margin`` are live knobs: a completion-driven
    controller (``core.adaptive.AdaptiveInvokerPool``) may retune them
    between arrivals.  ``margin`` is extra firing slack subtracted from
    ``t_remain`` on top of the latency estimate — it absorbs delay the
    offline table cannot see (platform queueing, cold starts), observed
    from completions.  The default 0.0 reproduces Eqn. 8 exactly.
    """

    def __init__(self, canvas_m: int, canvas_n: int, latency: LatencyTable,
                 max_canvases: int = 8, incremental: bool = True,
                 margin: float = 0.0):
        self.m, self.n = canvas_m, canvas_n
        self.latency = latency
        self.max_canvases = max_canvases
        self.margin = margin
        self.incremental = incremental
        self.queue: List[Patch] = []
        self.canvases: List[Canvas] = []
        self.t_remain: float = math.inf
        self._pack = PackState(canvas_m, canvas_n)
        self._t_ddl: float = math.inf      # running min deadline over queue

    # ------------------------------------------------------------ events ----

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        """Lines 4-18.  Returns invocations fired by this arrival."""
        fired: List[Invocation] = []

        n_after, packed = self._probe_canvases(patch)
        t_remain_after = (min(self._t_ddl, patch.deadline)
                          - self.latency.t_slack(n_after) - self.margin)

        if t_remain_after < t_now or n_after > self.max_canvases:
            reason = ("memory" if n_after > self.max_canvases
                      else "slo_pressure")
            if self.queue:
                # dispatch the live packing untouched; the new patch seeds
                # the next queue (the fire closes these canvases, so this
                # is the sanctioned full-repack boundary; the probe's
                # packing is for the abandoned queue+patch, so drop it)
                fired.append(Invocation(
                    t_now, self.canvases, self.queue,
                    self.latency.t_slack(len(self.canvases)), reason))
                self._clear()
            self._append(patch)
            if self.t_remain < t_now:
                # a lone patch that still cannot meet its SLO: fire ASAP to
                # minimise lateness (not covered by the paper's pseudo-code)
                fired.append(self._fire(t_now, "late"))
        else:
            self._append(patch, packed)
        return fired

    def poll(self, t_now: float) -> Optional[Invocation]:
        """Lines 19-22: the timer alignment check."""
        if self.queue and t_now >= self.t_remain:
            return self._fire(max(t_now, self.t_remain), "timer")
        return None

    def flush(self, t_now: float) -> Optional[Invocation]:
        if self.queue:
            return self._fire(t_now, "flush")
        return None

    def next_timer(self) -> float:
        return self.t_remain if self.queue else math.inf

    # ---------------------------------------------------------- internals ----

    def _probe_canvases(self, patch: Patch):
        """Canvas count of ``stitch(queue + [patch])`` without committing.

        Returns ``(count, packed)``: in from-scratch mode ``packed`` is
        the full restitch (handed to ``_append`` so the literal paper
        semantics still stitch exactly once per arrival); incrementally
        it is None — the read-only fit probe suffices.
        """
        if not self.incremental:
            packed = stitch(self.queue + [patch], self.m, self.n)
            return len(packed), packed
        if patch.w > self.n or patch.h > self.m:
            raise ValueError(
                f"patch ({patch.w}x{patch.h}) exceeds canvas "
                f"({self.n}x{self.m})")
        return (len(self.canvases)
                + (0 if self._pack.fits(patch.w, patch.h) else 1)), None

    def _append(self, patch: Patch, packed: Optional[List[Canvas]] = None):
        """Commit one arrival into the queue and the packing state."""
        self.queue.append(patch)
        if self.incremental:
            self._pack.append(patch)
            self.canvases = self._pack.canvases
        elif packed is not None:
            self.canvases = packed
        else:
            self.canvases = stitch(self.queue, self.m, self.n)
        self._t_ddl = min(self._t_ddl, patch.deadline)
        self.t_remain = (self._t_ddl
                         - self.latency.t_slack(len(self.canvases))
                         - self.margin)

    def _clear(self):
        self.queue = []
        self.canvases = []
        self.t_remain = math.inf
        self._pack = PackState(self.m, self.n)
        self._t_ddl = math.inf

    def _fire(self, t_now: float, reason: str) -> Invocation:
        inv = Invocation(t_now, self.canvases, self.queue,
                         self.latency.t_slack(len(self.canvases)), reason)
        self._clear()
        return inv
