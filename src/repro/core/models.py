"""Model registry: named :class:`ModelSpec` records behind ``make_model``.

The serving spine used to hard-code one detector built once in
``launch/serve.py``; every layer that matters to multi-tenant serving —
invoker latency tables, worker placement, the serverless platform's
warm pools — needs to know *which* model an invocation runs.  This
module is the single source of that identity:

* a :class:`ModelSpec` names a servable model: its detector trunk
  config, canvas geometry, a weight-size estimate (what a serverless
  instance must load before it can serve the model), and optionally an
  explicit latency profile;
* ``register_model`` / ``make_model`` mirror the factory quartet
  (``make_classify`` / ``make_clock`` / ``make_executor`` /
  ``make_source``): ``ServeConfig.model_map`` values resolve here, with
  the unified unknown-name error;
* the registry is seeded from the configs zoo — the paper's own
  ``tangram`` detector plus ``vit_s16`` and ``efficientnet_b7`` backed
  variants — and tests/benchmarks register their own small specs.

A spec separates three concerns so every consumer gets what it needs
without building the others:

* **economics** — ``weight_bytes`` / ``load_s`` feed the platform's
  per-model warm pools and the worker pool's weight caches;
* **latency** — :meth:`ModelSpec.latency_table` serves the per-model
  profile ``t_slack`` fires against (explicit ``table`` wins, else the
  analytical roofline model over the trunk dims);
* **execution** — :meth:`ModelSpec.build` jit-compiles a servable
  detector through the same path as ``launch/serve.py`` (reduced dims
  by default so CPU runs stay fast; pass ``reduced=False`` for the full
  trunk).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional, Tuple

from repro.config import DetectorConfig
from repro.core.latency import LatencyTable, detector_latency_model
from repro.core.registry import lookup

__all__ = ["ModelSpec", "make_model", "register_model", "model_names"]

#: bytes per parameter by param dtype (weight-size estimates)
_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4}

#: default host->accelerator weight-load bandwidth (PCIe gen4 x16-ish);
#: load_s = weight_bytes / load_bw is the modeled per-model cold cost
_DEFAULT_LOAD_BW = 12.5e9


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """One servable model: identity, geometry, economics, and builders.

    ``canvas_m`` / ``canvas_n`` / ``weight_bytes`` default from ``arch``
    when one is given (canvas geometry from its canvas size, weight
    bytes from its param count and dtype); specs without an ``arch``
    (pure-simulation models in tests/benchmarks) must state geometry and
    weight size explicitly and carry an explicit ``table``.
    """

    name: str
    arch: Optional[DetectorConfig] = None
    canvas_m: Optional[int] = None
    canvas_n: Optional[int] = None
    weight_bytes: Optional[float] = None
    table: Optional[LatencyTable] = None
    load_bw: float = _DEFAULT_LOAD_BW
    #: serving precision override: None serves the arch's param dtype;
    #: "int8" serves quantized-resident weights (1 byte/param economics,
    #: 2x MXU rate + halved weight streaming in the latency default, and
    #: :meth:`build` quantizes the fp init through models/quantize.py)
    dtype: Optional[str] = None
    description: str = ""

    def __post_init__(self):
        if self.dtype not in (None, "int8"):
            raise ValueError(f"ModelSpec {self.name!r}: unsupported dtype "
                             f"{self.dtype!r} (None or 'int8')")
        if self.arch is not None:
            if self.canvas_m is None:
                object.__setattr__(self, "canvas_m", self.arch.canvas)
            if self.canvas_n is None:
                object.__setattr__(self, "canvas_n", self.arch.canvas)
            if self.weight_bytes is None:
                per_param = (1 if self.dtype == "int8" else
                             _DTYPE_BYTES.get(self.arch.param_dtype, 4))
                object.__setattr__(self, "weight_bytes",
                                   float(self.arch.n_params * per_param))
        if self.canvas_m is None or self.canvas_n is None:
            raise ValueError(f"ModelSpec {self.name!r} needs canvas "
                             f"geometry (canvas_m/canvas_n or an arch)")
        if self.weight_bytes is None:
            raise ValueError(f"ModelSpec {self.name!r} needs weight_bytes "
                             f"(explicit or derivable from an arch)")
        if self.table is None and self.arch is None:
            raise ValueError(f"ModelSpec {self.name!r} needs a latency "
                             f"source (an explicit table or an arch)")
        if self.load_bw <= 0:
            raise ValueError(f"load_bw must be positive, got {self.load_bw}")

    # ------------------------------------------------------- economics ----

    @property
    def load_s(self) -> float:
        """Modeled seconds to move the weights onto an accelerator — the
        per-model half of a serverless cold start."""
        return float(self.weight_bytes) / self.load_bw

    # --------------------------------------------------------- latency ----

    def latency_table(self, max_batch: int = 16,
                      slack_sigmas: float = 3.0) -> LatencyTable:
        """The per-model profile ``t_slack`` fires against: the explicit
        ``table`` when given, else the analytical roofline model over the
        trunk dims at this spec's canvas geometry."""
        if self.table is not None:
            return self.table
        a = self.arch
        model = detector_latency_model(
            self.canvas_m, self.canvas_n, patch=a.patch,
            n_layers=a.n_layers, d_model=a.d_model, d_ff=a.d_ff)
        if self.dtype == "int8":
            # int8 MXU issues at 2x the fp rate and streams half the
            # weight bytes (1 B/param vs the model's bf16 2 B/param), so
            # the quantized profile differs whether the trunk is
            # compute- or memory-bound — it must never reuse the fp mu
            model = dataclasses.replace(
                model, flops_per_canvas=model.flops_per_canvas * 0.5,
                weight_bytes=model.weight_bytes * 0.5)
        return model.build_table(max_batch, slack_sigmas=slack_sigmas)

    # ------------------------------------------------------- execution ----

    def reduced_arch(self, canvas: int) -> DetectorConfig:
        """A small, CPU-runnable stand-in for the trunk: same family and
        patching, dims scaled down (distinct per source trunk, so two
        specs' jitted functions genuinely differ)."""
        a = self.arch
        if a is None:
            raise ValueError(f"ModelSpec {self.name!r} has no arch to build")
        patch = a.patch if canvas % a.patch == 0 else 32
        while canvas % patch:
            patch //= 2
        d_model = max(32, a.d_model // 12)
        return DetectorConfig(
            name=f"{self.name}-reduced", canvas=canvas, patch=patch,
            n_layers=max(1, a.n_layers // 6), d_model=d_model,
            n_heads=4, d_ff=2 * d_model,
            param_dtype="float32", compute_dtype="float32")

    def build(self, canvas: Optional[int] = None, reduced: bool = True):
        """Jit-compile a servable detector for this spec.

        Returns ``(cfg, params, serve_fn, rules)`` exactly like the
        historical ``launch.serve.build_detector``.  ``reduced=True``
        (default) serves the scaled-down trunk at ``canvas`` (default
        256) so drivers and tests run on CPU; ``reduced=False`` builds
        the full trunk at the spec's native canvas.  Params are seeded
        per model name, so two models' weights differ deterministically.

        ``dtype="int8"`` specs initialize the full-precision weights of
        their base model (seeded by the name minus the ``_int8`` suffix,
        so ``tangram_int8`` is literally ``tangram`` quantized) and
        quantize them through ``models/quantize.py``; the returned cfg
        carries ``quant_weights=True`` and ``serve_fn`` runs the
        int8-resident trunk.
        """
        import jax

        from repro import param as param_lib
        from repro.models import detector as detector_lib
        from repro.sharding import ShardingConfig

        if reduced:
            cfg = self.reduced_arch(canvas or 256)
        else:
            cfg = (self.arch if canvas is None
                   else dataclasses.replace(self.arch, canvas=canvas))
        rules = ShardingConfig.make().rules
        seed_name = (self.name[:-len("_int8")]
                     if self.dtype == "int8" and self.name.endswith("_int8")
                     else self.name)
        seed = zlib.crc32(seed_name.encode()) & 0x7FFFFFFF
        fp_cfg = dataclasses.replace(cfg, quant_weights=False)
        params = param_lib.init_params(jax.random.PRNGKey(seed),
                                       detector_lib.param_specs(fp_cfg))
        if self.dtype == "int8":
            from repro.models import quantize as quantize_lib

            cfg = dataclasses.replace(cfg, quant_weights=True)
            params = quantize_lib.quantize_params(
                detector_lib.param_specs(cfg), params)
        else:
            cfg = fp_cfg
        serve_cfg = cfg
        serve_fn = jax.jit(
            lambda p, x: detector_lib.serve(serve_cfg, p, x, rules))
        return cfg, params, serve_fn, rules


# ------------------------------------------------------------- registry ----

_MODELS: Dict[str, ModelSpec] = {}
_seeded = False


def register_model(spec: ModelSpec) -> ModelSpec:
    """Register (or replace — last registration wins) a named spec."""
    _MODELS[spec.name] = spec
    return spec


def _ensure_seeded():
    """Seed the registry from the configs zoo on first use (imports of
    this module stay cheap; the zoo configs import the model stack)."""
    global _seeded
    if _seeded:
        return
    _seeded = True

    from repro.configs import efficientnet_b7, tangram_detector, vit_s16
    from repro.models.efficientnet import count_params

    # the paper's own serving model: ViT-B/32 trunk on 1024^2 canvases
    register_model(ModelSpec(
        name="tangram", arch=tangram_detector.ARCH,
        description="the paper's detector (ViT-B/32 trunk, 1024^2 canvas)"))

    # its int8-resident variant: same trunk quantized through
    # models/quantize.py — half the load bytes, a faster latency profile
    register_model(ModelSpec(
        name="tangram_int8", arch=tangram_detector.ARCH, dtype="int8",
        description="tangram with int8-resident trunk weights "
                    "(quantized serve path)"))

    # a lighter detector on the ViT-S/16 trunk (finer patching, ~4x
    # fewer trunk params): the natural choice for tight SLO classes
    v = vit_s16.ARCH
    vit_s16_det = DetectorConfig(
        name="vit-s16-det", canvas=1024, patch=v.patch,
        n_layers=v.n_layers, d_model=v.d_model, n_heads=v.n_heads,
        d_ff=v.d_ff, param_dtype="bfloat16", compute_dtype="bfloat16")
    register_model(ModelSpec(
        name="vit_s16", arch=vit_s16_det,
        description="detector on the ViT-S/16 trunk (light, fine patches)"))
    register_model(ModelSpec(
        name="vit_s16_int8", arch=vit_s16_det, dtype="int8",
        description="vit_s16 with int8-resident trunk weights"))

    # EfficientNet-B7-class detector.  The repo's detector head runs on
    # a ViT trunk, so the servable build uses a transformer substitute
    # sized to B7's compute class; the weight economics (what a
    # serverless instance must load) come from the real conv net's
    # param count.
    e = efficientnet_b7.ARCH
    register_model(ModelSpec(
        name="efficientnet_b7",
        arch=DetectorConfig(
            name="efficientnet-b7-det", canvas=1024, patch=32,
            n_layers=18, d_model=512, n_heads=8, d_ff=2048,
            param_dtype="bfloat16", compute_dtype="bfloat16"),
        weight_bytes=float(count_params(e)
                           * _DTYPE_BYTES.get(e.param_dtype, 4)),
        description="EfficientNet-B7-class detector (conv-net weight "
                    "economics, transformer substitute trunk)"))


def make_model(name: str) -> ModelSpec:
    """Model-name -> :class:`ModelSpec`, mirroring ``make_classify`` /
    ``make_clock`` / ``make_executor`` / ``make_source`` — the named-
    reference resolution for ``ServeConfig.model`` / ``model_map``."""
    _ensure_seeded()
    return lookup("model", _MODELS, name)


def model_names() -> Tuple[str, ...]:
    """Registered model names (seeds the zoo on first call)."""
    _ensure_seeded()
    return tuple(sorted(_MODELS))
