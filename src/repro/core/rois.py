"""Foreground mask -> RoI bounding boxes.

Pipeline (all static shapes, jit-able):
  1. max-pool downsample the mask by ``downsample`` (small objects survive),
  2. morphological dilation (``dilate`` rounds of 3x3 max) to merge nearby
     fragments,
  3. connected components by iterative min-label propagation
     (lax.while_loop to fixpoint),
  4. per-component bbox via scatter-min/max, compacted to the ``max_rois``
     largest components by pixel count.

Returns boxes in full-resolution pixel coords (x0, y0, x1, y1) + validity.
A numpy reference (``numpy_rois``) exists for property tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RoIConfig:
    downsample: int = 8
    dilate: int = 2
    max_rois: int = 64
    min_area: int = 2          # in downsampled cells

    def degraded(self, factor: int = 2) -> "RoIConfig":
        """A reduced-quality variant for source-side overload response:
        coarser grid (small objects may be lost), fewer components —
        cheaper to extract and produces fewer, coarser patches."""
        return dataclasses.replace(
            self, downsample=self.downsample * factor,
            max_rois=max(1, self.max_rois // factor))


def _maxpool(mask: jnp.ndarray, k: int) -> jnp.ndarray:
    h, w = mask.shape
    m = mask[: h - h % k, : w - w % k]
    m = m.reshape(h // k, k, w // k, k)
    return m.any(axis=(1, 3))


def _dilate(mask: jnp.ndarray, rounds: int) -> jnp.ndarray:
    for _ in range(rounds):
        p = jnp.pad(mask, 1)
        mask = (p[:-2, 1:-1] | p[2:, 1:-1] | p[1:-1, :-2] | p[1:-1, 2:]
                | p[1:-1, 1:-1])
    return mask


def _label(mask: jnp.ndarray) -> jnp.ndarray:
    """Connected components (4-neighborhood) via min-label propagation."""
    h, w = mask.shape
    init = jnp.where(mask, jnp.arange(h * w, dtype=jnp.int32).reshape(h, w),
                     jnp.int32(h * w))

    def step(labels):
        p = jnp.pad(labels, 1, constant_values=h * w)
        nbr = jnp.minimum(jnp.minimum(p[:-2, 1:-1], p[2:, 1:-1]),
                          jnp.minimum(p[1:-1, :-2], p[1:-1, 2:]))
        return jnp.where(mask, jnp.minimum(labels, nbr), h * w)

    def cond(carry):
        labels, prev_changed = carry
        return prev_changed

    def body(carry):
        labels, _ = carry
        new = step(labels)
        return new, jnp.any(new != labels)

    labels, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True)))
    return labels


def extract_rois(mask: jnp.ndarray, cfg: RoIConfig = RoIConfig()
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """mask: (H, W) bool full-res -> (boxes (max_rois, 4) int32, valid)."""
    ds = cfg.downsample
    small = _dilate(_maxpool(mask, ds), cfg.dilate)
    hd, wd = small.shape
    labels = _label(small)                              # (hd, wd), hd*wd = bg

    n = hd * wd
    flat = labels.reshape(-1)
    ys, xs = jnp.divmod(jnp.arange(n, dtype=jnp.int32), wd)
    valid_px = flat < n

    count = jnp.zeros(n + 1, jnp.int32).at[flat].add(1)
    x0 = jnp.full(n + 1, wd, jnp.int32).at[flat].min(jnp.where(valid_px, xs, wd))
    y0 = jnp.full(n + 1, hd, jnp.int32).at[flat].min(jnp.where(valid_px, ys, hd))
    x1 = jnp.zeros(n + 1, jnp.int32).at[flat].max(jnp.where(valid_px, xs, 0))
    y1 = jnp.zeros(n + 1, jnp.int32).at[flat].max(jnp.where(valid_px, ys, 0))
    count = count.at[n].set(0)                          # background bucket

    top_count, top_idx = jax.lax.top_k(count[:-1], cfg.max_rois)
    valid = top_count >= cfg.min_area
    boxes = jnp.stack([
        x0[top_idx] * ds,
        y0[top_idx] * ds,
        (x1[top_idx] + 1) * ds,
        (y1[top_idx] + 1) * ds,
    ], axis=-1).astype(jnp.int32)
    boxes = boxes * valid[:, None]
    return boxes, valid


@functools.lru_cache(maxsize=None)
def rois_jit(cfg: RoIConfig = RoIConfig()):
    """Jitted :func:`extract_rois` specialised to ``cfg`` (cached per
    config, so sources can flip between normal and degraded quality
    without recompiling every frame)."""
    return jax.jit(lambda mask: extract_rois(mask, cfg))


def extract_rois_jit(mask, cfg: RoIConfig = RoIConfig()):
    return rois_jit(cfg)(mask)


# ------------------------------------------------------------- reference ----

def numpy_rois(mask: np.ndarray, cfg: RoIConfig = RoIConfig()):
    """Reference implementation with a classic two-pass flood fill."""
    ds = cfg.downsample
    h, w = mask.shape
    small = mask[: h - h % ds, : w - w % ds].reshape(
        h // ds, ds, w // ds, ds).any(axis=(1, 3))
    for _ in range(cfg.dilate):
        p = np.pad(small, 1)
        small = (p[:-2, 1:-1] | p[2:, 1:-1] | p[1:-1, :-2] | p[1:-1, 2:]
                 | p[1:-1, 1:-1])
    hd, wd = small.shape
    labels = -np.ones((hd, wd), np.int32)
    comps = []
    for i in range(hd):
        for j in range(wd):
            if small[i, j] and labels[i, j] < 0:
                stack = [(i, j)]
                labels[i, j] = len(comps)
                px = []
                while stack:
                    y, x = stack.pop()
                    px.append((y, x))
                    for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                        yy, xx = y + dy, x + dx
                        if 0 <= yy < hd and 0 <= xx < wd and small[yy, xx] \
                                and labels[yy, xx] < 0:
                            labels[yy, xx] = len(comps)
                            stack.append((yy, xx))
                comps.append(px)
    comps.sort(key=len, reverse=True)
    boxes, valid = [], []
    for px in comps[: cfg.max_rois]:
        if len(px) < cfg.min_area:
            continue
        ys = [p[0] for p in px]
        xs = [p[1] for p in px]
        boxes.append((min(xs) * ds, min(ys) * ds,
                      (max(xs) + 1) * ds, (max(ys) + 1) * ds))
        valid.append(True)
    return np.array(boxes, np.int32).reshape(-1, 4), np.array(valid, bool)
