"""Patch-stitching Solver (Algorithm 2, lines 24-39).

Guillotine 2-D packing with the paper's exact placement rule: among free
rectangles that fit the patch, choose the one minimizing
``min(w_c - w_i, h_c - h_i)`` (best-short-side-fit), place the patch at the
bottom-left corner, and split the residual space into two non-overlapping
rectangles along the *shorter axis* of the free rectangle.  Patches are
never overlapped, rotated, resized, or padded.  When no free rectangle
fits, a new canvas is opened.

The paper restitches from scratch on every arrival (``C <-
Patch_stitching_solver(Q, M, N)``), so placements are a pure function of
the queue.  Because the solver consumes the queue *in order* and never
moves a placed patch, packing ``Q + [p]`` equals packing ``Q`` and then
placing ``p`` into the resulting free-rectangle state — :class:`PackState`
exploits this to append each arrival in O(canvases * free rects) instead
of repacking the whole queue, falling back to a full repack only when the
queue is rebuilt (canvas closed / patch evicted).  ``stitch`` and
``PackState.append`` share one placement routine, so the equivalence holds
by construction (and is pinned by a property test).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partitioning import Patch


@dataclasses.dataclass(frozen=True)
class FreeRect:
    x: int
    y: int
    w: int
    h: int


@dataclasses.dataclass(frozen=True)
class Placement:
    patch_idx: int          # index into the stitched queue
    canvas_idx: int
    x: int
    y: int
    w: int
    h: int


@dataclasses.dataclass
class Canvas:
    m: int                  # height (M)
    n: int                  # width  (N)
    free: List[FreeRect] = dataclasses.field(default_factory=list)
    placements: List[Placement] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.free and not self.placements:
            self.free = [FreeRect(0, 0, self.n, self.m)]

    @property
    def used_area(self) -> int:
        return sum(p.w * p.h for p in self.placements)

    @property
    def efficiency(self) -> float:
        return self.used_area / (self.m * self.n)


def _choose(free: Sequence[FreeRect], w: int, h: int) -> Optional[int]:
    """Best-short-side-fit: argmin over fitting rects of min(dw, dh)."""
    best, best_key = None, None
    for i, c in enumerate(free):
        if c.w >= w and c.h >= h:
            key = (min(c.w - w, c.h - h), c.w * c.h)
            if best_key is None or key < best_key:
                best, best_key = i, key
    return best


def _split(c: FreeRect, w: int, h: int) -> List[FreeRect]:
    """Place (w, h) at the bottom-left of c; split residual on the rect's
    shorter axis (SAS rule).  Returns 0-2 non-empty free rects."""
    out = []
    if c.w <= c.h:
        # shorter axis horizontal: split with a horizontal cut
        #   c'  = right of the patch, patch-height strip
        #   c'' = everything above the patch row, full width
        if c.w - w > 0:
            out.append(FreeRect(c.x + w, c.y, c.w - w, h))
        if c.h - h > 0:
            out.append(FreeRect(c.x, c.y + h, c.w, c.h - h))
    else:
        # shorter axis vertical: split with a vertical cut
        #   c'  = right of the patch, full height
        #   c'' = above the patch, patch-width strip
        if c.w - w > 0:
            out.append(FreeRect(c.x + w, c.y, c.w - w, c.h))
        if c.h - h > 0:
            out.append(FreeRect(c.x, c.y + h, w, c.h - h))
    return out


class PackState:
    """Mutable guillotine packing state with O(1)-per-patch appends.

    Holds the canvases and their live free-rectangle lists for the queue
    packed so far.  ``append`` places one more patch with exactly the rule
    ``stitch`` applies to each queue element, so after appending patches
    p_0..p_k in order the state is identical to ``stitch([p_0..p_k])`` —
    no quadratic repack per arrival.
    """

    def __init__(self, m: int, n: int):
        self.m, self.n = m, n
        self.canvases: List[Canvas] = []
        self.count = 0              # patches packed (next patch_idx)

    def append(self, patch: Patch) -> None:
        """Place one patch (queue index ``self.count``) into the state."""
        i = self.count
        p = patch
        if p.w > self.n or p.h > self.m:
            raise ValueError(
                f"patch {i} ({p.w}x{p.h}) exceeds canvas ({self.n}x{self.m})")
        for ci, canvas in enumerate(self.canvases):
            j = _choose(canvas.free, p.w, p.h)
            if j is not None:
                c = canvas.free.pop(j)
                canvas.placements.append(
                    Placement(i, ci, c.x, c.y, p.w, p.h))
                canvas.free.extend(_split(c, p.w, p.h))
                self.count = i + 1
                return
        canvas = Canvas(self.m, self.n)
        c = canvas.free.pop(0)
        canvas.placements.append(
            Placement(i, len(self.canvases), c.x, c.y, p.w, p.h))
        canvas.free.extend(_split(c, p.w, p.h))
        self.canvases.append(canvas)
        self.count = i + 1

    def fits(self, w: int, h: int) -> bool:
        """Read-only probe: would a (w, h) patch fit an open canvas?"""
        return any(_choose(c.free, w, h) is not None for c in self.canvases)

    def reset(self, patches: Sequence[Patch] = ()) -> None:
        """Full repack: rebuild the state from an explicit queue."""
        self.canvases = []
        self.count = 0
        for p in patches:
            self.append(p)


def stitch(patches: Sequence[Patch], m: int, n: int) -> List[Canvas]:
    """Pack patches (in queue order) onto canvases of size m x n.

    Patches larger than the canvas raise ValueError — the partitioner is
    configured so zones never exceed the canvas (zone grid vs canvas size
    is validated in ``scheduler.Scheduler``).
    """
    state = PackState(m, n)
    for p in patches:
        state.append(p)
    return state.canvases


# eq=False: the generated __eq__ would elementwise-compare the records
# ndarray and raise in truth contexts (e.g. `plan in list`)
@dataclasses.dataclass(frozen=True, eq=False)
class BatchPlan:
    """Device-ready layout for stitching one multi-canvas batch.

    The SLO-aware invoker emits a whole batch of packings per invocation;
    this plan is the single array handed to the batched Pallas engine
    (``kernels.stitch``): one kernel launch stitches all ``num_canvases``
    canvases, and the same records drive the inverse unstitch gather.
    """
    canvas_m: int
    canvas_n: int
    num_canvases: int
    num_patches: int
    slots_per_canvas: int            # K: max placements on any canvas
    hmax: int                        # patch slot height (pow2-bucketed)
    wmax: int                        # patch slot width  (pow2-bucketed)
    records: np.ndarray              # (B, K, 6) int32 (valid, slot, x, y, w, h)
    slot_capacity: int = 0           # pow2-bucketed slot count (>= num_patches)

    def __post_init__(self):
        # derive (or repair) the capacity so manually built plans can't
        # violate the >= num_patches invariant pack_plan_host relies on
        if self.slot_capacity < max(self.num_patches, 1):
            object.__setattr__(self, "slot_capacity",
                               _bucket_pow2(self.num_patches, 1 << 30))

    @property
    def canvas_batch_shape(self) -> Tuple[int, int, int]:
        return (self.num_canvases, self.canvas_m, self.canvas_n)

    def placements(self):
        """Yield (canvas_idx, patch_idx, x, y, w, h) for valid records."""
        for bi in range(self.records.shape[0]):
            for rec in self.records[bi]:
                if rec[0] > 0:
                    yield (bi, int(rec[1]), int(rec[2]), int(rec[3]),
                           int(rec[4]), int(rec[5]))


def _bucket_pow2(x: int, cap: int) -> int:
    """Round x up to the next power of two, clamped to cap (min 1)."""
    x = max(x, 1)
    return min(1 << (x - 1).bit_length(), cap)


def build_batch_plan(patches: Sequence[Patch], canvases: Sequence[Canvas],
                     m: int, n: int, *, min_slots: int = 1) -> BatchPlan:
    """Flatten a packing (list of canvases) into one batched plan.

    ``patches`` is the stitched queue the placements index into.  An empty
    packing yields a plan with zero canvases/patches whose records array
    still has a well-defined (0, K, 6) shape.

    Slot extents and the slot count are bucketed to powers of two (zero
    padding is free) so the jit'd stitch/unstitch wrappers, which treat
    these as static, amortize compiles across invocations with varying
    queues instead of re-tracing per shape.
    """
    hmax = _bucket_pow2(max((p.h for p in patches), default=1), m)
    wmax = _bucket_pow2(max((p.w for p in patches), default=1), n)
    # K is bucketed too so the records array's traced shape stays stable;
    # B is left exact — the detector batch dim retraces per B regardless,
    # and padding B would run the model on dead canvases
    k = _bucket_pow2(
        max(max((len(c.placements) for c in canvases), default=0),
            min_slots), 1 << 30)
    b = len(canvases)
    records = np.zeros((b, k, 6), np.int32)
    for bi, canvas in enumerate(canvases):
        for ki, pl_ in enumerate(canvas.placements):
            records[bi, ki] = (1, pl_.patch_idx, pl_.x, pl_.y, pl_.w, pl_.h)
    return BatchPlan(canvas_m=m, canvas_n=n, num_canvases=b,
                     num_patches=len(patches), slots_per_canvas=k,
                     hmax=hmax, wmax=wmax, records=records)


def total_efficiency(canvases: Sequence[Canvas]) -> float:
    if not canvases:
        return 0.0
    used = sum(c.used_area for c in canvases)
    return used / sum(c.m * c.n for c in canvases)


def validate(canvases: Sequence[Canvas]) -> None:
    """Invariants (property-tested): in-bounds and non-overlapping."""
    for canvas in canvases:
        for p in canvas.placements:
            assert 0 <= p.x and p.x + p.w <= canvas.n, p
            assert 0 <= p.y and p.y + p.h <= canvas.m, p
        ps = canvas.placements
        for i in range(len(ps)):
            for j in range(i + 1, len(ps)):
                a, b = ps[i], ps[j]
                sep = (a.x + a.w <= b.x or b.x + b.w <= a.x or
                       a.y + a.h <= b.y or b.y + b.h <= a.y)
                assert sep, (a, b)
