"""Stauffer–Grimson adaptive background mixture model (pure JAX).

Per-pixel K-component Gaussian mixture over luminance, the paper's RoI
extractor (cv2 BackgroundSubtractorMOG2 on the edge Jetson).  The update
is a classic streaming rule and is the compute hot-spot of the edge side —
the Pallas kernel in ``repro/kernels/gmm`` implements the same update with
explicit VMEM tiling; this module is the jnp oracle and the jit path used
by the host pipeline.

State arrays are (H, W, K): weight w, mean mu, variance var.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GMMConfig:
    n_components: int = 3
    learning_rate: float = 0.05
    match_sigmas: float = 2.5      # match if |x-mu| < 2.5 sigma
    background_ratio: float = 0.8  # cumulative weight treated as background
    init_var: float = 0.04         # variance for new components ([0,1] pixels)
    min_var: float = 1e-4


def init_state(h: int, w: int, cfg: GMMConfig = GMMConfig()):
    k = cfg.n_components
    return {
        "w": jnp.concatenate([jnp.ones((h, w, 1), jnp.float32),
                              jnp.zeros((h, w, k - 1), jnp.float32)], -1),
        "mu": jnp.zeros((h, w, k), jnp.float32),
        "var": jnp.full((h, w, k), cfg.init_var, jnp.float32),
    }


def update(state, frame: jnp.ndarray, cfg: GMMConfig = GMMConfig()
           ) -> Tuple[dict, jnp.ndarray]:
    """One streaming update.  frame: (H, W) float32 in [0, 1].

    Returns (new_state, foreground_mask (H, W) bool).
    """
    w, mu, var = state["w"], state["mu"], state["var"]
    x = frame[..., None]                               # (H, W, 1)
    lr = cfg.learning_rate

    dist2 = jnp.square(x - mu)                         # (H, W, K)
    matched = dist2 < (cfg.match_sigmas ** 2) * var    # (H, W, K)
    any_match = jnp.any(matched, axis=-1)              # (H, W)

    # among matched components pick the most dominant (max w/sigma)
    fitness = w / jnp.sqrt(var)
    fit_masked = jnp.where(matched, fitness, -jnp.inf)
    best = jnp.argmax(fit_masked, axis=-1)             # (H, W)
    onehot = jax.nn.one_hot(best, cfg.n_components) * any_match[..., None]

    # matched update
    w_new = (1 - lr) * w + lr * onehot
    rho = lr  # classic approximation of lr * N(x | mu, var)
    mu_new = jnp.where(onehot > 0, (1 - rho) * mu + rho * x, mu)
    var_new = jnp.where(onehot > 0,
                        jnp.maximum((1 - rho) * var + rho * dist2, cfg.min_var),
                        var)

    # no match: replace the weakest component with a fresh one at x
    weakest = jnp.argmin(w, axis=-1)
    replace = jax.nn.one_hot(weakest, cfg.n_components) * (~any_match)[..., None]
    w_new = jnp.where(replace > 0, lr, w_new)
    mu_new = jnp.where(replace > 0, x, mu_new)
    var_new = jnp.where(replace > 0, cfg.init_var, var_new)

    # renormalize weights
    w_new = w_new / jnp.sum(w_new, axis=-1, keepdims=True)

    # background = top components (by fitness) covering background_ratio.
    # Sort-free rank formulation (identical to sorted-cumsum, but purely
    # elementwise so the Pallas kernel can mirror it exactly): a component
    # is background iff the total weight of strictly-fitter components is
    # below the threshold.  Index tie-break keeps it deterministic.
    fit_new = w_new / jnp.sqrt(var_new)
    ki = jnp.arange(cfg.n_components)
    fitter = (fit_new[..., None, :] > fit_new[..., :, None]) | (
        (fit_new[..., None, :] == fit_new[..., :, None])
        & (ki[None, :] < ki[:, None]))                 # (H, W, K, K')
    cum_before = jnp.sum(jnp.where(fitter, w_new[..., None, :], 0.0), axis=-1)
    is_bg = cum_before < cfg.background_ratio

    fg = ~jnp.any(matched & is_bg, axis=-1)
    new_state = {"w": w_new, "mu": mu_new, "var": var_new}
    return new_state, fg


@jax.jit
def update_jit(state, frame):
    return update(state, frame)


def warmup(state, frames, cfg: GMMConfig = GMMConfig()):
    """Run the model over a stack of frames (T, H, W) via scan."""
    def body(s, f):
        s, fg = update(s, f, cfg)
        return s, fg
    return jax.lax.scan(body, state, frames)
