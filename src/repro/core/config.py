"""`ServeConfig`: one declarative record for the whole serving pipeline.

`TangramScheduler` had accreted ~10 orthogonal keyword arguments
(batching knobs, executor mode, pool size, placement, estimator, clock),
and `launch/serve.py` mirrored each as an ad-hoc CLI flag.  This module
consolidates them into a single frozen dataclass grouped by subsystem,
designed so a config can be **logged into benchmark JSON and rebuilt**
from it:

* every field is a plain value or a *named reference* — classifiers,
  placements, clocks, executors, sources and models are referred to by
  their registry names (``make_classify`` / ``make_placement`` /
  ``make_clock`` / ``make_executor`` / ``make_source`` /
  ``make_model`` resolve them), never by callables or meshes;
* ``to_dict()`` / ``from_dict()`` round-trip through ``json`` exactly
  (nested ``AIMDConfig`` included), and ``dataclasses.replace`` works
  for one-field sweeps.

The old keyword arguments still work through a deprecation shim on
``TangramScheduler`` that warns once and forwards here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.core.adaptive import AIMDConfig
from repro.core.partitioning import Patch
from repro.core.registry import lookup

#: classifier registry: named references for the `classify` field.  None
#: (the paper's single shared queue) is spelled as the name ``None`` /
#: JSON null.  Register project classifiers here so configs stay
#: serializable.
_CLASSIFIERS: dict = {}


def register_classify(name: str, fn: Callable[[Patch], object]) -> None:
    _CLASSIFIERS[name] = fn


def make_classify(name: Optional[str]
                  ) -> Optional[Callable[[Patch], object]]:
    """Classifier-name -> callable (``"slo"`` | ``None``), the named-
    reference resolution for ``ServeConfig.classify``."""
    if name is None:
        return None
    if not _CLASSIFIERS:
        from repro.core.engine import slo_class
        _CLASSIFIERS["slo"] = slo_class
    return lookup("classifier", _CLASSIFIERS, name)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything the serving pipeline needs beyond data + models.

    Grouped by subsystem; each group's fields resolve through the
    matching factory.  All fields are JSON-safe by construction.
    """

    # --- batching (invoker pool) ---------------------------------------
    max_canvases: int = 8            # canvas budget per invocation (Eq. 5)
    incremental: bool = True         # live PackState vs literal restitch
    classify: Optional[str] = None   # None: shared queue; "slo": per-class
    adaptive: Optional[AIMDConfig] = None  # AIMD controller on the pool

    # --- execution ------------------------------------------------------
    executor: str = "sim"            # sim | device | async_device
    use_pallas: bool = False         # Pallas stitch kernel on device paths
    fuse: bool = False               # fused stitch->embed / decode->gather
                                     # device hot path (fused_embed.py)
    quantize: bool = False           # serve int8-resident weights: models
                                     # resolve to their _int8 registry
                                     # variants, the ad-hoc detector builds
                                     # quantized
    max_inflight: int = 4            # async in-flight bound (device memory)
    clock: str = "virtual"           # virtual | wall
    wall_speed: float = 1.0          # engine seconds per wall second
    check_invariants: bool = False

    # --- worker pool ----------------------------------------------------
    n_workers: int = 1
    placement: Optional[str] = None  # least | round | affinity | model
                                     # (None: least)

    # --- fleet sharding (core.fleet) ------------------------------------
    shards: Optional[int] = None     # None: one engine; N: ShardedEngine
                                     # with N camera-group shards (then
                                     # n_workers is the TOTAL worker
                                     # budget split across shards)
    planner: Optional[str] = None    # cost | equal — shard layout planner
                                     # (None: "cost" when shards is set)
    parallel: bool = False           # run each shard's engine loop on its
                                     # own thread (ParallelShardedEngine);
                                     # False keeps the sequential path
                                     # bit-identical to PR 9

    # --- models (registry names; see repro.core.models) -----------------
    model: Optional[str] = None      # default model for every class (None:
                                     # the implicit single-model pipeline)
    model_map: Optional[Dict[str, str]] = None
                                     # SLO class (as str) -> model name;
                                     # classes not in the map fall back to
                                     # ``model``

    # --- latency estimator ----------------------------------------------
    online_latency: bool = False     # OnlineLatencyTable feedback loop

    # --- ingestion (source layer) ---------------------------------------
    source: str = "trace"            # trace | synthetic | file
    ingestion_window: Optional[int] = None  # backlog bound, in patches

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {self.max_inflight}")
        if self.wall_speed <= 0:
            raise ValueError(
                f"wall_speed must be positive, got {self.wall_speed}")
        if self.ingestion_window is not None and self.ingestion_window < 1:
            raise ValueError(f"ingestion_window must be >= 1, got "
                             f"{self.ingestion_window}")
        if self.shards is not None and self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.planner is not None and self.shards is None:
            raise ValueError("planner requires shards to be set")
        if self.parallel and self.shards is None:
            raise ValueError("parallel requires shards to be set")

    def replace(self, **changes) -> "ServeConfig":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------ model routing ----

    @property
    def multi_model(self) -> bool:
        """True when model identity is threaded explicitly (a default
        model and/or a class->model map is configured)."""
        return self.model is not None or bool(self.model_map)

    def resolve_model(self, key: object) -> Optional[str]:
        """SLO class key -> registry model name.  Class keys are matched
        against ``model_map`` by their ``str()`` (JSON object keys are
        strings); misses fall back to the default ``model``."""
        if self.model_map:
            name = self.model_map.get(str(key))
            if name is not None:
                return name
        return self.model

    def model_names(self) -> list:
        """Every registry model this config references (sorted)."""
        names = set(self.model_map.values()) if self.model_map else set()
        if self.model is not None:
            names.add(self.model)
        return sorted(names)

    # ------------------------------------------------------ serialization ----

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)   # AIMDConfig -> nested plain dict
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        d = dict(d)
        adaptive = d.get("adaptive")
        if isinstance(adaptive, dict):
            d["adaptive"] = AIMDConfig(**adaptive)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServeConfig fields {sorted(unknown)}")
        return cls(**d)
