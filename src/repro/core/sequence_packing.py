"""Tangram's stitching idea applied to LM serving: 1-D sequence packing.

A patch is a variable-length token span; a canvas is one row of a fixed
(rows x seq_len) prefill buffer.  The placement rule is the 1-D projection
of the paper's best-short-side-fit: choose the row whose remaining space
leaves the smallest residual (best-fit), open a new row when none fits.
The SLO-aware invoker semantics (restitch on arrival, t_remain = earliest
deadline minus mu+3sigma slack, dispatch-previous on pressure) are reused
verbatim via ``SLOAwareInvoker`` with a RowLatencyTable.

See DESIGN.md §5: this is the arch-applicability analogue for the LM pool
(the 2-D pixel packer itself has no meaning for token sequences).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Request:
    n_tokens: int
    t_gen: float
    slo: float
    request_id: int = 0

    @property
    def deadline(self) -> float:
        return self.t_gen + self.slo


@dataclasses.dataclass
class Row:
    seq_len: int
    spans: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)                 # (request_idx, start, end)

    @property
    def used(self) -> int:
        return sum(e - s for _, s, e in self.spans)

    @property
    def free(self) -> int:
        return self.seq_len - self.used

    @property
    def efficiency(self) -> float:
        return self.used / self.seq_len


def pack(requests: Sequence[Request], seq_len: int) -> List[Row]:
    """Best-fit packing of requests (queue order) into fixed-length rows."""
    rows: List[Row] = []
    for i, r in enumerate(requests):
        if r.n_tokens > seq_len:
            raise ValueError(f"request {i} longer than row ({r.n_tokens})")
        best, best_free = None, None
        for row in rows:
            if row.free >= r.n_tokens:
                if best_free is None or row.free < best_free:
                    best, best_free = row, row.free
        if best is None:
            best = Row(seq_len)
            rows.append(best)
        start = best.used
        best.spans.append((i, start, start + r.n_tokens))
    return rows


def packing_efficiency(rows: Sequence[Row]) -> float:
    if not rows:
        return 0.0
    return sum(r.used for r in rows) / sum(r.seq_len for r in rows)


def attention_mask_blocks(rows: List[Row]) -> List[List[Tuple[int, int]]]:
    """Per-row block-diagonal attention spans (packed sequences must not
    attend across request boundaries); consumed by the flash kernel's
    segment masking."""
    return [[(s, e) for _, s, e in row.spans] for row in rows]


class SequencePacker:
    """Adapter exposing Request packing through the Tangram invoker.

    Requests masquerade as 1-px-tall patches (w = n_tokens, h = 1) on an
    (1 x seq_len) canvas, so ``SLOAwareInvoker`` + ``stitch`` drive the
    exact same control path that serves vision canvases.
    """

    def __init__(self, seq_len: int, latency, max_rows: int = 64):
        from repro.core.invoker import SLOAwareInvoker
        self.seq_len = seq_len
        self.invoker = SLOAwareInvoker(1, seq_len, latency,
                                       max_canvases=max_rows)

    def on_request(self, t_now: float, r: Request):
        from repro.core.partitioning import Patch
        p = Patch(0, 0, r.n_tokens, 1, frame_id=r.request_id,
                  t_gen=r.t_gen, slo=r.slo)
        return self.invoker.on_patch(t_now, p)

    def poll(self, t_now: float):
        return self.invoker.poll(t_now)

    def next_timer(self) -> float:
        return self.invoker.next_timer()
