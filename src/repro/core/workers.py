"""Multi-worker device pool: route concurrent invocations across workers.

The PR 4 async core overlaps device execution with arrival ingestion, but
every invocation still funnels through *one* executor with one in-flight
queue — the simulation models N concurrent instances while the real
pipeline can exploit only one.  This module splits the executor layer
into independent **workers** (each its own mesh slice / device queue /
platform shard) behind one submit/complete facade:

* :class:`WorkerPoolExecutor` implements the engine's executor protocol
  (``submit``/``resolve``/``ready``/``max_inflight``/``on_complete``)
  and dispatches each fired :class:`~repro.core.invoker.Invocation` to a
  worker chosen by a pluggable **placement policy**.  Workers are plain
  executors — ``AsyncDeviceExecutor`` over per-worker mesh slices
  (:func:`repro.launch.mesh.make_worker_meshes`), ``SimExecutor`` over
  per-worker platform shards (:func:`repro.serverless.platform.
  split_platform`), or stubs — so Sim and Device scenarios share the
  same pool semantics.
* Placement policies: :class:`LeastOutstandingPlacement` (default — the
  worker with the fewest unresolved invocations wins, index breaks
  ties), :class:`RoundRobinPlacement`,
  :class:`ClassAffinityPlacement` (tight-SLO classes get reserved
  workers; everything else spreads over the rest), and
  :class:`ModelAffinityPlacement` (same-model batches co-locate so
  weights stay resident — see :class:`WeightCache`, the per-worker LRU
  weight cache with a modeled swap-in cost).
* The engine harvests completions **out of order** across all workers'
  in-flight work (a slow batch on worker 0 no longer pins completed
  batches on worker 1), with delivery ties pinned to ``(worker index,
  submit seq)`` so multi-worker replays are reproducible.
* Pass an :class:`~repro.core.latency.OnlineLatencyTable` as
  ``estimator`` and every resolved completion feeds its observed
  per-worker, per-batch elapsed time back into the table the invokers
  fire against — the closed loop between real device speed and batching
  decisions.

Device workers sharing pixels: :func:`share_frame_store` aliases the
refcounted frame store across a pool's device executors, so any worker
can gather crops for any frame and eviction still happens exactly when
the last patch cut from a frame has been routed (regardless of which
workers routed them).
"""
from __future__ import annotations

import collections
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.engine import Completion, ExecHandle
from repro.core.invoker import Invocation
from repro.core.registry import lookup


# ----------------------------------------------------- weight cache ----

class WeightCache:
    """Per-worker model-weight residency: LRU over a byte budget.

    The single-model pipeline kept its detector implicitly
    always-resident; with multiple models a worker holds whichever
    weights fit in ``capacity_bytes`` and pays a modeled load cost to
    swap one in.  ``models`` maps a registry model name to
    ``(weight_bytes, load_s)`` (both straight off a
    :class:`~repro.core.models.ModelSpec`).

    :meth:`ensure` is the one mutation: it returns the load seconds the
    caller must add to the invocation's finish time — ``0.0`` on a hit —
    touching the entry MRU and evicting least-recently-used residents
    until the new weights fit.  A model larger than the whole budget
    still loads (it runs resident alone, everything else evicted), the
    same semantics as a platform instance hosting one oversized model.
    Unknown or untagged models cost nothing and are not cached — the
    legacy single-model path goes through unchanged.

    Deterministic by construction (no clock, no randomness): eviction
    order is pinned by the access sequence alone, which is what the
    eviction regression test relies on.
    """

    def __init__(self, capacity_bytes: float,
                 models: Mapping[str, Tuple[float, float]]):
        if capacity_bytes <= 0:
            raise ValueError(
                f"capacity_bytes must be positive, got {capacity_bytes}")
        self.capacity_bytes = float(capacity_bytes)
        self.models = {name: (float(size), float(load))
                       for name, (size, load) in models.items()}
        self._resident: "collections.OrderedDict[str, float]" = \
            collections.OrderedDict()          # name -> weight_bytes
        self.used_bytes = 0.0
        self.hits: Dict[str, int] = {}
        self.misses: Dict[str, int] = {}
        self.evictions = 0
        self.load_seconds = 0.0

    def holds(self, model: Optional[str]) -> bool:
        return model in self._resident

    def resident(self) -> List[str]:
        """Resident model names, LRU first (the next eviction victim
        leads)."""
        return list(self._resident)

    @property
    def n_hits(self) -> int:
        return sum(self.hits.values())

    @property
    def n_misses(self) -> int:
        return sum(self.misses.values())

    @property
    def hit_rate(self) -> float:
        total = self.n_hits + self.n_misses
        return self.n_hits / total if total else 0.0

    def ensure(self, model: Optional[str]) -> float:
        """Make ``model`` resident; returns the modeled load seconds
        (0.0 on a hit, or for untagged/unknown models)."""
        if model is None or model not in self.models:
            return 0.0
        if model in self._resident:
            self._resident.move_to_end(model)
            self.hits[model] = self.hits.get(model, 0) + 1
            return 0.0
        size, load_s = self.models[model]
        while self._resident and self.used_bytes + size > self.capacity_bytes:
            _, evicted = self._resident.popitem(last=False)
            self.used_bytes -= evicted
            self.evictions += 1
        self._resident[model] = size
        self.used_bytes += size
        self.misses[model] = self.misses.get(model, 0) + 1
        self.load_seconds += load_s
        return load_s

    def stats(self) -> dict:
        return {"hits": self.n_hits, "misses": self.n_misses,
                "hit_rate": round(self.hit_rate, 4),
                "evictions": self.evictions,
                "load_s": round(self.load_seconds, 4),
                "resident": self.resident()}


def weight_caches(n_workers: int, capacity_bytes: float,
                  models: Mapping[str, Tuple[float, float]]
                  ) -> List[WeightCache]:
    """One independent :class:`WeightCache` per pool worker."""
    return [WeightCache(capacity_bytes, models) for _ in range(n_workers)]


# ------------------------------------------------------- placement ----

class LeastOutstandingPlacement:
    """Pick the worker with the fewest unresolved invocations (lowest
    index wins ties) — the classic join-the-shortest-queue heuristic."""

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        return min(range(pool.n_workers),
                   key=lambda i: (pool.outstanding[i], i))


class RoundRobinPlacement:
    """Cycle through workers regardless of load (baseline policy)."""

    def __init__(self):
        self._next = 0

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        idx = self._next % pool.n_workers
        self._next += 1
        return idx


class ClassAffinityPlacement:
    """Reserve workers for specific SLO classes.

    ``reserved`` maps an invocation's class key (``inv.key``, tagged by
    the :class:`~repro.core.engine.InvokerPool`) to the worker indices
    its batches may run on; keys not in the map spread over the
    *unreserved* workers (or over every worker when nothing is left).
    Within the allowed set the least-outstanding worker wins, so the
    policy degrades to :class:`LeastOutstandingPlacement` inside each
    partition.

    ``reserve_tightest`` is the zero-config variant: the first
    ``reserve_tightest`` workers are reserved for the numerically
    smallest class key observed so far (tightest SLO under the default
    ``slo_class`` classification) — useful when class keys are not known
    up front.  The reservation only activates once a *second* class has
    been seen: with a single class there is no competition to protect
    against, and pinning all traffic to the reserved workers would
    silently waste the rest of the pool.
    """

    def __init__(self, reserved: Optional[Mapping[object,
                                                  Sequence[int]]] = None,
                 reserve_tightest: int = 0):
        self.reserved = {k: tuple(v) for k, v in (reserved or {}).items()}
        self.reserve_tightest = reserve_tightest
        self._tightest: object = None
        self._seen: set = set()

    def _allowed(self, key: object, n_workers: int) -> Sequence[int]:
        if self.reserve_tightest > 0:
            k = min(self.reserve_tightest, n_workers)
            self._seen.add(key)
            try:
                if self._tightest is None or key < self._tightest:
                    self._tightest = key
            except TypeError:          # uncomparable keys: first one wins
                if self._tightest is None:
                    self._tightest = key
            if len(self._seen) < 2:
                return range(n_workers)
            if key == self._tightest:
                return range(k)
            rest = range(k, n_workers)
            return rest if len(rest) else range(n_workers)
        if key in self.reserved:
            allowed = [i for i in self.reserved[key] if i < n_workers]
            if allowed:
                return allowed
        taken = {i for v in self.reserved.values() for i in v}
        free = [i for i in range(n_workers) if i not in taken]
        return free if free else range(n_workers)

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        allowed = self._allowed(inv.key, pool.n_workers)
        return min(allowed, key=lambda i: (pool.outstanding[i], i))


class ReservedClassPlacement:
    """Placement honouring a :class:`~repro.core.fleet.FleetPlan`
    shard's per-class worker reservations.

    ``reserved`` maps a class key's ``str()`` (the plan's JSON-safe
    spelling) to a worker count: that class's batches run on the
    lowest-index workers reserved for it, unmatched classes on whatever
    is left (everything, when nothing is reserved).  Least-outstanding
    within the allowed set, lowest index on ties — the same degradation
    rule as :class:`ClassAffinityPlacement`.
    """

    def __init__(self, reserved: Mapping[str, int]):
        self.reserved = dict(reserved)
        self._ranges: Dict[str, range] = {}
        start = 0
        for key in sorted(self.reserved):
            count = self.reserved[key]
            self._ranges[key] = range(start, start + count)
            start += count
        self._first_free = start

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        allowed = self._ranges.get(str(inv.key))
        if allowed is None or len(allowed) == 0:
            allowed = range(self._first_free, pool.n_workers)
            if len(allowed) == 0:
                allowed = range(pool.n_workers)
        allowed = [i for i in allowed if i < pool.n_workers]
        if not allowed:
            allowed = list(range(pool.n_workers))
        return min(allowed, key=lambda i: (pool.outstanding[i], i))


class ModelAffinityPlacement:
    """Co-locate batches of the same model so weights stay resident.

    An invocation tagged with a registry model (``inv.model``, set by
    the :class:`~repro.core.engine.InvokerPool`'s ``model_of``) prefers
    workers that already hold that model's weights:

    * with pool :class:`WeightCache`\\ s, the least-outstanding worker
      whose cache holds the model wins (real residency);
    * otherwise each model gets a sticky **home worker** assigned
      round-robin on first sight, so an N-model workload spreads over
      the pool while every model's traffic stays on one worker — the
      sim-platform analogue, where each worker's platform shard then
      keeps its instances warm for exactly one model.

    Untagged invocations fall back to least-outstanding.  The pool's
    per-worker in-flight bound still wins over affinity (overflow
    re-routes, as for every policy) — a resident model is worth a warm
    start, not an unbounded queue.
    """

    def __init__(self):
        self._home: Dict[str, int] = {}
        self._next = 0

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        model = getattr(inv, "model", None)
        if model is None:
            return min(range(pool.n_workers),
                       key=lambda i: (pool.outstanding[i], i))
        caches = pool.weight_caches
        if caches is not None:
            resident = [i for i in range(pool.n_workers)
                        if caches[i].holds(model)]
            if resident:
                return min(resident,
                           key=lambda i: (pool.outstanding[i], i))
        home = self._home.get(model)
        if home is None:
            home = self._home[model] = self._next % pool.n_workers
            self._next += 1
        return home


_PLACEMENTS = {
    "least": LeastOutstandingPlacement,
    "round": RoundRobinPlacement,
    "affinity": lambda: ClassAffinityPlacement(reserve_tightest=1),
    "model": ModelAffinityPlacement,
}


def make_placement(name: str):
    """CLI-name -> policy instance
    (``least`` | ``round`` | ``affinity`` | ``model``)."""
    return lookup("placement", _PLACEMENTS, name)()


# ------------------------------------------------------------ pool ----

class WorkerPoolExecutor:
    """N independent workers behind one engine-facing executor.

    ``workers`` are executors implementing the submit/complete protocol
    (legacy ``execute``-only executors are not supported here — wrap them
    first).  ``placement`` chooses a worker per invocation; ``estimator``
    (an :class:`~repro.core.latency.OnlineLatencyTable`) receives every
    resolved completion's ``(batch, elapsed, worker)`` observation.

    ``max_inflight`` is the sum of the workers' bounds (the engine blocks
    only when the whole pool is saturated).  A worker's *own* bound is a
    hard constraint — it exists because each unresolved handle pins
    device memory on that worker — so :meth:`submit` treats placement as
    a preference that yields to it: an invocation placed on a worker
    already at its bound is re-routed to the least-outstanding worker
    with room (there always is one while the engine admits submits).
    Workers without a bound (sim workers resolve from the model at
    submit) are never full — a pool of only such workers exposes no
    bound at all.
    """

    def __init__(self, workers: Sequence[object], placement=None,
                 estimator=None,
                 weight_caches: Optional[Sequence[WeightCache]] = None):
        if not workers:
            raise ValueError("WorkerPoolExecutor needs at least one worker")
        self.workers = list(workers)
        self.placement = placement or LeastOutstandingPlacement()
        self.estimator = estimator
        if weight_caches is not None and len(weight_caches) != len(workers):
            raise ValueError(
                f"weight_caches has {len(weight_caches)} entries "
                f"for {len(workers)} workers")
        self.weight_caches = (list(weight_caches)
                              if weight_caches is not None else None)
        n = len(self.workers)
        self.outstanding = [0] * n       # unresolved invocations per worker
        self.n_submitted = [0] * n
        self.n_patches = [0] * n
        self.busy_s = [0.0] * n          # union of per-worker busy intervals
        self._last_finish = [0.0] * n
        bounds = [getattr(w, "max_inflight", None) for w in self.workers]
        known = [b for b in bounds if b is not None]
        if known:
            self.max_inflight = sum(known)

    @property
    def n_workers(self) -> int:
        return len(self.workers)

    def _has_room(self, idx: int) -> bool:
        bound = getattr(self.workers[idx], "max_inflight", None)
        return bound is None or self.outstanding[idx] < bound

    # ------------------------------------------------ engine protocol ----

    def submit(self, inv: Invocation) -> ExecHandle:
        idx = self.placement.choose(inv, self)
        if not 0 <= idx < self.n_workers:
            raise ValueError(f"placement chose worker {idx} "
                             f"of {self.n_workers}")
        if not self._has_room(idx):
            # the per-worker in-flight bound is a device-memory bound and
            # therefore hard; overflow to the least-loaded worker with
            # room rather than exceed it (skewed policies like class
            # affinity can otherwise pile everything on one worker)
            room = [i for i in range(self.n_workers) if self._has_room(i)]
            if room:
                idx = min(room, key=lambda i: (self.outstanding[i], i))
        handle = self.workers[idx].submit(inv)
        handle.worker = idx
        if self.weight_caches is not None:
            # charge the weight-swap cost at submit (residency is decided
            # by where the batch lands, i.e. here, not inside the worker)
            load_s = self.weight_caches[idx].ensure(
                getattr(inv, "model", None))
            if load_s:
                if handle.t_finish is not None:
                    handle.t_finish += load_s
                    if handle.completion is not None:
                        handle.completion.t_finish += load_s
                else:
                    # async worker: finish time unknown until resolve;
                    # remember the debit and apply it there
                    handle.load_s += load_s
        self.outstanding[idx] += 1
        self.n_submitted[idx] += 1
        self.n_patches[idx] += len(inv.patches)
        return handle

    def ready(self, handle: ExecHandle) -> bool:
        probe = getattr(self.workers[handle.worker], "ready", None)
        if probe is None:
            return handle.completion is not None
        return probe(handle)

    def resolve(self, handle: ExecHandle) -> Completion:
        comp = self.workers[handle.worker].resolve(handle)
        w = handle.worker
        comp.worker = w
        if handle.load_s:
            comp.t_finish += handle.load_s
            handle.load_s = 0.0
        self.outstanding[w] -= 1
        elapsed = comp.t_finish - comp.invocation.t_submit
        if math.isfinite(elapsed) and elapsed > 0:
            # busy time is the union of the worker's service intervals: a
            # queued invocation's interval starts where the previous one
            # finished, so overlapped in-flight work is not double-counted
            # (utilization = busy_s / horizon must stay <= 1)
            start = max(comp.invocation.t_submit, self._last_finish[w])
            self.busy_s[w] += max(0.0, comp.t_finish - start)
            self._last_finish[w] = max(self._last_finish[w], comp.t_finish)
        if self.estimator is not None:
            # the estimator deliberately sees submit->finish elapsed
            # (including queueing on the worker): that is the quantity
            # t_slack must cover for the firing decision to be safe
            batch = (len(comp.invocation.canvases)
                     or len(comp.invocation.patches))
            model = getattr(comp.invocation, "model", None)
            if model is not None:
                # pass the model only when tagged: duck-typed estimators
                # predating multi-model need not accept the kwarg
                self.estimator.observe(batch, elapsed, worker=w,
                                       model=model)
            else:
                self.estimator.observe(batch, elapsed, worker=w)
        return comp

    def on_complete(self, comp: Completion):
        on_complete = getattr(self.workers[comp.worker], "on_complete", None)
        if on_complete is not None:
            on_complete(comp)

    # ---------------------------------------------- frame store facade ----

    def add_frame(self, frame_id, pixels, n_patches: int):
        """Register a frame once; device workers share one store (see
        :func:`share_frame_store`), so worker 0's store is the store."""
        self.workers[0].add_frame(frame_id, pixels, n_patches)

    @property
    def frames(self):
        return self.workers[0].frames

    # --------------------------------------------------- aggregation ----

    def _sum(self, attr: str) -> int:
        return sum(getattr(w, attr, 0) for w in self.workers)

    @property
    def n_invocations(self) -> int:
        return self._sum("n_invocations")

    @property
    def n_detections(self) -> int:
        return self._sum("n_detections")

    @property
    def n_sharded(self) -> int:
        return self._sum("n_sharded")

    @property
    def evidence_bytes(self) -> int:
        return self._sum("evidence_bytes")

    def worker_stats(self) -> List[dict]:
        """Per-worker counters for ``Results.worker_stats`` / benchmarks."""
        stats = []
        for i in range(self.n_workers):
            ws = {"worker": i,
                  "invocations": self.n_submitted[i],
                  "patches": self.n_patches[i],
                  "busy_s": round(self.busy_s[i], 4)}
            if self.estimator is not None:
                ws["drift"] = round(self.estimator.drift(worker=i), 3)
            if self.weight_caches is not None:
                ws["weights"] = self.weight_caches[i].stats()
            stats.append(ws)
        return stats

    def model_cache_stats(self) -> Dict[str, dict]:
        """Pool-wide per-model weight-cache counters (empty without
        caches): hits/misses aggregated over every worker's cache."""
        if self.weight_caches is None:
            return {}
        out: Dict[str, dict] = {}
        for cache in self.weight_caches:
            for name in set(cache.hits) | set(cache.misses):
                row = out.setdefault(name, {"weight_hits": 0,
                                            "weight_misses": 0})
                row["weight_hits"] += cache.hits.get(name, 0)
                row["weight_misses"] += cache.misses.get(name, 0)
        for row in out.values():
            total = row["weight_hits"] + row["weight_misses"]
            row["weight_hit_rate"] = (round(row["weight_hits"] / total, 4)
                                      if total else 0.0)
        return out


def share_frame_store(executors: Sequence[object]) -> None:
    """Alias one refcounted frame store across device executors.

    Patches cut from one frame may be routed by different workers; with
    per-worker stores each worker's refcount would never drain (worker A
    cannot see the decrements worker B's completions perform).  Sharing
    the store keeps `DeviceExecutor.on_complete`'s eviction exact: the
    frame disappears when the *pool-wide* last patch is routed.  The
    store is the striped-lock :class:`~repro.core.framestore.FrameStore`,
    so the sharing is also safe across the parallel fleet runtime's
    shard threads; duck-typed executors that predate the store (bare
    ``frames`` / ``_refs`` dicts) still get the historical dict
    aliasing."""
    if not executors:
        return
    head = executors[0]
    store = getattr(head, "store", None)
    for ex in executors[1:]:
        if store is not None and hasattr(ex, "store"):
            ex.store = store
        else:
            ex.frames = head.frames
            ex._refs = head._refs


def device_worker_pool(n_workers: int, make_executor: Callable[[int], object],
                       placement=None, estimator=None,
                       weight_caches: Optional[Sequence[WeightCache]] = None
                       ) -> WorkerPoolExecutor:
    """Build a device pool: ``make_executor(i)`` constructs worker ``i``
    (typically an ``AsyncDeviceExecutor`` over mesh slice ``i``); the
    frame stores are shared and the pool assembled."""
    workers = [make_executor(i) for i in range(n_workers)]
    share_frame_store(workers)
    return WorkerPoolExecutor(workers, placement=placement,
                              estimator=estimator,
                              weight_caches=weight_caches)
