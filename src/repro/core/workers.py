"""Multi-worker device pool: route concurrent invocations across workers.

The PR 4 async core overlaps device execution with arrival ingestion, but
every invocation still funnels through *one* executor with one in-flight
queue — the simulation models N concurrent instances while the real
pipeline can exploit only one.  This module splits the executor layer
into independent **workers** (each its own mesh slice / device queue /
platform shard) behind one submit/complete facade:

* :class:`WorkerPoolExecutor` implements the engine's executor protocol
  (``submit``/``resolve``/``ready``/``max_inflight``/``on_complete``)
  and dispatches each fired :class:`~repro.core.invoker.Invocation` to a
  worker chosen by a pluggable **placement policy**.  Workers are plain
  executors — ``AsyncDeviceExecutor`` over per-worker mesh slices
  (:func:`repro.launch.mesh.make_worker_meshes`), ``SimExecutor`` over
  per-worker platform shards (:func:`repro.serverless.platform.
  split_platform`), or stubs — so Sim and Device scenarios share the
  same pool semantics.
* Placement policies: :class:`LeastOutstandingPlacement` (default — the
  worker with the fewest unresolved invocations wins, index breaks
  ties), :class:`RoundRobinPlacement`, and
  :class:`ClassAffinityPlacement` (tight-SLO classes get reserved
  workers; everything else spreads over the rest).
* The engine harvests completions **out of order** across all workers'
  in-flight work (a slow batch on worker 0 no longer pins completed
  batches on worker 1), with delivery ties pinned to ``(worker index,
  submit seq)`` so multi-worker replays are reproducible.
* Pass an :class:`~repro.core.latency.OnlineLatencyTable` as
  ``estimator`` and every resolved completion feeds its observed
  per-worker, per-batch elapsed time back into the table the invokers
  fire against — the closed loop between real device speed and batching
  decisions.

Device workers sharing pixels: :func:`share_frame_store` aliases the
refcounted frame store across a pool's device executors, so any worker
can gather crops for any frame and eviction still happens exactly when
the last patch cut from a frame has been routed (regardless of which
workers routed them).
"""
from __future__ import annotations

import math
from typing import Callable, List, Mapping, Optional, Sequence

from repro.core.engine import Completion, ExecHandle
from repro.core.invoker import Invocation


# ------------------------------------------------------- placement ----

class LeastOutstandingPlacement:
    """Pick the worker with the fewest unresolved invocations (lowest
    index wins ties) — the classic join-the-shortest-queue heuristic."""

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        return min(range(pool.n_workers),
                   key=lambda i: (pool.outstanding[i], i))


class RoundRobinPlacement:
    """Cycle through workers regardless of load (baseline policy)."""

    def __init__(self):
        self._next = 0

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        idx = self._next % pool.n_workers
        self._next += 1
        return idx


class ClassAffinityPlacement:
    """Reserve workers for specific SLO classes.

    ``reserved`` maps an invocation's class key (``inv.key``, tagged by
    the :class:`~repro.core.engine.InvokerPool`) to the worker indices
    its batches may run on; keys not in the map spread over the
    *unreserved* workers (or over every worker when nothing is left).
    Within the allowed set the least-outstanding worker wins, so the
    policy degrades to :class:`LeastOutstandingPlacement` inside each
    partition.

    ``reserve_tightest`` is the zero-config variant: the first
    ``reserve_tightest`` workers are reserved for the numerically
    smallest class key observed so far (tightest SLO under the default
    ``slo_class`` classification) — useful when class keys are not known
    up front.  The reservation only activates once a *second* class has
    been seen: with a single class there is no competition to protect
    against, and pinning all traffic to the reserved workers would
    silently waste the rest of the pool.
    """

    def __init__(self, reserved: Optional[Mapping[object,
                                                  Sequence[int]]] = None,
                 reserve_tightest: int = 0):
        self.reserved = {k: tuple(v) for k, v in (reserved or {}).items()}
        self.reserve_tightest = reserve_tightest
        self._tightest: object = None
        self._seen: set = set()

    def _allowed(self, key: object, n_workers: int) -> Sequence[int]:
        if self.reserve_tightest > 0:
            k = min(self.reserve_tightest, n_workers)
            self._seen.add(key)
            try:
                if self._tightest is None or key < self._tightest:
                    self._tightest = key
            except TypeError:          # uncomparable keys: first one wins
                if self._tightest is None:
                    self._tightest = key
            if len(self._seen) < 2:
                return range(n_workers)
            if key == self._tightest:
                return range(k)
            rest = range(k, n_workers)
            return rest if len(rest) else range(n_workers)
        if key in self.reserved:
            allowed = [i for i in self.reserved[key] if i < n_workers]
            if allowed:
                return allowed
        taken = {i for v in self.reserved.values() for i in v}
        free = [i for i in range(n_workers) if i not in taken]
        return free if free else range(n_workers)

    def choose(self, inv: Invocation, pool: "WorkerPoolExecutor") -> int:
        allowed = self._allowed(inv.key, pool.n_workers)
        return min(allowed, key=lambda i: (pool.outstanding[i], i))


_PLACEMENTS = {
    "least": LeastOutstandingPlacement,
    "round": RoundRobinPlacement,
    "affinity": lambda: ClassAffinityPlacement(reserve_tightest=1),
}


def make_placement(name: str):
    """CLI-name -> policy instance (``least`` | ``round`` | ``affinity``)."""
    try:
        return _PLACEMENTS[name]()
    except KeyError:
        raise ValueError(f"unknown placement {name!r}; "
                         f"choose from {sorted(_PLACEMENTS)}") from None


# ------------------------------------------------------------ pool ----

class WorkerPoolExecutor:
    """N independent workers behind one engine-facing executor.

    ``workers`` are executors implementing the submit/complete protocol
    (legacy ``execute``-only executors are not supported here — wrap them
    first).  ``placement`` chooses a worker per invocation; ``estimator``
    (an :class:`~repro.core.latency.OnlineLatencyTable`) receives every
    resolved completion's ``(batch, elapsed, worker)`` observation.

    ``max_inflight`` is the sum of the workers' bounds (the engine blocks
    only when the whole pool is saturated).  A worker's *own* bound is a
    hard constraint — it exists because each unresolved handle pins
    device memory on that worker — so :meth:`submit` treats placement as
    a preference that yields to it: an invocation placed on a worker
    already at its bound is re-routed to the least-outstanding worker
    with room (there always is one while the engine admits submits).
    Workers without a bound (sim workers resolve from the model at
    submit) are never full — a pool of only such workers exposes no
    bound at all.
    """

    def __init__(self, workers: Sequence[object], placement=None,
                 estimator=None):
        if not workers:
            raise ValueError("WorkerPoolExecutor needs at least one worker")
        self.workers = list(workers)
        self.placement = placement or LeastOutstandingPlacement()
        self.estimator = estimator
        n = len(self.workers)
        self.outstanding = [0] * n       # unresolved invocations per worker
        self.n_submitted = [0] * n
        self.n_patches = [0] * n
        self.busy_s = [0.0] * n          # union of per-worker busy intervals
        self._last_finish = [0.0] * n
        bounds = [getattr(w, "max_inflight", None) for w in self.workers]
        known = [b for b in bounds if b is not None]
        if known:
            self.max_inflight = sum(known)

    @property
    def n_workers(self) -> int:
        return len(self.workers)

    def _has_room(self, idx: int) -> bool:
        bound = getattr(self.workers[idx], "max_inflight", None)
        return bound is None or self.outstanding[idx] < bound

    # ------------------------------------------------ engine protocol ----

    def submit(self, inv: Invocation) -> ExecHandle:
        idx = self.placement.choose(inv, self)
        if not 0 <= idx < self.n_workers:
            raise ValueError(f"placement chose worker {idx} "
                             f"of {self.n_workers}")
        if not self._has_room(idx):
            # the per-worker in-flight bound is a device-memory bound and
            # therefore hard; overflow to the least-loaded worker with
            # room rather than exceed it (skewed policies like class
            # affinity can otherwise pile everything on one worker)
            room = [i for i in range(self.n_workers) if self._has_room(i)]
            if room:
                idx = min(room, key=lambda i: (self.outstanding[i], i))
        handle = self.workers[idx].submit(inv)
        handle.worker = idx
        self.outstanding[idx] += 1
        self.n_submitted[idx] += 1
        self.n_patches[idx] += len(inv.patches)
        return handle

    def ready(self, handle: ExecHandle) -> bool:
        probe = getattr(self.workers[handle.worker], "ready", None)
        if probe is None:
            return handle.completion is not None
        return probe(handle)

    def resolve(self, handle: ExecHandle) -> Completion:
        comp = self.workers[handle.worker].resolve(handle)
        w = handle.worker
        comp.worker = w
        self.outstanding[w] -= 1
        elapsed = comp.t_finish - comp.invocation.t_submit
        if math.isfinite(elapsed) and elapsed > 0:
            # busy time is the union of the worker's service intervals: a
            # queued invocation's interval starts where the previous one
            # finished, so overlapped in-flight work is not double-counted
            # (utilization = busy_s / horizon must stay <= 1)
            start = max(comp.invocation.t_submit, self._last_finish[w])
            self.busy_s[w] += max(0.0, comp.t_finish - start)
            self._last_finish[w] = max(self._last_finish[w], comp.t_finish)
        if self.estimator is not None:
            # the estimator deliberately sees submit->finish elapsed
            # (including queueing on the worker): that is the quantity
            # t_slack must cover for the firing decision to be safe
            batch = (len(comp.invocation.canvases)
                     or len(comp.invocation.patches))
            self.estimator.observe(batch, elapsed, worker=w)
        return comp

    def on_complete(self, comp: Completion):
        on_complete = getattr(self.workers[comp.worker], "on_complete", None)
        if on_complete is not None:
            on_complete(comp)

    # ---------------------------------------------- frame store facade ----

    def add_frame(self, frame_id, pixels, n_patches: int):
        """Register a frame once; device workers share one store (see
        :func:`share_frame_store`), so worker 0's store is the store."""
        self.workers[0].add_frame(frame_id, pixels, n_patches)

    @property
    def frames(self):
        return self.workers[0].frames

    # --------------------------------------------------- aggregation ----

    def _sum(self, attr: str) -> int:
        return sum(getattr(w, attr, 0) for w in self.workers)

    @property
    def n_invocations(self) -> int:
        return self._sum("n_invocations")

    @property
    def n_detections(self) -> int:
        return self._sum("n_detections")

    @property
    def n_sharded(self) -> int:
        return self._sum("n_sharded")

    @property
    def evidence_bytes(self) -> int:
        return self._sum("evidence_bytes")

    def worker_stats(self) -> List[dict]:
        """Per-worker counters for ``Results.worker_stats`` / benchmarks."""
        stats = []
        for i in range(self.n_workers):
            ws = {"worker": i,
                  "invocations": self.n_submitted[i],
                  "patches": self.n_patches[i],
                  "busy_s": round(self.busy_s[i], 4)}
            if self.estimator is not None:
                ws["drift"] = round(self.estimator.drift(worker=i), 3)
            stats.append(ws)
        return stats


def share_frame_store(executors: Sequence[object]) -> None:
    """Alias one refcounted frame store across device executors.

    Patches cut from one frame may be routed by different workers; with
    per-worker stores each worker's refcount would never drain (worker A
    cannot see the decrements worker B's completions perform).  Sharing
    the dicts keeps `DeviceExecutor.on_complete`'s eviction exact: the
    frame disappears when the *pool-wide* last patch is routed."""
    if not executors:
        return
    head = executors[0]
    for ex in executors[1:]:
        ex.frames = head.frames
        ex._refs = head._refs


def device_worker_pool(n_workers: int, make_executor: Callable[[int], object],
                       placement=None, estimator=None) -> WorkerPoolExecutor:
    """Build a device pool: ``make_executor(i)`` constructs worker ``i``
    (typically an ``AsyncDeviceExecutor`` over mesh slice ``i``); the
    frame stores are shared and the pool assembled."""
    workers = [make_executor(i) for i in range(n_workers)]
    share_frame_store(workers)
    return WorkerPoolExecutor(workers, placement=placement,
                              estimator=estimator)
