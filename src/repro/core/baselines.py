"""Baseline serving policies from Section V-A.

* Full Frame   — whole 4K frame per request, triggered in sequence.
* Masked Frame — non-RoIs masked, still full resolution per request [35].
* ELF          — every patch its own request [12].
* Clipper      — AIMD dynamic batch size over padded fixed-size tiles [23].
* MArk         — max-batch + timeout over padded fixed-size tiles [24].

Clipper and MArk cannot batch variable-size inputs, so patches are padded
to a fixed tile (``tile_side``); that padding waste vs Tangram's stitching
is exactly the paper's point.  All policies share the arrival model, the
platform (cost/billing), and the ``Results`` record.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.latency import AnalyticalLatencyModel, LatencyTable
from repro.core.partitioning import Patch
from repro.core.scheduler import PatchOutcome, Results
from repro.data import video
from repro.data.video import Arrival, merge_arrivals, shape_arrivals
from repro.serverless.platform import Platform


@dataclasses.dataclass(frozen=True)
class FrameMeta:
    """Per-frame record for the frame-level baselines."""
    width: int
    height: int
    fg_area: int
    t_gen: float
    slo: float
    camera_id: int = 0

    @property
    def deadline(self) -> float:
        return self.t_gen + self.slo


def _frame_arrivals(frames: Sequence[FrameMeta], bandwidth_bps: float,
                    masked: bool) -> List[Arrival]:
    byte_rate = bandwidth_bps / 8.0
    link_free = 0.0
    out = []
    for f in frames:
        b = (video.masked_frame_bytes(f.width, f.height, f.fg_area)
             if masked else video.frame_bytes(f.width, f.height))
        start = max(f.t_gen, link_free)
        t_arr = start + b / byte_rate
        link_free = t_arr
        proxy = Patch(0, 0, f.width, f.height, t_gen=f.t_gen, slo=f.slo,
                      camera_id=f.camera_id)
        out.append(Arrival(t_arr, proxy, b))
    return out


def _collect(name: str, outcomes, bytes_sent, platform, batch_sizes,
             patches_per_batch, trans) -> Results:
    return Results(
        name=name, outcomes=outcomes, canvas_efficiencies=[],
        batch_sizes=batch_sizes, patches_per_batch=patches_per_batch,
        bytes_sent=bytes_sent, total_cost=platform.total_cost,
        invocations=len(platform.records),
        exec_seconds=platform.meter.busy_seconds,
        transmission_seconds=trans,
        mean_consolidation=platform.mean_consolidation)


# ------------------------------------------------------------ full/masked ----

def run_frame_baseline(frame_streams: Sequence[Sequence[FrameMeta]],
                       bandwidth_bps: float, platform: Platform,
                       masked: bool, name: Optional[str] = None) -> Results:
    """Full Frame / Masked Frame: one request per frame, in sequence."""
    per_cam = [_frame_arrivals(s, bandwidth_bps, masked)
               for s in frame_streams]
    arrivals = merge_arrivals(per_cam)
    outcomes = []
    for a in arrivals:
        rec = platform.submit(a.t_arrive, 1, n_patches=1)
        outcomes.append(PatchOutcome(a.patch, a.t_arrive, a.t_arrive,
                                     rec.t_finish))
    bytes_sent = sum(a.n_bytes for cam in per_cam for a in cam)
    trans = sum(a.t_arrive - a.patch.t_gen for cam in per_cam for a in cam)
    return _collect(name or ("masked_frame" if masked else "full_frame"),
                    outcomes, bytes_sent, platform,
                    [1] * len(arrivals), [1] * len(arrivals), trans)


# -------------------------------------------------------------------- ELF ----

def run_elf(streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            platform: Platform, canvas_area: int) -> Results:
    """Every patch is its own request (fractional canvas-equivalents)."""
    per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
    arrivals = merge_arrivals(per_cam)
    outcomes = []
    for a in arrivals:
        equiv = max(a.patch.area / canvas_area, 0.05)
        rec = platform.submit(a.t_arrive, equiv, n_patches=1)
        outcomes.append(PatchOutcome(a.patch, a.t_arrive, a.t_arrive,
                                     rec.t_finish))
    bytes_sent = sum(a.n_bytes for cam in per_cam for a in cam)
    trans = sum(a.t_arrive - a.patch.t_gen for cam in per_cam for a in cam)
    return _collect("elf", outcomes, bytes_sent, platform,
                    [1] * len(arrivals), [1] * len(arrivals), trans)


# ---------------------------------------------------------------- Clipper ----

def run_clipper(streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
                platform: Platform, canvas_area: int, tile_side: int = 512,
                slo: float = 1.0) -> Results:
    """AIMD dynamic batch size (Additive-Increase Multiplicative-Decrease).

    Requests are patches padded to tile_side^2; a batch fires when the
    queue reaches the current target; the target grows +1 when the batch
    met its SLO budget and halves on violation.  A drain timer (slo/2)
    bounds tail waiting, as in Clipper's adaptive batching.
    """
    per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
    arrivals = merge_arrivals(per_cam)
    tile_equiv = tile_side * tile_side / canvas_area
    target = 1.0
    queue: List[Arrival] = []
    outcomes, batch_sizes, ppb = [], [], []

    def fire(t_now: float):
        nonlocal target
        batch = queue[: max(1, int(target))]
        del queue[: len(batch)]
        rec = platform.submit(t_now, len(batch) * tile_equiv,
                              n_patches=len(batch))
        batch_sizes.append(len(batch))
        ppb.append(len(batch))
        ok = True
        for a in batch:
            outcomes.append(PatchOutcome(a.patch, a.t_arrive, t_now,
                                         rec.t_finish))
            ok &= rec.t_finish <= a.patch.deadline
        target = target + 1.0 if ok else max(1.0, target / 2.0)

    drain = slo / 2.0
    for a in arrivals:
        while queue and a.t_arrive - queue[0].t_arrive > drain:
            fire(queue[0].t_arrive + drain)
        queue.append(a)
        if len(queue) >= int(target):
            fire(a.t_arrive)
    while queue:
        fire(queue[0].t_arrive + drain)

    bytes_sent = sum(x.n_bytes for cam in per_cam for x in cam)
    trans = sum(x.t_arrive - x.patch.t_gen for cam in per_cam for x in cam)
    return _collect("clipper", outcomes, bytes_sent, platform, batch_sizes,
                    ppb, trans)


# ------------------------------------------------------------------- MArk ----

def run_mark(streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
             platform: Platform, canvas_area: int, tile_side: int = 512,
             max_batch: int = 8, timeout: float = 0.25) -> Results:
    """Max-batch + timeout batching over padded tiles."""
    per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
    arrivals = merge_arrivals(per_cam)
    tile_equiv = tile_side * tile_side / canvas_area
    queue: List[Arrival] = []
    outcomes, batch_sizes, ppb = [], [], []

    def fire(t_now: float):
        batch = list(queue)
        queue.clear()
        rec = platform.submit(t_now, len(batch) * tile_equiv,
                              n_patches=len(batch))
        batch_sizes.append(len(batch))
        ppb.append(len(batch))
        for a in batch:
            outcomes.append(PatchOutcome(a.patch, a.t_arrive, t_now,
                                         rec.t_finish))

    for a in arrivals:
        while queue and a.t_arrive - queue[0].t_arrive >= timeout:
            fire(queue[0].t_arrive + timeout)
        queue.append(a)
        if len(queue) >= max_batch:
            fire(a.t_arrive)
    while queue:
        fire(queue[0].t_arrive + timeout)

    bytes_sent = sum(x.n_bytes for cam in per_cam for x in cam)
    trans = sum(x.t_arrive - x.patch.t_gen for cam in per_cam for x in cam)
    return _collect("mark", outcomes, bytes_sent, platform, batch_sizes,
                    ppb, trans)
