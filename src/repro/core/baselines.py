"""Baseline serving policies from Section V-A, as engine batchers.

* Full Frame   — whole 4K frame per request, triggered in sequence.
* Masked Frame — non-RoIs masked, still full resolution per request [35].
* ELF          — every patch its own request [12].
* Clipper      — AIMD dynamic batch size over padded fixed-size tiles [23].
* MArk         — max-batch + timeout over padded fixed-size tiles [24].

Every policy is a batcher over the same :class:`~repro.core.engine.
ServingEngine` event loop Tangram runs on (arrivals, timers, completions
— no hand-rolled loops), dispatching to the same ``SimExecutor`` /
``Platform``, so cost/SLO comparisons isolate the batching policy.
Clipper and MArk cannot batch variable-size inputs, so patches are padded
to a fixed tile (``tile_side``); that padding waste vs Tangram's stitching
is exactly the paper's point.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.engine import Results, ServingEngine, SimExecutor
from repro.core.invoker import Invocation
from repro.core.partitioning import Patch
from repro.data import video
from repro.data.video import Arrival, merge_arrivals, shape_arrivals
from repro.serverless.platform import Platform


@dataclasses.dataclass(frozen=True)
class FrameMeta:
    """Per-frame record for the frame-level baselines."""
    width: int
    height: int
    fg_area: int
    t_gen: float
    slo: float
    camera_id: int = 0

    @property
    def deadline(self) -> float:
        return self.t_gen + self.slo


def _frame_arrivals(frames: Sequence[FrameMeta], bandwidth_bps: float,
                    masked: bool) -> List[Arrival]:
    byte_rate = bandwidth_bps / 8.0
    link_free = 0.0
    out = []
    for f in frames:
        b = (video.masked_frame_bytes(f.width, f.height, f.fg_area)
             if masked else video.frame_bytes(f.width, f.height))
        start = max(f.t_gen, link_free)
        t_arr = start + b / byte_rate
        link_free = t_arr
        proxy = Patch(0, 0, f.width, f.height, t_gen=f.t_gen, slo=f.slo,
                      camera_id=f.camera_id)
        out.append(Arrival(t_arr, proxy, b))
    return out


# --------------------------------------------------------------- batchers ----

class PassthroughBatcher:
    """Every arrival fires immediately as its own invocation.

    ``cost_for(patch)`` gives the invocation's canvas-equivalent billing
    size (1.0 for frame-level baselines, fractional for ELF).
    """

    def __init__(self, cost_for: Callable[[Patch], float] = lambda p: 1.0):
        self.cost_for = cost_for

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        return [Invocation(t_now, [], [patch], 0.0, "arrival",
                           cost_canvases=self.cost_for(patch))]

    def poll(self, t_now: float) -> Optional[Invocation]:
        return None

    def flush(self, t_now: float) -> Optional[Invocation]:
        return None

    def next_timer(self) -> float:
        return math.inf


class ClipperBatcher:
    """AIMD dynamic batch size (Additive-Increase Multiplicative-Decrease).

    Requests are patches padded to a fixed tile; a batch fires when the
    queue reaches the current target; the target grows +1 when the batch
    met its SLO budget and halves on violation.  The engine delivers the
    ``on_result`` feedback at *completion-delivery* time — the batcher
    learns a batch's fate when its result lands, as the real Clipper
    does, so arrivals in the dispatch->finish window still see the old
    target.  A drain timer (slo/2) bounds tail waiting, as in Clipper's
    adaptive batching.
    """

    def __init__(self, tile_equiv: float, drain: float):
        self.tile_equiv = tile_equiv
        self.drain = drain
        self.target = 1.0
        self.items: List[Tuple[float, Patch]] = []

    def _fire(self, t_now: float) -> Invocation:
        batch = self.items[: max(1, int(self.target))]
        del self.items[: len(batch)]
        return Invocation(t_now, [], [p for _, p in batch], 0.0, "clipper",
                          cost_canvases=len(batch) * self.tile_equiv)

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        self.items.append((t_now, patch))
        if len(self.items) >= int(self.target):
            return [self._fire(t_now)]
        return []

    def on_result(self, inv: Invocation, t_finish: float):
        ok = all(t_finish <= p.deadline for p in inv.patches)
        self.target = self.target + 1.0 if ok else max(1.0, self.target / 2.0)

    def next_timer(self) -> float:
        return self.items[0][0] + self.drain if self.items else math.inf

    def poll(self, t_now: float) -> Optional[Invocation]:
        if self.items and t_now >= self.items[0][0] + self.drain:
            return self._fire(self.items[0][0] + self.drain)
        return None

    def flush(self, t_now: float) -> Optional[Invocation]:
        if self.items:
            return self._fire(self.items[0][0] + self.drain)
        return None


class MArkBatcher:
    """Max-batch + timeout batching over padded tiles."""

    def __init__(self, tile_equiv: float, max_batch: int, timeout: float):
        self.tile_equiv = tile_equiv
        self.max_batch = max_batch
        self.timeout = timeout
        self.items: List[Tuple[float, Patch]] = []

    def _fire(self, t_now: float) -> Invocation:
        batch = list(self.items)
        self.items.clear()
        return Invocation(t_now, [], [p for _, p in batch], 0.0, "mark",
                          cost_canvases=len(batch) * self.tile_equiv)

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        fired = []
        # inclusive timeout: an arrival landing exactly on the boundary
        # still triggers the pending batch first (the engine only fires
        # timers scheduled strictly before an arrival)
        if self.items and t_now - self.items[0][0] >= self.timeout:
            fired.append(self._fire(self.items[0][0] + self.timeout))
        self.items.append((t_now, patch))
        if len(self.items) >= self.max_batch:
            fired.append(self._fire(t_now))
        return fired

    def next_timer(self) -> float:
        return self.items[0][0] + self.timeout if self.items else math.inf

    def poll(self, t_now: float) -> Optional[Invocation]:
        if self.items and t_now >= self.items[0][0] + self.timeout:
            return self._fire(self.items[0][0] + self.timeout)
        return None

    def flush(self, t_now: float) -> Optional[Invocation]:
        if self.items:
            return self._fire(self.items[0][0] + self.timeout)
        return None


# ---------------------------------------------------------------- runners ----

def _run(name: str, batcher, arrivals, per_cam, platform: Platform
         ) -> Results:
    engine = ServingEngine(batcher, SimExecutor(platform))
    outcomes = engine.run(arrivals)
    bytes_sent = sum(a.n_bytes for cam in per_cam for a in cam)
    trans = sum(a.t_arrive - a.patch.t_gen for cam in per_cam for a in cam)
    return Results(
        name=name, outcomes=outcomes, canvas_efficiencies=[],
        batch_sizes=[len(i.patches) for i in engine.invocations],
        patches_per_batch=[len(i.patches) for i in engine.invocations],
        bytes_sent=bytes_sent, total_cost=platform.total_cost,
        invocations=len(platform.records),
        exec_seconds=platform.meter.busy_seconds,
        transmission_seconds=trans,
        mean_consolidation=platform.mean_consolidation)


def run_frame_baseline(frame_streams: Sequence[Sequence[FrameMeta]],
                       bandwidth_bps: float, platform: Platform,
                       masked: bool, name: Optional[str] = None) -> Results:
    """Full Frame / Masked Frame: one request per frame, in sequence."""
    per_cam = [_frame_arrivals(s, bandwidth_bps, masked)
               for s in frame_streams]
    return _run(name or ("masked_frame" if masked else "full_frame"),
                PassthroughBatcher(), merge_arrivals(per_cam), per_cam,
                platform)


def run_elf(streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
            platform: Platform, canvas_area: int) -> Results:
    """Every patch is its own request (fractional canvas-equivalents)."""
    per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
    batcher = PassthroughBatcher(
        lambda p: max(p.area / canvas_area, 0.05))
    return _run("elf", batcher, merge_arrivals(per_cam), per_cam, platform)


def run_clipper(streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
                platform: Platform, canvas_area: int, tile_side: int = 512,
                slo: float = 1.0) -> Results:
    per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
    batcher = ClipperBatcher(tile_side * tile_side / canvas_area,
                             drain=slo / 2.0)
    return _run("clipper", batcher, merge_arrivals(per_cam), per_cam,
                platform)


def run_mark(streams: Sequence[Sequence[Patch]], bandwidth_bps: float,
             platform: Platform, canvas_area: int, tile_side: int = 512,
             max_batch: int = 8, timeout: float = 0.25) -> Results:
    per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
    batcher = MArkBatcher(tile_side * tile_side / canvas_area,
                          max_batch=max_batch, timeout=timeout)
    return _run("mark", batcher, merge_arrivals(per_cam), per_cam, platform)
