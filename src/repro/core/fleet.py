"""Fleet-scale sharded serving: camera-group shards under a two-level
scheduler, an event-heap invoker pool, and a cost-model-driven planner.

One :class:`~repro.core.engine.ServingEngine` is a single Python event
loop: every arrival pays an O(classes) timer scan in the invoker pool
and every submit an O(instances) warm scan in the platform, so a fleet
of thousands of cameras saturates the *scheduler* long before the
accelerators (ROADMAP item 2, BENCH_engine.json ``fleet``).  This module
shards the engine itself:

* :class:`ShardedEngine` — partitions cameras into shard groups, each
  with its own invoker pool, executor/worker subset, and arrival
  bookkeeping (a private :class:`~repro.core.engine.ServingEngine`).
  Scheduling is two-level: batching and timer firing are group-local
  (per shard), while placement of cameras onto shards and the final
  completion harvest are global.  Group routing reuses the engine's
  per-key ``classify`` notion — a shard's pool sees exactly the classes
  its cameras produce.
* :class:`FleetInvokerPool` — an :class:`~repro.core.engine.InvokerPool`
  with an event-heap timer index: ``next_timer``/``poll`` peek a lazy
  heap keyed ``(timer, registration_index)`` instead of scanning every
  class, so the no-timer-due case (the common case between firings) is
  O(1).  Tie rules are bit-identical to the stock pool (earliest timer,
  then first-registered class) — pinned by an equivalence test.
* :class:`FleetPlanner` + :class:`FleetCostModel` — a HugeCTR-style
  shard planner (SNIPPETS.md snippet 3: a ``CostModel`` scoring
  candidate shard matrices under compute/bandwidth ratios, searched by
  a ``Planner``): from per-camera arrival rates and the profiled
  :class:`~repro.core.latency.LatencyTable` it picks the shard count,
  the camera->shard grouping (LPT balancing), the per-shard worker
  allocation, and per-class worker reservations, and is refined online
  by :class:`~repro.core.latency.OnlineLatencyTable` drift ratios
  (:meth:`FleetPlanner.replan`).

The resulting :class:`FleetPlan` is JSON-safe (``to_dict`` /
``from_dict``) so a planned layout can be logged into benchmark JSON
and rebuilt, like a :class:`~repro.core.config.ServeConfig`.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, Iterable, List, Mapping, Optional, \
    Sequence, Tuple

from repro.core.engine import (InvokerPool, PatchOutcome, ServingEngine,
                               slo_class)
from repro.core.invoker import Invocation, SLOAwareInvoker
from repro.core.partitioning import Patch
from repro.core.registry import lookup
from repro.core.workers import ReservedClassPlacement
from repro.data.video import Arrival

__all__ = [
    "FleetCostModel", "FleetInvokerPool", "FleetPlan", "FleetPlanner",
    "EqualSplitPlanner", "ReservedClassPlacement", "ShardedEngine",
    "fleet_uniform_pool", "make_planner",
]


# ------------------------------------------------------ event-heap pool ----

class FleetInvokerPool(InvokerPool):
    """Invoker pool with an event-heap timer index (shard hot path).

    The stock pool's ``next_timer`` and ``poll`` scan every class on
    every engine event — O(classes) per *arrival*, which at fleet scale
    (hundreds of camera-group classes) dominates the event loop.  Here
    each class keeps at most one live entry ``(timer, registration
    index, version, key)`` on a heap, re-pushed whenever the class
    mutates (arrival, fire, flush — the only points an invoker's stored
    ``t_remain`` can change); stale versions are discarded lazily on
    peek.  ``poll`` therefore answers "no timer due" in O(1) and fires
    in O(log classes).

    Ordering is identical to the stock scan — earliest timer first,
    ties to the first-registered class (the heap's registration-index
    component reproduces the dict-iteration-order ``min``) — pinned by
    a randomized equivalence test against :class:`InvokerPool`.
    """

    def __init__(self, make_invoker: Callable[[object], SLOAwareInvoker],
                 classify: Callable[[Patch], object] = slo_class,
                 model_of: Optional[Callable[[object],
                                             Optional[str]]] = None):
        super().__init__(make_invoker, classify, model_of=model_of)
        self._heap: List[Tuple[float, int, int, object]] = []
        self._version: Dict[object, int] = {}
        self._reg: Dict[object, int] = {}
        self._in_heap: Dict[object, bool] = {}
        self._stale = 0

    def _invoker(self, key: object) -> SLOAwareInvoker:
        inv = self.invokers.get(key)
        if inv is None:
            inv = super()._invoker(key)
            self._reg[key] = len(self._reg)
            self._version[key] = 0
        return inv

    def _reindex(self, key: object) -> None:
        """Refresh ``key``'s heap entry after a mutation."""
        if self._in_heap.get(key):
            self._stale += 1        # the old live entry just went stale
        version = self._version[key] + 1
        self._version[key] = version
        t = self.invokers[key].next_timer()
        if t != math.inf:
            heapq.heappush(self._heap, (t, self._reg[key], version, key))
            self._in_heap[key] = True
        else:
            self._in_heap[key] = False
        if self._stale > 2 * len(self.invokers) + 16:
            # compact: the exact stale count says dead entries exceed
            # 2x the live classes, so rebuild — a churn-heavy class set
            # (cameras cycling between timered and idle) would otherwise
            # grow the heap without bound between pops
            self._heap = [e for e in self._heap
                          if self._version.get(e[3]) == e[2]]
            heapq.heapify(self._heap)
            self._stale = 0

    def on_patch(self, t_now: float, patch: Patch) -> List[Invocation]:
        key = self.classify(patch)
        fired = self._invoker(key).on_patch(t_now, patch)
        self._reindex(key)
        return self._tag(fired, key)

    def next_timer(self) -> float:
        heap = self._heap
        while heap:
            t, _, version, key = heap[0]
            if self._version.get(key) == version:
                return t
            heapq.heappop(heap)
            self._stale -= 1
        return math.inf

    def poll(self, t_now: float) -> Optional[Invocation]:
        heap = self._heap
        while heap:
            t, _, version, key = heap[0]
            if self._version.get(key) != version:
                heapq.heappop(heap)
                self._stale -= 1
                continue
            if t > t_now:
                return None
            heapq.heappop(heap)
            self._in_heap[key] = False
            fired = self.invokers[key].poll(t_now)
            self._reindex(key)
            if fired is not None:
                self._tag([fired], key)
            return fired
        return None

    def flush(self, t_now: float) -> Optional[Invocation]:
        for key, inv in self.invokers.items():
            fired = inv.flush(t_now)
            if fired is not None:
                self._reindex(key)
                self._tag([fired], key)
                return fired
        return None


def fleet_uniform_pool(canvas_m: int, canvas_n: int, latency,
                       max_canvases: int = 8, incremental: bool = True,
                       classify: Optional[Callable[[Patch], object]] = None,
                       model_of: Optional[Callable[[object],
                                                   Optional[str]]] = None
                       ) -> FleetInvokerPool:
    """:func:`~repro.core.engine.uniform_pool` with the event-heap pool
    (one geometry/latency spec shared by every class)."""
    return FleetInvokerPool(
        lambda key: SLOAwareInvoker(canvas_m, canvas_n, latency,
                                    max_canvases, incremental=incremental),
        classify if classify is not None else (lambda p: None),
        model_of=model_of)


# -------------------------------------------------------------- the plan ----

@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """A fleet layout: camera groups, worker allocation, reservations.

    ``camera_groups[s]`` lists the camera ids routed to shard ``s``; a
    camera id in no group (or an empty ``camera_groups``) falls back to
    ``camera_id % n_shards``, so live fleets that grow new cameras keep
    routing deterministically.  ``workers[s]`` is shard ``s``'s worker
    allocation and ``reservations[s]`` maps a class key's ``str()`` to
    the number of that shard's workers reserved for it (lowest indices
    first; empty: no reservation).  JSON-safe via ``to_dict`` /
    ``from_dict``; ``predicted`` carries the planner's per-shard
    diagnostics (rate, scheduler/device utilization, score).
    """

    n_shards: int
    camera_groups: Tuple[Tuple[int, ...], ...] = ()
    workers: Tuple[int, ...] = ()
    reservations: Tuple[Dict[str, int], ...] = ()
    predicted: Optional[dict] = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.camera_groups and len(self.camera_groups) != self.n_shards:
            raise ValueError(
                f"{len(self.camera_groups)} camera groups for "
                f"{self.n_shards} shards")
        if self.workers and len(self.workers) != self.n_shards:
            raise ValueError(f"{len(self.workers)} worker allocations for "
                             f"{self.n_shards} shards")
        object.__setattr__(self, "_shard_by_camera", {
            cam: s for s, group in enumerate(self.camera_groups)
            for cam in group})

    def shard_of(self, camera_id: int) -> int:
        """Camera id -> shard index (modulo fallback for new cameras)."""
        s = self._shard_by_camera.get(camera_id)
        if s is not None:
            return s
        return camera_id % self.n_shards

    def workers_of(self, shard: int) -> int:
        return self.workers[shard] if self.workers else 1

    def to_dict(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "camera_groups": [list(g) for g in self.camera_groups],
            "workers": list(self.workers),
            "reservations": [dict(r) for r in self.reservations],
            "predicted": self.predicted,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FleetPlan":
        return cls(
            n_shards=d["n_shards"],
            camera_groups=tuple(tuple(g)
                                for g in d.get("camera_groups") or ()),
            workers=tuple(d.get("workers") or ()),
            reservations=tuple(dict(r)
                               for r in d.get("reservations") or ()),
            predicted=d.get("predicted"))


# ------------------------------------------------------------ cost model ----

@dataclasses.dataclass(frozen=True)
class FleetCostModel:
    """Per-shard resource ratios, HugeCTR-style (SNIPPETS.md snippet 3).

    The exemplar scores a candidate shard matrix by its worst
    compute/bandwidth ratio; here the two resources are the shard's
    *event loop* (a serial Python scheduler: per-event cost plus a
    per-class scan term) and its *workers* (service seconds per patch
    from the profiled latency table, scaled by the online drift ratio).
    A layout's score is the bottleneck shard's utilization plus a small
    per-shard overhead so the search does not shard without bound.

    ``service_s(batch)`` reads ``latency.mu_sigma`` — the same profiled
    table the invokers batch against — so the plan and the firing policy
    agree on how fast the accelerator is.  ``drift`` multiplies service
    time (1.0 = profile holds); :meth:`FleetPlanner.replan` feeds the
    :class:`~repro.core.latency.OnlineLatencyTable`'s clamped EWMA ratio
    in, closing the offline-plan / online-reality loop.
    """

    latency: object                   # LatencyTable duck (mu_sigma)
    sched_event_s: float = 8e-6      # event-loop seconds per arrival
    sched_class_s: float = 1.2e-7    # per-class scan seconds per arrival
    consolidation: float = 4.0       # patches per fired invocation
    canvases_per_batch: int = 2      # expected canvases per invocation
    target_util: float = 0.7         # keep shards below this utilization
    shard_overhead: float = 0.01     # score penalty per shard
    drift: float = 1.0               # online latency drift multiplier

    def service_per_patch(self) -> float:
        """Accelerator-seconds of service one patch costs (amortized
        over the expected consolidation)."""
        mu, _ = self.latency.mu_sigma(self.canvases_per_batch)
        return self.drift * mu / max(self.consolidation, 1e-9)

    def sched_util(self, rate: float, n_classes: int) -> float:
        """Event-loop utilization of one shard ingesting ``rate``
        arrivals/sec over ``n_classes`` invoker classes."""
        return rate * (self.sched_event_s
                       + self.sched_class_s * max(n_classes, 1))

    def device_util(self, rate: float, workers: int) -> float:
        """Worker-pool utilization of one shard: service demand over
        ``workers`` concurrent batch servers."""
        return rate * self.service_per_patch() / max(workers, 1)

    def shard_util(self, rate: float, n_classes: int,
                   workers: int) -> float:
        return max(self.sched_util(rate, n_classes),
                   self.device_util(rate, workers))

    def score(self, group_rates: Sequence[float],
              group_classes: Sequence[int],
              workers: Sequence[int]) -> float:
        """Bottleneck-shard utilization + per-shard overhead (lower is
        better); ``inf`` for an empty candidate."""
        if not group_rates:
            return math.inf
        worst = max(self.shard_util(r, c, w) for r, c, w
                    in zip(group_rates, group_classes, workers))
        return worst + self.shard_overhead * len(group_rates)


# --------------------------------------------------------------- planner ----

def _lpt_groups(camera_rates: Mapping[int, float], n_shards: int
                ) -> Tuple[List[List[int]], List[float]]:
    """Longest-processing-time camera assignment: hottest camera first
    onto the least-loaded shard.  Returns (groups, per-group rate)."""
    heap = [(0.0, s) for s in range(n_shards)]
    heapq.heapify(heap)
    groups: List[List[int]] = [[] for _ in range(n_shards)]
    loads = [0.0] * n_shards
    for cam, rate in sorted(camera_rates.items(),
                            key=lambda kv: (-kv[1], kv[0])):
        load, s = heapq.heappop(heap)
        groups[s].append(cam)
        loads[s] = load + rate
        heapq.heappush(heap, (loads[s], s))
    for g in groups:
        g.sort()
    return groups, loads


def _proportional_workers(loads: Sequence[float], budget: int) -> List[int]:
    """Split ``budget`` workers over shards proportionally to load
    (largest remainder), every shard getting at least one."""
    n = len(loads)
    budget = max(budget, n)
    total = sum(loads) or 1.0
    raw = [load / total * budget for load in loads]
    out = [max(1, int(r)) for r in raw]
    while sum(out) > budget:   # the max(1,...) floor may overshoot
        i = max(range(n), key=lambda j: (out[j] - raw[j], out[j]))
        if out[i] <= 1:
            break
        out[i] -= 1
    remainders = sorted(range(n), key=lambda j: (raw[j] - out[j], loads[j]),
                        reverse=True)
    i = 0
    while sum(out) < budget:
        out[remainders[i % n]] += 1
        i += 1
    return out


def _reservations(class_rates: Optional[Mapping[object, float]],
                  workers: Sequence[int]) -> Tuple[Dict[str, int], ...]:
    """Per-shard per-class worker reservations: each class gets its
    rate-proportional share of the shard's workers (floor, so something
    is always left unreserved for strays); single-class fleets and
    single-worker shards reserve nothing."""
    if not class_rates or len(class_rates) < 2:
        return tuple({} for _ in workers)
    total = sum(class_rates.values()) or 1.0
    out = []
    for w in workers:
        if w < 2:
            out.append({})
            continue
        row = {}
        for key, rate in sorted(class_rates.items(),
                                key=lambda kv: str(kv[0])):
            share = int(w * rate / total)
            if share >= 1:
                row[str(key)] = share
        out.append(row)
    return tuple(out)


class FleetPlanner:
    """Search shard layouts under :class:`FleetCostModel` (the HugeCTR
    ``Planner`` idiom: enumerate candidate shard counts, assign work,
    score, keep the argmin).

    For each candidate shard count (powers of two up to ``max_shards``)
    cameras are LPT-balanced by rate, the worker budget is split
    proportionally to shard load, and the layout is scored by the cost
    model; ties prefer fewer shards.  ``class_rates`` (optional) drives
    per-class worker reservations inside each shard.
    """

    def __init__(self, cost_model: FleetCostModel,
                 worker_budget: Optional[int] = None,
                 max_shards: int = 64):
        if max_shards < 1:
            raise ValueError(f"max_shards must be >= 1, got {max_shards}")
        self.cost_model = cost_model
        self.worker_budget = worker_budget
        self.max_shards = max_shards

    def _candidates(self, n_cameras: int) -> Iterable[int]:
        s = 1
        while s <= min(self.max_shards, n_cameras):
            yield s
            s *= 2

    def plan(self, camera_rates: Mapping[int, float],
             class_rates: Optional[Mapping[object, float]] = None,
             classes_per_camera: int = 1,
             n_shards: Optional[int] = None,
             camera_block: int = 1) -> FleetPlan:
        """Pick the layout for a fleet of ``camera_rates`` (camera id ->
        patch arrivals/sec).  ``n_shards`` pins the shard count (the
        benchmark's per-shard-count sweep); ``None`` searches.
        ``classes_per_camera`` sizes each shard's class count for the
        scheduler term (e.g. 2 when classify is (slo, group)).
        ``camera_block`` LPT-balances contiguous id-blocks of that size
        instead of single cameras — match it to the classify grouping
        (e.g. 8 for ``camera_id // 8`` keys) so cameras sharing a batch
        class land on the same shard and keep batching together."""
        if not camera_rates:
            raise ValueError("camera_rates must not be empty")
        if camera_block < 1:
            raise ValueError(
                f"camera_block must be >= 1, got {camera_block}")
        budget = (self.worker_budget if self.worker_budget is not None
                  else n_shards or 1)
        if camera_block > 1:
            block_rates: Dict[int, float] = {}
            block_members: Dict[int, List[int]] = {}
            for cam, rate in camera_rates.items():
                b = cam // camera_block
                block_rates[b] = block_rates.get(b, 0.0) + rate
                block_members.setdefault(b, []).append(cam)
            unit_rates: Mapping[int, float] = block_rates
        else:
            unit_rates = camera_rates
        best = None
        candidates = ([n_shards] if n_shards is not None
                      else self._candidates(len(camera_rates)))
        for s in candidates:
            s = min(s, len(unit_rates))
            groups, loads = _lpt_groups(unit_rates, s)
            if camera_block > 1:
                groups = [sorted(cam for b in g for cam in block_members[b])
                          for g in groups]
            workers = _proportional_workers(loads, max(budget, s))
            n_classes = [max(1, -(-len(g) // camera_block))
                         * classes_per_camera for g in groups]
            score = self.cost_model.score(loads, n_classes, workers)
            if best is None or score < best[0]:
                best = (score, s, groups, loads, workers, n_classes)
        score, s, groups, loads, workers, n_classes = best
        cm = self.cost_model
        predicted = {
            "score": round(score, 6),
            "drift": cm.drift,
            "shards": [
                {"rate": round(r, 3), "classes": c, "workers": w,
                 "sched_util": round(cm.sched_util(r, c), 4),
                 "device_util": round(cm.device_util(r, w), 4)}
                for r, c, w in zip(loads, n_classes, workers)],
        }
        return FleetPlan(
            n_shards=s,
            camera_groups=tuple(tuple(g) for g in groups),
            workers=tuple(workers),
            reservations=_reservations(class_rates, workers),
            predicted=predicted)

    def replan(self, camera_rates: Mapping[int, float], estimator,
               **kwargs) -> FleetPlan:
        """Online refinement: fold the estimator's observed drift ratio
        (:meth:`OnlineLatencyTable.drift`) into the cost model's service
        term and re-run the search — a fleet whose accelerators run
        slower than profiled gets more workers per shard (and possibly
        a different shard count) without re-profiling."""
        drift = estimator.drift() if hasattr(estimator, "drift") else 1.0
        refined = dataclasses.replace(self.cost_model, drift=drift)
        return FleetPlanner(refined, self.worker_budget,
                            self.max_shards).plan(camera_rates, **kwargs)


class EqualSplitPlanner:
    """The naive baseline the cost planner must beat: contiguous
    equal-count camera groups in id order, workers split evenly —
    oblivious to per-camera rates."""

    def __init__(self, cost_model: Optional[FleetCostModel] = None,
                 worker_budget: Optional[int] = None,
                 max_shards: int = 64, default_shards: int = 16):
        self.cost_model = cost_model
        self.worker_budget = worker_budget
        self.max_shards = max_shards
        self.default_shards = default_shards

    def plan(self, camera_rates: Mapping[int, float],
             class_rates: Optional[Mapping[object, float]] = None,
             classes_per_camera: int = 1,
             n_shards: Optional[int] = None) -> FleetPlan:
        cams = sorted(camera_rates)
        s = min(n_shards or self.default_shards, self.max_shards,
                len(cams))
        budget = max(self.worker_budget if self.worker_budget is not None
                     else s, s)
        per = -(-len(cams) // s)
        groups = [cams[i * per:(i + 1) * per] for i in range(s)]
        groups = [g for g in groups if g]
        s = len(groups)
        workers = _proportional_workers([1.0] * s, budget)
        return FleetPlan(
            n_shards=s,
            camera_groups=tuple(tuple(g) for g in groups),
            workers=tuple(workers),
            reservations=tuple({} for _ in range(s)))


_PLANNERS = {
    "cost": FleetPlanner,
    "equal": EqualSplitPlanner,
}


def make_planner(name: str, **cfg):
    """Planner-name -> instance (``cost`` | ``equal``), mirroring
    ``make_placement`` / ``make_source`` — the named reference behind
    ``ServeConfig.planner``."""
    return lookup("planner", _PLANNERS, name)(**cfg)


# --------------------------------------------------------- sharded engine ----

class ShardedEngine:
    """Camera-group shards under a two-level scheduler.

    Level 1 (global): every arrival routes to its camera's shard
    (``plan.shard_of``), with consecutive same-shard runs drained into
    the shard in one :meth:`ServingEngine.offer_batch` call; the
    completion harvest re-merges every shard's outcomes into one stream
    with a pinned order.  Level 2 (group-local): each shard is a private
    :class:`~repro.core.engine.ServingEngine` — its own invoker pool
    (classes = the shard's camera groups x SLO), executor / worker
    subset, arrival slots, and event heap — so batching and timer firing
    never contend with other shards' cameras.

    With one shard this is *event-identical* to driving the inner
    ``ServingEngine`` directly (pinned by test): routing degenerates to
    the identity and the merge to a copy.

    Cross-shard outcome order is pinned: ``(t_finish, shard index,
    within-shard delivery order)`` — simultaneous completions on
    different shards deliver in shard order, so N-shard replays are
    reproducible run-to-run (regression-tested).
    """

    def __init__(self, shards: Sequence[ServingEngine],
                 shard_of_camera: Callable[[int], int],
                 plan: Optional[FleetPlan] = None):
        if not shards:
            raise ValueError("ShardedEngine needs at least one shard")
        self.shards = list(shards)
        self.shard_of_camera = shard_of_camera
        self.plan = plan
        self.ingestion_window = None
        for eng in self.shards:
            if eng.ingestion_window is not None:
                self.ingestion_window = ((self.ingestion_window or 0)
                                         + eng.ingestion_window)
        self._outcomes: Optional[List[PatchOutcome]] = None
        self._finished = False

    @classmethod
    def build(cls, plan: FleetPlan,
              make_shard: Callable[[int, FleetPlan], ServingEngine]
              ) -> "ShardedEngine":
        """Construct the fleet from a plan: ``make_shard(s, plan)``
        builds shard ``s``'s engine (pool + executor wired to
        ``plan.workers_of(s)`` / ``plan.reservations[s]``)."""
        shards = [make_shard(s, plan) for s in range(plan.n_shards)]
        return cls(shards, plan.shard_of, plan=plan)

    # ----------------------------------------------------------- feeding ----

    def shard_of(self, patch: Patch) -> int:
        return self.shard_of_camera(patch.camera_id)

    def offer(self, arrival: Arrival):
        self._outcomes = None
        self.shards[self.shard_of(arrival.patch)].offer(arrival)

    def run(self, arrivals: Sequence[Arrival]) -> List[PatchOutcome]:
        """Drive a merged (sorted-by-``t_arrive``) fleet trace to empty.

        Consecutive same-shard arrivals are drained into the shard in
        one ``offer_batch`` call, so the global router touches each
        *run*, not each event."""
        shard_of_camera = self.shard_of_camera
        run_buf: List[Arrival] = []
        current = -1
        for arr in arrivals:
            s = shard_of_camera(arr.patch.camera_id)
            if s != current:
                if run_buf:
                    self.shards[current].offer_batch(run_buf)
                    run_buf = []
                current = s
            run_buf.append(arr)
        if run_buf:
            self.shards[current].offer_batch(run_buf)
        self.finish()
        return self.outcomes

    def serve(self, source) -> List[PatchOutcome]:
        """Pull loop over a :mod:`repro.sources` source; this engine is
        the backpressure handle (global backlog vs the summed window)."""
        for arr in source.events(self):
            self.offer(arr)
        self.finish()
        return self.outcomes

    def finish(self, t_end: Optional[float] = None):
        # Barrier-clock members (parallel-runtime equivalence tests):
        # lift every shard to the fleet-wide max time before finishing,
        # the non-blocking twin of the threaded runners' end-of-input
        # ``sync()`` — so both paths flush trailing partial canvases at
        # the same engine time.
        aligned = []
        for eng in self.shards:
            parent = getattr(eng.clock, "parent", None)
            if parent is not None and hasattr(parent, "align") \
                    and all(parent is not p for p in aligned):
                parent.align()
                aligned.append(parent)
        for s, eng in enumerate(self.shards):
            eng.finish(t_end)
            for inv in eng.invocations:
                if inv.shard is None:
                    inv.shard = s
        self._finished = True
        self._outcomes = None

    # ------------------------------------------------------- backpressure ----

    def backlog(self) -> int:
        return sum(eng.backlog() for eng in self.shards)

    def queued_patches(self) -> int:
        return sum(eng.queued_patches() for eng in self.shards)

    def inflight_patches(self) -> int:
        return sum(eng.inflight_patches() for eng in self.shards)

    def overloaded(self) -> bool:
        return (self.ingestion_window is not None
                and self.backlog() >= self.ingestion_window)

    @property
    def backlog_high_water(self) -> int:
        """Upper bound on the global backlog peak (shard peaks need not
        coincide; the exact global maximum would cost O(shards) per
        arrival to track)."""
        return sum(eng.backlog_high_water for eng in self.shards)

    @property
    def arrivals_total(self) -> int:
        return sum(eng.arrivals_total for eng in self.shards)

    # ----------------------------------------------------------- harvest ----

    @property
    def outcomes(self) -> List[PatchOutcome]:
        """Every shard's outcomes merged into one stream, ordered by
        ``(t_finish, shard index, within-shard delivery order)`` — the
        pinned cross-shard tie rule."""
        if self._outcomes is None:
            rows = []
            for s, eng in enumerate(self.shards):
                rows.extend(((o.t_finish, s, i), o)
                            for i, o in enumerate(eng.outcomes))
            rows.sort(key=lambda r: r[0])
            self._outcomes = [o for _, o in rows]
        return self._outcomes

    @property
    def invocations(self) -> List[Invocation]:
        return [inv for eng in self.shards for inv in eng.invocations]

    @property
    def completions(self) -> List:
        return [c for eng in self.shards for c in eng.completions]

    def shard_stats(self, horizon: Optional[float] = None) -> List[dict]:
        """Per-shard observability rows (``Results.summary()``'s
        ``per_shard`` section): arrivals, invocations, violations,
        backlog high water, and utilization — shard imbalance without a
        profiler."""
        if horizon is None:
            horizon = max((o.t_finish for o in self.outcomes), default=0.0)
        rows = []
        for s, eng in enumerate(self.shards):
            violations = sum(o.violated for o in eng.outcomes)
            row = {
                "shard": s,
                "cameras": (len(self.plan.camera_groups[s])
                            if self.plan and self.plan.camera_groups
                            else None),
                "workers": (self.plan.workers_of(s) if self.plan else 1),
                "arrivals": eng.arrivals_total,
                "invocations": len(eng.invocations),
                "violations": violations,
                "violation_rate": round(
                    violations / max(len(eng.outcomes), 1), 4),
                "backlog_high_water": eng.backlog_high_water,
            }
            platform = getattr(eng.executor, "platform", None)
            if platform is not None and horizon > 0:
                row["utilization"] = round(platform.utilization(horizon), 4)
            rows.append(row)
        return rows
