"""Recorded frame sequences as a live source.

``FileStreamSource`` plays a recorded stack of frames
(:func:`~repro.data.video.load_frames`: ``.npy``/``.npz`` stack or a
directory of per-frame ``.npy`` files) through the same edge pipeline
and rate clock as the synthetic camera — GMM, RoI extraction, Alg. 1
partitioning, FIFO uplink, overload policy.  The recording loops when
``n_frames`` exceeds its length, so a short clip can drive a long
(or overload) run.
"""
from __future__ import annotations

import pathlib
from typing import Optional, Tuple, Union

import numpy as np

from repro.data.video import load_frames
from repro.sources.camera import LiveSource


class FileStreamSource(LiveSource):
    """Replay a recorded frame stack through the live edge pipeline."""

    kind = "file"

    def __init__(self, path: Union[str, pathlib.Path],
                 n_frames: Optional[int] = None, canvas: int = 256,
                 **kwargs):
        self.frames = load_frames(path)
        t, height, width = self.frames.shape
        super().__init__(height, width,
                         n_frames if n_frames is not None else t,
                         canvas=canvas, **kwargs)

    def _frame(self, idx: int) -> Tuple[int, np.ndarray]:
        frame = self.frames[idx % len(self.frames)]
        return (self.camera_id << 20) | idx, frame
