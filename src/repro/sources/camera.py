"""Live camera sources: the edge half of the paper as a Source.

Each camera runs the full per-frame edge pipeline — GMM background
subtraction -> RoI extraction -> adaptive frame partitioning (Alg. 1) —
and ships the resulting patches over its own FIFO uplink
(:class:`~repro.data.video.Uplink`), yielding shaped arrivals to the
engine as they would land on the cloud side.

Frame timing comes from a :class:`RateProfile`: a base fps modulated by
a seeded diurnal cycle (slow sinusoid in frame rate) and random bursts
(short stretches of elevated rate), reproducing the irregular load
fluctuation of the paper's Fig. 3 deterministically per seed.

Backpressure: between frames the source reads the engine's backlog
against its ingestion window and applies an overload policy —

* ``"drop"``   — skip the frame entirely (the GMM background model still
  updates, so recovery is immediate once load falls);
* ``"degrade"``— extract RoIs with :meth:`RoIConfig.degraded` (coarser
  grid, fewer components -> fewer, coarser patches), escalating to a
  drop at twice the window;
* ``"none"``   — ignore the signal (a camera that won't throttle).

Dropped/degraded frame counts surface in ``stats()`` and from there in
``Results.summary()["source"]``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import gmm, partitioning
from repro.core.rois import RoIConfig, extract_rois_jit
from repro.data.synthetic import Scene, preset
from repro.data.video import Arrival, Uplink
from repro.sources.base import SourceStats


@dataclasses.dataclass(frozen=True)
class RateProfile:
    """Seeded frame-clock model: diurnal cycle + random bursts.

    The instantaneous rate at time ``t`` is ``fps * (1 +
    diurnal_amplitude * sin(2 pi t / diurnal_period_s))``, multiplied by
    ``burst_factor`` for frames where a seeded coin lands under
    ``burst_prob``.  Frame interval = 1 / rate; with the defaults this
    degenerates to a constant ``1/fps`` clock.
    """

    fps: float = 10.0
    diurnal_amplitude: float = 0.0   # in [0, 1)
    diurnal_period_s: float = 60.0
    burst_prob: float = 0.0
    burst_factor: float = 3.0
    seed: int = 0

    def __post_init__(self):
        if self.fps <= 0:
            raise ValueError(f"fps must be positive, got {self.fps}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError(f"diurnal_amplitude must be in [0, 1), got "
                             f"{self.diurnal_amplitude}")
        if self.burst_factor <= 0:
            raise ValueError(f"burst_factor must be positive, got "
                             f"{self.burst_factor}")

    def intervals(self) -> Iterator[float]:
        """Yield successive frame intervals (seconds), deterministically
        per seed."""
        rng = np.random.default_rng(self.seed)
        t = 0.0
        while True:
            rate = self.fps * (1.0 + self.diurnal_amplitude
                               * math.sin(2.0 * math.pi * t
                                          / self.diurnal_period_s))
            if self.burst_prob > 0 and rng.random() < self.burst_prob:
                rate *= self.burst_factor
            dt = 1.0 / rate
            t += dt
            yield dt


class EdgePipeline:
    """Per-camera frame -> patches: GMM -> RoIs -> Alg. 1 -> canvas clamp.

    Holds the GMM background state across frames.  ``degrade=True``
    switches RoI extraction to the reduced-quality config for that frame
    only; the background model is shared, so quality recovers instantly.
    """

    def __init__(self, height: int, width: int, canvas: int,
                 slo: float = 1.0, roi_cfg: RoIConfig = RoIConfig(),
                 zones: Tuple[int, int] = (4, 4), warmup_s: float = 1.0):
        self.height, self.width = height, width
        self.canvas = canvas
        self.slo = slo
        self.roi_cfg = roi_cfg
        self.roi_degraded = roi_cfg.degraded()
        self.zones = zones
        self.warmup_s = warmup_s
        self.state = gmm.init_state(height, width)

    def observe(self, frame: np.ndarray) -> None:
        """Update the background model only (the drop path)."""
        self.state, _ = gmm.update_jit(self.state, jnp.asarray(frame))

    def process(self, t: float, frame: np.ndarray, frame_id: int,
                camera_id: int, degrade: bool = False):
        """Full pipeline for one frame; [] during GMM warm-up."""
        self.state, fg = gmm.update_jit(self.state, jnp.asarray(frame))
        if t < self.warmup_s:
            return []
        cfg = self.roi_degraded if degrade else self.roi_cfg
        boxes, valid = extract_rois_jit(jnp.asarray(fg), cfg)
        boxes_np = np.asarray(boxes)[np.asarray(valid)]
        patches = partitioning.partition_host(
            boxes_np, self.width, self.height, *self.zones,
            frame_id=frame_id, camera_id=camera_id, t_gen=t, slo=self.slo)
        # enclosing rects can exceed zones; clamp to the canvas tile
        c = self.canvas
        return [partitioning.Patch(
            p.x0, p.y0, min(p.x1, p.x0 + c), min(p.y1, p.y0 + c),
            p.frame_id, p.camera_id, p.t_gen, p.slo) for p in patches]


class LiveSource:
    """Shared frame loop for live sources (synthetic camera, file stream).

    Subclasses provide ``_frame(idx) -> (frame_id, gray)`` and optionally
    ``_rgb()``; this class owns the rate clock, the overload policy, the
    edge pipeline, the uplink, and the accounting.  ``frame_sink`` (if
    set) receives ``(frame_id, rgb, n_patches)`` for every transmitted
    frame — the hook device executors use to register frames in their
    refcounted store.  Single-use: ``events`` consumes the stream.
    """

    kind = "live"

    def __init__(self, height: int, width: int, n_frames: int,
                 canvas: int = 256, slo: float = 1.0,
                 bandwidth_bps: float = 40e6, camera_id: int = 0,
                 rate: Optional[RateProfile] = None,
                 overload: str = "drop", warmup_s: float = 1.0,
                 roi_cfg: RoIConfig = RoIConfig(),
                 frame_sink: Optional[Callable] = None):
        if overload not in ("drop", "degrade", "none"):
            raise ValueError(f"unknown overload policy {overload!r}; "
                             f"choose from ['degrade', 'drop', 'none']")
        if n_frames < 1:
            raise ValueError(f"n_frames must be >= 1, got {n_frames}")
        self.n_frames = n_frames
        self.camera_id = camera_id
        self.rate = rate if rate is not None else RateProfile()
        self.overload = overload
        self.frame_sink = frame_sink
        self.pipeline = EdgePipeline(height, width, canvas, slo=slo,
                                     roi_cfg=roi_cfg, warmup_s=warmup_s)
        self.uplink = Uplink(bandwidth_bps)
        self._stats = SourceStats(kind=self.kind)

    # ------------------------------------------------- subclass surface ----

    def _frame(self, idx: int) -> Tuple[int, np.ndarray]:
        """Produce frame ``idx``: (frame_id, grayscale (H, W) float32)."""
        raise NotImplementedError

    def _rgb(self, frame: np.ndarray) -> np.ndarray:
        return np.stack([frame, frame, frame], axis=-1)

    # ------------------------------------------------------- frame loop ----

    def _policy(self, engine) -> str:
        """One of "send" | "degrade" | "drop" for the next frame."""
        window = getattr(engine, "ingestion_window", None) \
            if engine is not None else None
        if window is None or self.overload == "none":
            return "send"
        backlog = engine.backlog()
        if backlog < window:
            return "send"
        if self.overload == "drop" or backlog >= 2 * window:
            return "drop"
        return "degrade"

    def events(self, engine) -> Iterator[Arrival]:
        t = 0.0
        intervals = self.rate.intervals()
        for idx in range(self.n_frames):
            t += next(intervals)
            frame_id, frame = self._frame(idx)
            self._stats.frames_total += 1
            action = self._policy(engine)
            if action == "drop":
                self.pipeline.observe(frame)   # background stays fresh
                self._stats.frames_dropped += 1
                continue
            if action == "degrade":
                self._stats.frames_degraded += 1
            patches = self.pipeline.process(t, frame, frame_id,
                                            self.camera_id,
                                            degrade=action == "degrade")
            if self.frame_sink is not None:
                self.frame_sink(frame_id, self._rgb(frame), len(patches))
            for p in patches:
                yield self.uplink.send(p)

    def stats(self) -> SourceStats:
        s = dataclasses.replace(self._stats)
        s.arrivals = s.patches_emitted = self.uplink.n_sent
        s.bytes_sent = self.uplink.bytes_sent
        s.transmission_seconds = self.uplink.transmission_seconds
        return s


class SyntheticCameraSource(LiveSource):
    """A PANDA-like synthetic camera running the live edge pipeline.

    ``scene`` selects the Table-I preset; frame ids embed the camera id
    (``camera_id << 20 | frame index``) so multi-camera merges stay
    unambiguous in shared frame stores.
    """

    kind = "synthetic"

    def __init__(self, scene: int = 0, n_frames: int = 40,
                 canvas: int = 256, width: Optional[int] = None,
                 height: Optional[int] = None, **kwargs):
        width = width if width is not None else 2 * canvas
        height = height if height is not None else canvas
        self.scene = Scene(preset(scene, width=width, height=height))
        super().__init__(height, width, n_frames, canvas=canvas, **kwargs)

    def _frame(self, idx: int) -> Tuple[int, np.ndarray]:
        self.scene.step()
        return (self.camera_id << 20) | self.scene.t, self.scene.render()

    def _rgb(self, frame: np.ndarray) -> np.ndarray:
        return self.scene.render_rgb()


def synthetic_source(n_cameras: int = 1, scene: int = 0, **cfg):
    """Registry factory for ``make_source("synthetic", ...)``.

    One camera returns a plain :class:`SyntheticCameraSource`; more get
    distinct scene presets/ids merged into one stream
    (:class:`~repro.sources.base.MergedSource`), each camera throttling
    independently under backpressure."""
    from repro.sources.base import MergedSource
    if n_cameras < 1:
        raise ValueError(f"n_cameras must be >= 1, got {n_cameras}")
    if n_cameras == 1:
        return SyntheticCameraSource(scene=scene, **cfg)
    return MergedSource([
        SyntheticCameraSource(scene=scene + i, camera_id=i, **cfg)
        for i in range(n_cameras)])
