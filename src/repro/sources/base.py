"""Source protocol, stats record, and the source registry.

A *source* is where arrivals come from: it yields the same
:class:`~repro.data.video.Arrival` events the serving engine consumes,
whether they are replayed from a pre-shaped trace
(:class:`~repro.sources.trace.TraceSource`), produced live by a
synthetic camera running the full edge pipeline
(:class:`~repro.sources.camera.SyntheticCameraSource`), or decoded from
a recorded frame sequence
(:class:`~repro.sources.filestream.FileStreamSource`).

The contract (:class:`Source`) is deliberately tiny:

* ``events(engine)`` — an iterator of arrivals in non-decreasing
  ``t_arrive`` order.  The engine passes *itself* in, which is the
  backpressure channel: a live source reads ``engine.overloaded()`` /
  ``engine.backlog()`` between frames and throttles (drop frames,
  degrade RoI quality); a trace source ignores it.
* ``stats()`` — a :class:`SourceStats` record of what the source did:
  bandwidth accounting (bytes, transmission seconds) plus the
  drop/degrade counters that ``Results.summary()`` surfaces.

Sources are constructed by name through :func:`make_source`, mirroring
``make_placement`` / ``make_clock`` / ``make_executor``, so
``ServeConfig.source`` stays a serializable named reference.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, Iterator, List, Protocol, Sequence, \
    runtime_checkable

from repro.data.video import Arrival
from repro.core.registry import lookup


@dataclasses.dataclass
class SourceStats:
    """What a source did, for ``Results`` assembly and ``summary()``.

    ``frames_total`` counts frames the source *considered* (including
    dropped ones); ``patches_emitted`` equals the number of arrivals
    yielded.  For a trace source the frame counters are zero — a trace
    has no frame loop to drop from.
    """

    kind: str = "source"
    arrivals: int = 0
    bytes_sent: float = 0.0
    transmission_seconds: float = 0.0
    frames_total: int = 0
    frames_dropped: int = 0
    frames_degraded: int = 0
    patches_emitted: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def add(self, other: "SourceStats") -> None:
        """Accumulate another source's counters (multi-camera merge)."""
        self.arrivals += other.arrivals
        self.bytes_sent += other.bytes_sent
        self.transmission_seconds += other.transmission_seconds
        self.frames_total += other.frames_total
        self.frames_dropped += other.frames_dropped
        self.frames_degraded += other.frames_degraded
        self.patches_emitted += other.patches_emitted


@runtime_checkable
class Source(Protocol):
    """What :meth:`~repro.core.engine.ServingEngine.serve` needs."""

    def events(self, engine) -> Iterator[Arrival]:
        """Yield arrivals in non-decreasing ``t_arrive`` order.  The
        engine is the backpressure handle: read ``engine.overloaded()``
        between frames to throttle under load."""

    def stats(self) -> SourceStats:
        """Accounting for the run so far (valid mid-stream and after)."""


class MergedSource:
    """Several per-camera sources merged into one arrival stream.

    Each member's event stream is sorted by ``t_arrive`` (a FIFO uplink
    guarantees that per camera); the merge interleaves them into one
    globally sorted stream — the streaming counterpart of
    :func:`~repro.data.video.merge_arrivals`.  Backpressure reaches
    every member: each receives the engine handle and throttles its own
    camera independently.
    """

    def __init__(self, sources: Sequence[Source]):
        if not sources:
            raise ValueError("MergedSource needs at least one source")
        self.sources = list(sources)

    def events(self, engine) -> Iterator[Arrival]:
        # Stable merge key (t_arrive, camera, per-stream seq): keying on
        # t_arrive alone left same-timestamp arrivals from different
        # cameras ordered by the *constructor's* source order, so two
        # MergedSources over the same cameras listed differently replayed
        # different traces.  The composite key pins tie-breaks to camera
        # id (then intra-stream order), independent of source order —
        # regression-tested in test_sources.
        def keyed(stream):
            for seq, a in enumerate(stream):
                yield (a.t_arrive, a.patch.camera_id, seq), a

        streams = [keyed(s.events(engine)) for s in self.sources]
        for _key, a in heapq.merge(*streams, key=lambda ka: ka[0]):
            yield a

    def stats(self) -> SourceStats:
        total = SourceStats(kind=f"merged[{len(self.sources)}]")
        for s in self.sources:
            total.add(s.stats())
        return total


_SOURCES: Dict[str, Callable[..., Source]] = {}


def register_source(name: str, factory: Callable[..., Source]) -> None:
    """Register a source factory under ``name`` for :func:`make_source`
    (and thus for ``ServeConfig.source`` / ``--source``)."""
    _SOURCES[name] = factory


def make_source(name: str, **cfg) -> Source:
    """Source-name -> instance (``trace`` | ``synthetic`` | ``file``),
    mirroring ``make_placement`` / ``make_clock`` / ``make_executor``.
    ``cfg`` forwards to the registered factory."""
    factory = lookup("source", _SOURCES, name)
    return factory(**cfg)
