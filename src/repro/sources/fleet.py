"""Synthetic camera-fleet source: thousands of cameras, no pixels.

:class:`~repro.sources.camera.SyntheticCameraSource` runs the full edge
pipeline (GMM background -> RoIs -> Alg. 1) per frame — faithful, but
per-frame CPU work that cannot drive a 10k-camera benchmark.
:class:`FleetCameraSource` keeps the part that matters at fleet scale —
every camera's seeded :class:`~repro.sources.camera.RateProfile` frame
clock (heterogeneous base rates, diurnal cycles, bursts) — and emits
RoI patches directly from a deterministic per-camera geometry cycle, so
a 10k-camera, 200k-arrival trace materializes in seconds.

The per-camera streams merge under the stable ``(t_arrive, camera,
seq)`` key (same rule as :class:`~repro.sources.base.MergedSource`), so
the fleet trace is one globally sorted, reproducible arrival stream.
:meth:`camera_rates` / :meth:`class_rates` expose the expected
per-camera and per-SLO-class patch rates — the
:class:`~repro.core.fleet.FleetPlanner`'s inputs.

Registered as source name ``"fleet"``.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partitioning import Patch
from repro.data.video import Arrival, patch_bytes
from repro.sources.base import SourceStats
from repro.sources.camera import RateProfile

__all__ = ["FleetCameraSource", "fleet_source"]

#: RoI sizes cycled per (camera, frame, patch) — small/medium/large mix
_PATCH_SIZES: Tuple[Tuple[int, int], ...] = ((32, 32), (48, 48),
                                             (64, 64), (96, 96))
#: patches per frame cycles 1..3 (mean 2.0) — used by camera_rates()
_PATCHES_PER_FRAME = (1, 2, 3)
_MEAN_PATCHES = sum(_PATCHES_PER_FRAME) / len(_PATCHES_PER_FRAME)


class FleetCameraSource:
    """``n_cameras`` synthetic cameras with heterogeneous rate profiles.

    Camera ``c``'s frame rate is ``base_fps`` scaled by a seeded
    lognormal weight (``rate_sigma`` controls the spread — 0 gives a
    homogeneous fleet, ~1 a heavy-tailed one where a few hot cameras
    carry much of the load, the regime where planned shard layouts beat
    naive equal splits), with the shared diurnal amplitude/period and
    burst parameters riding on top (phase-shifted per camera via the
    profile seed).  Each frame emits 1-3 patches (deterministic cycle)
    whose geometry cycles through ``_PATCH_SIZES``; the camera's SLO is
    ``slos[c % len(slos)]``.

    ``duration_s`` bounds every camera's frame clock.  Backpressure is
    ignored (this is a trace generator, not a live loop) — pair with
    :class:`~repro.sources.camera.LiveSource` semantics when drop /
    degrade behaviour matters.
    """

    def __init__(self, n_cameras: int = 1000, duration_s: float = 30.0,
                 base_fps: float = 1.0, rate_sigma: float = 1.0,
                 diurnal_amplitude: float = 0.3,
                 diurnal_period_s: float = 60.0,
                 burst_prob: float = 0.05, burst_factor: float = 3.0,
                 slos: Sequence[float] = (0.5, 2.0), seed: int = 0,
                 sorted_by_rate: bool = False):
        if n_cameras < 1:
            raise ValueError(f"n_cameras must be >= 1, got {n_cameras}")
        if duration_s <= 0:
            raise ValueError(f"duration_s must be positive, "
                             f"got {duration_s}")
        if not slos:
            raise ValueError("slos must not be empty")
        self.n_cameras = n_cameras
        self.duration_s = duration_s
        self.base_fps = base_fps
        self.diurnal_amplitude = diurnal_amplitude
        self.diurnal_period_s = diurnal_period_s
        self.burst_prob = burst_prob
        self.burst_factor = burst_factor
        self.slos = tuple(slos)
        self.seed = seed
        rng = np.random.default_rng(seed)
        weights = (np.exp(rng.normal(0.0, rate_sigma, size=n_cameras))
                   if rate_sigma > 0 else np.ones(n_cameras))
        if sorted_by_rate:
            # id-correlated load: cameras numbered by site, busiest sites
            # first — the regime where a contiguous equal split melts its
            # first shards and a rate-aware planner earns its keep
            weights = np.sort(weights)[::-1]
        self.sorted_by_rate = sorted_by_rate
        self.fps = np.clip(base_fps * weights, 0.05 * base_fps,
                           20.0 * base_fps)
        self._stats = SourceStats(kind=f"fleet[{n_cameras}]")

    # ------------------------------------------------------ planner feed ----

    def slo_of(self, camera_id: int) -> float:
        return self.slos[camera_id % len(self.slos)]

    def camera_rates(self) -> Dict[int, float]:
        """Expected patch arrivals/sec per camera (fps x mean patches
        per frame) — the :class:`~repro.core.fleet.FleetPlanner` input."""
        return {c: float(self.fps[c]) * _MEAN_PATCHES
                for c in range(self.n_cameras)}

    def class_rates(self) -> Dict[float, float]:
        """Expected patch arrivals/sec per SLO class (reservations)."""
        rates: Dict[float, float] = {}
        for c in range(self.n_cameras):
            slo = self.slo_of(c)
            rates[slo] = rates.get(slo, 0.0) + float(self.fps[c]) \
                * _MEAN_PATCHES
        return rates

    def total_rate(self) -> float:
        return float(self.fps.sum()) * _MEAN_PATCHES

    # ---------------------------------------------------------- streaming ----

    def _camera_events(self, cam: int) -> Iterator[Arrival]:
        profile = RateProfile(
            fps=float(self.fps[cam]),
            diurnal_amplitude=self.diurnal_amplitude,
            diurnal_period_s=self.diurnal_period_s,
            burst_prob=self.burst_prob, burst_factor=self.burst_factor,
            seed=self.seed * 1000003 + cam)
        slo = self.slo_of(cam)
        n_sizes = len(_PATCH_SIZES)
        n_counts = len(_PATCHES_PER_FRAME)
        t = 0.0
        frame = 0
        for dt in profile.intervals():
            t += dt
            if t >= self.duration_s:
                return
            count = _PATCHES_PER_FRAME[(cam + frame) % n_counts]
            for i in range(count):
                w, h = _PATCH_SIZES[(cam + frame + i) % n_sizes]
                x0 = 16 * ((cam + 3 * i) % 8)
                y0 = 16 * ((frame + 5 * i) % 8)
                patch = Patch(x0, y0, x0 + w, y0 + h,
                              frame_id=(cam << 20) | frame,
                              camera_id=cam, t_gen=t, slo=slo)
                yield Arrival(t, patch, patch_bytes(patch))
            frame += 1

    def events(self, engine=None) -> Iterator[Arrival]:
        """Globally sorted fleet stream under the stable ``(t_arrive,
        camera, seq)`` merge key; records stats as it yields."""
        def keyed(cam: int):
            for seq, a in enumerate(self._camera_events(cam)):
                yield (a.t_arrive, a.patch.camera_id, seq), a

        streams = [keyed(c) for c in range(self.n_cameras)]
        for _key, a in heapq.merge(*streams, key=lambda ka: ka[0]):
            self._stats.arrivals += 1
            self._stats.patches_emitted += 1
            self._stats.bytes_sent += a.n_bytes
            yield a

    def arrivals(self) -> List[Arrival]:
        """The whole fleet trace, materialized (benchmark input — both
        the single-engine baseline and every shard-count arm replay the
        identical list)."""
        return list(self.events())

    def stats(self) -> SourceStats:
        return self._stats


def fleet_source(**cfg) -> FleetCameraSource:
    """Factory behind source name ``"fleet"``."""
    return FleetCameraSource(**cfg)
