"""Pluggable arrival sources for the serving engine.

Where arrivals come from is a policy, not a property of the engine:
trace replay (:class:`TraceSource` — the historical path, event-for-
event identical to ``shape_arrivals`` + ``merge_arrivals``), live
synthetic cameras running the full edge pipeline
(:class:`SyntheticCameraSource`), or recorded frame sequences
(:class:`FileStreamSource`).  All yield the same
:class:`~repro.data.video.Arrival` events; the engine's
``serve(source)`` pulls them and hands the source its backpressure
handle (``backlog()`` / ``overloaded()`` against the ingestion window).

Construct by name via :func:`make_source` — ``"trace"``,
``"synthetic"`` (``n_cameras > 1`` merges per-camera streams), or
``"file"`` — mirroring the other pipeline factories.
"""
from repro.sources.base import (MergedSource, Source, SourceStats,
                                make_source, register_source)
from repro.sources.camera import (EdgePipeline, LiveSource, RateProfile,
                                  SyntheticCameraSource, synthetic_source)
from repro.sources.filestream import FileStreamSource
from repro.sources.fleet import FleetCameraSource, fleet_source
from repro.sources.trace import TraceSource

register_source("trace", TraceSource)
register_source("synthetic", synthetic_source)
register_source("file", FileStreamSource)
register_source("fleet", fleet_source)

__all__ = [
    "EdgePipeline",
    "FileStreamSource",
    "FleetCameraSource",
    "LiveSource",
    "MergedSource",
    "RateProfile",
    "Source",
    "SourceStats",
    "SyntheticCameraSource",
    "TraceSource",
    "make_source",
    "register_source",
]
