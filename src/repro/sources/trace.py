"""Trace replay as a source: the historical arrival path, boxed.

``TraceSource`` wraps the exact pipeline ``TangramScheduler.run`` always
used — :func:`~repro.data.video.shape_arrivals` per camera (FIFO uplink)
then :func:`~repro.data.video.merge_arrivals` across cameras — behind
the :class:`~repro.sources.base.Source` protocol.  Replaying a trace
through ``engine.serve(TraceSource(...))`` is event-for-event identical
to ``engine.run(merge_arrivals([shape_arrivals(s, bw) for s in
streams]))``; the boundary-identity test pins this, which is what keeps
every benchmark number unchanged under the source API.

A trace ignores backpressure by design: the events already happened, and
replay semantics (virtual clock) require ingesting all of them.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence

from repro.core.partitioning import Patch
from repro.data.video import (Arrival, merge_arrivals, shape_arrivals)
from repro.sources.base import SourceStats


class TraceSource:
    """Replay per-camera patch streams (or pre-shaped arrivals).

    Exactly one of:

    * ``streams`` + ``bandwidth_bps`` — per-camera patch lists in
      generation order, shaped through one FIFO uplink each;
    * ``arrivals`` — an already-shaped, already-merged arrival list
      (sorted by ``t_arrive``), replayed verbatim.
    """

    def __init__(self, streams: Optional[Sequence[Sequence[Patch]]] = None,
                 bandwidth_bps: Optional[float] = None,
                 arrivals: Optional[Sequence[Arrival]] = None):
        if (streams is None) == (arrivals is None):
            raise ValueError("pass exactly one of streams= or arrivals=")
        if streams is not None:
            if bandwidth_bps is None:
                raise ValueError("streams= requires bandwidth_bps=")
            per_cam = [shape_arrivals(s, bandwidth_bps) for s in streams]
            self.arrivals: List[Arrival] = merge_arrivals(per_cam)
        else:
            self.arrivals = list(arrivals)

    def events(self, engine) -> Iterator[Arrival]:
        return iter(self.arrivals)

    def stats(self) -> SourceStats:
        return SourceStats(
            kind="trace",
            arrivals=len(self.arrivals),
            bytes_sent=sum(a.n_bytes for a in self.arrivals),
            transmission_seconds=sum(a.t_arrive - a.patch.t_gen
                                     for a in self.arrivals),
            patches_emitted=len(self.arrivals))
