"""Serverless platform model (instances, autoscaling, billing)."""
