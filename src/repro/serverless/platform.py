"""Serverless platform model: instances, cold starts, autoscaling, billing.

Deterministic (seeded) discrete-event model of a GPU/TPU-slice serverless
platform with the paper's semantics: per-function concurrency = 1, pay per
execution-second (Eqn. 1), fast scale-up with a cold-start penalty.
Includes straggler injection and optional backup dispatch (hedged
requests) for straggler mitigation at pod scale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.cost import CostMeter
from repro.core.latency import LatencyTable


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    cold_start_s: float = 0.25       # container + weights to accelerator
    container_cold_s: Optional[float] = None
                                     # multi-model decomposition: the
                                     # container-only share of a cold start
                                     # (weights billed separately per model
                                     # via submit's model_load_s).  None:
                                     # cold_start_s covers the container and
                                     # the model load rides on top.
    keep_alive_s: float = 60.0
    max_instances: int = 64
    concurrency: int = 1             # paper setting
    pre_warm: int = 1                # provisioned instances (the paper's
                                     # offline profiling warms the function)
    straggler_prob: float = 0.0
    straggler_factor: float = 4.0
    backup_after_sigma: float = math.inf   # hedged dispatch threshold
    seed: int = 0

    def per_worker(self, n_workers: int, worker: int = 0) -> "PlatformConfig":
        """Capacity shard of this config for one of ``n_workers`` pool
        workers.  Total capacity is conserved exactly: instance and
        pre-warm budgets are split with the remainder going to the
        lowest-index workers, so summing the shards reproduces the
        source config and an ``n_workers`` sweep compares platforms of
        identical aggregate capacity.  Jitter seeds are offset per
        worker so shards draw independent streams.  More workers than
        instances is refused — a zero-instance shard cannot serve."""
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if not 0 <= worker < n_workers:
            raise ValueError(f"worker {worker} not in [0, {n_workers})")
        if self.max_instances < n_workers:
            raise ValueError(
                f"cannot shard {self.max_instances} instances across "
                f"{n_workers} workers (a worker needs >= 1)")

        def share(total: int) -> int:
            return total // n_workers + (1 if worker < total % n_workers
                                         else 0)

        return dataclasses.replace(
            self, max_instances=share(self.max_instances),
            pre_warm=share(self.pre_warm), seed=self.seed + worker)


@dataclasses.dataclass
class _Instance:
    free_at: float = 0.0
    warm_until: float = -1.0
    model: Optional[str] = None      # weights currently resident (None:
                                     # nothing loaded / single-model legacy)


@dataclasses.dataclass
class ExecutionRecord:
    t_submit: float
    t_start: float
    t_finish: float
    exec_s: float
    batch_size: int              # canvases in the invocation
    cold: bool
    hedged: bool
    cost: float
    n_patches: int = 0           # patches consolidated into the batch
    instance: int = -1           # index of the instance that ran it
    backup_instance: int = -1    # hedged backup's instance (-1: none)
    backup_t_start: float = 0.0
    backup_exec_s: float = 0.0
    model: Optional[str] = None  # registry model the batch ran
    load_s: float = 0.0          # weight-load seconds paid (0.0: warm hit)
    weight_loaded: bool = False  # the instance swapped weights in


class Platform:
    def __init__(self, latency: LatencyTable, cfg: PlatformConfig = PlatformConfig(),
                 meter: Optional[CostMeter] = None):
        self.latency = latency
        self.cfg = cfg
        self.meter = meter or CostMeter()
        self.instances: List[_Instance] = [
            _Instance(free_at=0.0, warm_until=cfg.keep_alive_s)
            for _ in range(cfg.pre_warm)]
        self.records: List[ExecutionRecord] = []
        self._rng = np.random.default_rng(cfg.seed)

    # ----------------------------------------------------------- sampling ----

    def _sample_exec(self, batch_size: int,
                     table: Optional[LatencyTable] = None
                     ) -> Tuple[float, bool]:
        mu, sigma = (table or self.latency).mu_sigma(batch_size)
        t = mu + abs(float(self._rng.normal())) * sigma  # one-sided jitter
        straggler = bool(self._rng.random() < self.cfg.straggler_prob)
        if straggler:
            t *= self.cfg.straggler_factor
        return t, straggler

    # ---------------------------------------------------------- placement ----

    @property
    def _container_cold_s(self) -> float:
        cc = self.cfg.container_cold_s
        return self.cfg.cold_start_s if cc is None else cc

    def _acquire(self, t: float, model: Optional[str] = None,
                 load_s: float = 0.0
                 ) -> Tuple[_Instance, float, bool, bool]:
        """Pick a warm free instance, else scale up (cold start), else
        queue on the earliest-free instance.  Returns ``(instance, start,
        cold, loaded)``.

        Among warm free instances the *most recently used* one (max
        ``warm_until``) wins: traffic concentrates on a small hot set, so
        the idle tail cools and falls out of keep-alive instead of every
        instance's lease being refreshed round-robin by stray requests.

        Multi-model economics: an instance warm for model A is *not* warm
        for model B — a warm-free instance holding the right ``model``
        beats one holding another model, which still saves the container
        cold start but pays ``load_s`` to swap weights in.  A genuine
        scale-up pays the container share (``container_cold_s``, falling
        back to ``cold_start_s``) plus ``load_s``.  With ``model=None``
        every instance matches (all start at model ``None``) and the
        behaviour is exactly the legacy single-model path.
        """
        warm_free = [i for i in self.instances
                     if i.free_at <= t and i.warm_until >= t]
        if warm_free:
            same = [i for i in warm_free if i.model == model]
            if same:
                return max(same, key=lambda i: i.warm_until), t, False, False
            # warm container, wrong weights: swap in
            inst = max(warm_free, key=lambda i: i.warm_until)
            return inst, t + load_s, False, load_s > 0
        if len(self.instances) < self.cfg.max_instances:
            inst = _Instance()
            self.instances.append(inst)
            return (inst, t + self._container_cold_s + load_s, True,
                    load_s > 0)
        inst = min(self.instances, key=lambda i: i.free_at)
        start = max(t, inst.free_at)
        cold = inst.warm_until < start
        loaded = False
        if cold:
            start += self._container_cold_s + load_s
            loaded = load_s > 0
        elif inst.model != model:
            start += load_s
            loaded = load_s > 0
        return inst, start, cold, loaded

    # ------------------------------------------------------------- submit ----

    def submit(self, t_submit: float, batch_size: int,
               n_patches: int = 0, model: Optional[str] = None,
               model_load_s: float = 0.0,
               latency: Optional[LatencyTable] = None) -> ExecutionRecord:
        """Run one batch.  ``model``/``model_load_s`` opt into per-model
        warm pools (see :meth:`_acquire`); ``latency`` overrides the
        platform table for this submission (each model samples from its
        own profile).  The defaults reproduce the single-model platform
        exactly."""
        inst, t_start, cold, loaded = self._acquire(t_submit, model=model,
                                                    load_s=model_load_s)
        table = latency or self.latency
        exec_s, straggler = self._sample_exec(batch_size, table)

        hedged = False
        mu, sigma = table.mu_sigma(batch_size)
        threshold = mu + self.cfg.backup_after_sigma * sigma
        t_finish = t_start + exec_s
        cost = self.meter.charge(exec_s)

        # commit the primary's busy interval BEFORE any hedged acquire:
        # with free_at still stale, _acquire at t_start + threshold used to
        # hand the backup the very instance the primary is running on —
        # two overlapping busy intervals billed on one concurrency-1
        # instance (double-billed warm time, utilization > 1 possible)
        inst.free_at = t_start + exec_s
        inst.warm_until = inst.free_at + self.cfg.keep_alive_s
        inst.model = model

        b_instance, b_start, backup_exec = -1, 0.0, 0.0
        if exec_s > threshold:
            # hedged backup on a second instance, fired at the threshold
            hedged = True
            backup_exec, _ = self._sample_exec(batch_size, table)
            inst2, b_start, b_cold, _ = self._acquire(
                t_start + threshold, model=model, load_s=model_load_s)
            t_finish = min(t_finish, b_start + backup_exec)
            cost += self.meter.charge(backup_exec)
            inst2.free_at = b_start + backup_exec
            inst2.warm_until = inst2.free_at + self.cfg.keep_alive_s
            inst2.model = model
            b_instance = self.instances.index(inst2)

        rec = ExecutionRecord(t_submit, t_start, t_finish, exec_s,
                              batch_size, cold, hedged, cost,
                              n_patches=n_patches,
                              instance=self.instances.index(inst),
                              backup_instance=b_instance,
                              backup_t_start=b_start,
                              backup_exec_s=backup_exec,
                              model=model,
                              load_s=model_load_s if loaded else 0.0,
                              weight_loaded=loaded)
        self.records.append(rec)
        return rec

    # ------------------------------------------------------------ metrics ----

    @property
    def total_cost(self) -> float:
        return self.meter.total

    @property
    def mean_consolidation(self) -> float:
        """Mean patches consolidated per invocation, over records that
        reported patch counts (0.0 when none did)."""
        return mean_consolidation(self.records)

    def busy_intervals(self) -> dict:
        """Per-instance busy intervals ``{idx: [(start, end), ...]}``.

        Every billed second appears in exactly one interval (primaries
        and hedged backups each on their own instance), so
        ``sum(lengths) == meter.busy_seconds`` — the audit that overlapping
        in-flight invocations are never double-billed onto one
        concurrency-1 instance."""
        out: dict = {}
        for r in self.records:
            out.setdefault(r.instance, []).append(
                (r.t_start, r.t_start + r.exec_s))
            if r.backup_instance >= 0:
                out.setdefault(r.backup_instance, []).append(
                    (r.backup_t_start, r.backup_t_start + r.backup_exec_s))
        for iv in out.values():
            iv.sort()
        return out

    def utilization(self, horizon: float) -> float:
        if not self.instances or horizon <= 0:
            return 0.0
        return self.meter.busy_seconds / (len(self.instances) * horizon)

    def model_stats(self) -> dict:
        """Per-model platform economics over this platform's records
        (empty when no record was model-tagged): invocations, patches,
        cold starts, weight loads + seconds, and the weight warm-hit
        rate ``1 - weight_loads / invocations``."""
        return model_stats(self.records)


def model_stats(records: List[ExecutionRecord]) -> dict:
    """Aggregate per-model counters from execution records (shared by
    :meth:`Platform.model_stats` and multi-shard scheduler assembly)."""
    out: dict = {}
    for r in records:
        if r.model is None:
            continue
        row = out.setdefault(r.model, {
            "invocations": 0, "patches": 0, "cold_starts": 0,
            "weight_loads": 0, "load_seconds": 0.0})
        row["invocations"] += 1
        row["patches"] += r.n_patches
        row["cold_starts"] += int(r.cold)
        row["weight_loads"] += int(r.weight_loaded)
        row["load_seconds"] += r.load_s
    for row in out.values():
        n = row["invocations"]
        row["load_seconds"] = round(row["load_seconds"], 4)
        row["weight_hit_rate"] = (round(1.0 - row["weight_loads"] / n, 4)
                                  if n else 0.0)
    return out


def mean_consolidation(records: List[ExecutionRecord]) -> float:
    """Mean patches consolidated per invocation over records that
    reported patch counts (0.0 when none did) — shared by the platform
    property and multi-shard aggregation in the scheduler."""
    counted = [r.n_patches for r in records if r.n_patches > 0]
    if not counted:
        return 0.0
    return sum(counted) / len(counted)


def split_platform(platform: Platform, n_workers: int,
                   weights: Optional[List[float]] = None) -> List[Platform]:
    """Per-worker capacity shards of one platform (the simulation twin of
    splitting the device mesh into worker slices).

    Each shard gets ``cfg.per_worker``'s instance budget and its own
    jitter stream, but all shards **share the source platform's cost
    meter** — total cost / busy seconds aggregate exactly as if one
    platform had served everything, so Results accounting is unchanged
    by the split.

    ``weights`` (optional, one per shard) splits the instance and
    pre-warm budgets *proportionally* instead of evenly — the fleet
    planner's per-shard worker allocation — still conserving the totals
    exactly (largest remainder, at least one instance per shard)."""
    if weights is None:
        return [Platform(platform.latency,
                         platform.cfg.per_worker(n_workers, worker=i),
                         meter=platform.meter)
                for i in range(n_workers)]
    if len(weights) != n_workers:
        raise ValueError(f"{len(weights)} weights for {n_workers} shards")
    cfg = platform.cfg
    if cfg.max_instances < n_workers:
        raise ValueError(
            f"cannot shard {cfg.max_instances} instances across "
            f"{n_workers} workers (a worker needs >= 1)")

    def shares(total: int, floor: int) -> List[int]:
        scale = sum(weights) or 1.0
        raw = [w / scale * total for w in weights]
        out = [max(floor, int(r)) for r in raw]
        while sum(out) > total:
            i = max(range(n_workers),
                    key=lambda j: (out[j] - raw[j], out[j]))
            if out[i] <= floor:
                break
            out[i] -= 1
        order = sorted(range(n_workers), key=lambda j: raw[j] - out[j],
                       reverse=True)
        i = 0
        while sum(out) < total:
            out[order[i % n_workers]] += 1
            i += 1
        return out

    instances = shares(cfg.max_instances, 1)
    pre_warm = shares(cfg.pre_warm, 0)
    return [Platform(platform.latency,
                     dataclasses.replace(cfg, max_instances=instances[i],
                                         pre_warm=pre_warm[i],
                                         seed=cfg.seed + i),
                     meter=platform.meter)
            for i in range(n_workers)]
