"""Parameter spec trees: single source of truth for shapes/axes/init.

Each model module exposes ``param_specs(cfg) -> nested dict of ParamSpec``.
From that one tree we derive:

* ``abstract_params``  — ShapeDtypeStruct tree (dry-run lowering, no alloc)
* ``init_params``      — materialized arrays (tests / real training)
* ``param_pspecs``     — PartitionSpec tree via the logical-axis rules
* ``count_params``     — exact parameter count
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import Rules, logical_to_spec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: object = jnp.float32
    init: str = "normal"        # normal | zeros | ones | embed | pos
    scale: float = 1.0          # stddev multiplier for "normal"
    fan_in_axes: Tuple[int, ...] = ()  # dims to use for fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable, specs):
    return jax.tree_util.tree_map(fn, specs, is_leaf=_is_spec)


def abstract_params(specs):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def param_pspecs(specs, rules: Rules, mesh=None):
    """PartitionSpec tree for the params.

    With ``mesh`` given, mesh axes whose size does not evenly divide the
    corresponding dim are dropped (jit input shardings must divide evenly;
    e.g. 40 heads cannot shard over a 16-way "model" axis — the weight
    stays replicated while activation constraints may still shard unevenly).
    """
    if mesh is None:
        return tree_map_specs(lambda s: logical_to_spec(s.axes, rules), specs)
    from repro.sharding import divisible_spec
    return tree_map_specs(
        lambda s: divisible_spec(s.shape, s.axes, rules, mesh), specs)


def count_params(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


def _init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init in ("normal", "embed", "pos"):
        if spec.fan_in_axes:
            fan_in = math.prod(spec.shape[a] for a in spec.fan_in_axes)
        else:
            # default: all dims but the last are fan-in
            fan_in = math.prod(spec.shape[:-1]) or 1
        std = spec.scale / math.sqrt(fan_in) if spec.init == "normal" else 0.02 * spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    raise ValueError(spec.init)


def init_params(key, specs):
    """Materialize the tree. Deterministic per-leaf keys via fold_in on path."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    paths = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)[0]
    out = []
    for i, ((path, spec), _) in enumerate(zip(paths, leaves)):
        sub = jax.random.fold_in(key, i)
        out.append(_init_leaf(sub, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def spec(shape: Sequence[int], axes: Sequence[Optional[str]], *,
         dtype=jnp.float32, init: str = "normal", scale: float = 1.0,
         fan_in_axes: Tuple[int, ...] = ()) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale, fan_in_axes)


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)
