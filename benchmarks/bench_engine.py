"""Engine perf trajectory: incremental vs from-scratch restitch + e2e sim.

Two measurements, written to ``BENCH_engine.json`` at the repo root:

* (a) invoker arrivals/sec at queue depths {16, 64, 256} for the
  incremental packer (live ``PackState``, probe-then-append) vs the
  paper's literal from-scratch restitch of the whole queue per arrival.
  Arrivals use a huge SLO and an unbounded canvas budget so the queue
  actually reaches the target depth — this isolates restitch cost.
* (b) end-to-end simulated serving throughput (patches/sec) through the
  unified engine: bandwidth-shaped arrivals -> per-class invoker pool ->
  SimExecutor/platform, on the standard multi-camera synthetic streams.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_engine            # full
    PYTHONPATH=src python -m benchmarks.bench_engine --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import LatencyTable, detector_latency_model
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig

DEPTHS = (16, 64, 256)
CANVAS = 256


def _queue_patches(depth: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Patch(0, 0, int(rng.integers(16, 96)), int(rng.integers(16, 96)),
                  t_gen=i * 1e-4, slo=1e9) for i in range(depth)]


def bench_restitch(depth: int, incremental: bool, budget_s: float) -> float:
    """Arrivals/sec while filling a queue to ``depth`` (no firing)."""
    table = LatencyTable({1: (1e-9, 0.0)})
    patches = _queue_patches(depth)
    reps = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < budget_s or reps == 0:
        inv = SLOAwareInvoker(CANVAS, CANVAS, table,
                              max_canvases=1 << 30,
                              incremental=incremental)
        for p in patches:
            inv.on_patch(0.0, p)
        assert len(inv.queue) == depth
        reps += 1
    return depth * reps / (time.perf_counter() - t0)


def bench_e2e(n_cams: int, n_frames: int, per_frame: int = 6) -> dict:
    rng = np.random.default_rng(0)
    streams = []
    for cam in range(n_cams):
        patches = []
        for f in range(n_frames):
            t = f / 10.0
            for _ in range(rng.integers(1, per_frame + 1)):
                patches.append(Patch(0, 0, int(rng.integers(16, 160)),
                                     int(rng.integers(16, 160)),
                                     frame_id=f, camera_id=cam,
                                     t_gen=t, slo=1.0))
        streams.append(patches)
    table = detector_latency_model(CANVAS, CANVAS).build_table(16)
    sched = TangramScheduler(CANVAS, CANVAS, table,
                             Platform(table, PlatformConfig()))
    t0 = time.perf_counter()
    res = sched.run(streams, bandwidth_bps=20e6)
    dt = time.perf_counter() - t0
    return {"patches": res.n_patches, "seconds": round(dt, 4),
            "patches_per_s": round(res.n_patches / dt, 1),
            "violation_rate": round(res.violation_rate, 4),
            "invocations": res.invocations}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short budgets for CI")
    ap.add_argument("--out", default=None,
                    help="output path (default: repo-root BENCH_engine.json)")
    args = ap.parse_args(argv)

    budget = 0.2 if args.smoke else 1.0
    report = {"smoke": bool(args.smoke), "queue_restitch": {}}
    for depth in DEPTHS:
        inc = bench_restitch(depth, incremental=True, budget_s=budget)
        scr = bench_restitch(depth, incremental=False, budget_s=budget)
        report["queue_restitch"][str(depth)] = {
            "incremental_arrivals_per_s": round(inc, 1),
            "scratch_arrivals_per_s": round(scr, 1),
            "speedup": round(inc / scr, 2),
        }
        print(f"depth {depth:4d}: incremental {inc:10.0f}/s "
              f"scratch {scr:10.0f}/s  speedup {inc / scr:6.1f}x")

    report["e2e_sim"] = bench_e2e(n_cams=2 if args.smoke else 4,
                                  n_frames=15 if args.smoke else 40)
    print("e2e:", report["e2e_sim"])

    out = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json")
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
